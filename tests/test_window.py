"""Sliding windows, incremental collects, and the caches behind them.

Covers the PR's two tentpole workloads end to end: (1) ``Dataset.window``
re-merging cached group states — every window bitwise equal to mining its
rows from scratch, mergeable and order-sensitive verbs alike; (2) the
incremental path — appending a file re-decodes only the fresh groups,
proven by ``ScanReport.groups_cached`` / ``groups_folded``.  Plus the
satellite regressions: result memoization is zero-read until a file's
``st_mtime_ns``/``st_size`` changes, and ``explain()`` prints the
state-cache accounting.
"""
import dataclasses
import os

import numpy as np
import pytest

from helpers import random_log, sorted_frame

import repro
from repro.core import engine
from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from repro.dataset import engines as ds_engines
from repro.dataset.window import _unit_chunks
from repro.query.expr import col
from repro.query.statecache import state_cache
from repro.storage import edf
from repro.storage.edf import EDFReader

VERBS = ("dfg", "variants", "case_sizes", "case_durations",
         "activity_counts", "eventually_follows", "alpha", "heuristics",
         "discovery", "stats", "sojourn_times", "performance_dfg")
N_ACTS, N_CASES = 6, 50


def eq(a, b):
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        return type(a) is type(b) and all(
            eq(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, dict):
        return set(a) == set(b) and all(eq(a[k], b[k]) for k in a)
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(eq(x, y) for x, y in zip(a, b))
    if hasattr(a, "shape") or hasattr(b, "shape"):
        return np.array_equal(np.asarray(a), np.asarray(b))
    return a == b


def _slice(frame, a, b):
    return EventFrame({k: v[a:b] for k, v in frame.columns.items()},
                      {k: v[a:b] for k, v in frame.valid.items()},
                      frame.rows_valid()[a:b])


def _fresh():
    state_cache().clear()
    ds_engines.clear_result_cache()


@pytest.fixture(scope="module")
def twofiles(tmp_path_factory):
    """Two EDF files with tiny row groups and a case cut mid-file."""
    rng = np.random.default_rng(3)
    frame, tables = sorted_frame(
        random_log(rng, n_cases=N_CASES, n_acts=N_ACTS, max_len=9))
    tmp = tmp_path_factory.mktemp("window")
    p1, p2 = str(tmp / "a.edf"), str(tmp / "b.edf")
    cut = frame.nrows // 2
    edf.write(p1, _slice(frame, 0, cut), tables, version=3,
              row_group_rows=19)
    edf.write(p2, _slice(frame, cut, frame.nrows), tables, version=3,
              row_group_rows=19)
    return frame, [p1, p2]


def _open(paths):
    return repro.open(paths, num_activities=N_ACTS, num_cases=N_CASES)


def test_streaming_report_folds_then_caches(twofiles):
    """Satellite: ScanReport's groups_folded / groups_cached counters —
    first collect decodes everything, the second merges from cache."""
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    rep1 = ds.collect("dfg", engine="streaming").report
    assert rep1.groups_folded == rep1.groups_read > 0
    assert rep1.groups_cached == 0
    ds_engines.clear_result_cache()       # keep the state cache warm
    rep2 = ds.collect("dfg", engine="streaming").report
    assert rep2.groups_read == 0 and rep2.groups_folded == 0
    assert rep2.groups_cached == rep1.groups_folded
    assert rep2.bytes_read == 0


def test_result_memo_zero_reads_until_touch(twofiles, monkeypatch):
    """Satellite: memoized CollectResults keyed by file stat signatures —
    an untouched re-collect issues zero reads and returns the identical
    object; touching a file forces a recompute."""
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    calls = {"n": 0}
    orig = EDFReader.read_group

    def counting(self, *a, **k):
        calls["n"] += 1
        return orig(self, *a, **k)

    monkeypatch.setattr(EDFReader, "read_group", counting)
    a = ds.collect("dfg", engine="streaming")
    assert calls["n"] > 0
    before = calls["n"]
    b = ds.collect("dfg", engine="streaming")
    assert b is a and calls["n"] == before
    os.utime(paths[0])                    # st_mtime_ns changes
    c = ds.collect("dfg", engine="streaming")
    assert c is not a
    assert eq(a.result, c.result)


def test_memo_disabled_by_env(twofiles, monkeypatch):
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    monkeypatch.setenv(ds_engines.RESULT_CACHE_ENV, "0")
    a = ds.collect("dfg", engine="streaming")
    b = ds.collect("dfg", engine="streaming")
    assert b is not a and eq(a.result, b.result)


def test_group_windows_bitwise_equal_scratch(twofiles):
    """Every verb — mergeable or not — windowed by row groups matches a
    sequential scratch fold of exactly those units."""
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    w = ds.window(by="groups", size=3, step=2)
    assert len(w.bounds()) >= 3
    dims = engine.Dims(N_ACTS, N_CASES)
    for verb in VERBS:
        spec = engine.kernel_spec(verb)
        kern = spec.make(dims)
        got = w.collect(verb)
        units, _ = w._units(spec.columns)
        assert got.bounds == tuple(w.bounds()) and got.by == "groups"
        for (lo, hi), res in zip(got.bounds, got.results):
            state, carry = kern.init()
            for ch in _unit_chunks(units[lo:hi]):
                if ch.nrows:
                    state, carry = kern.update(state, carry, ch)
            assert eq(kern.finalize(state, carry), res), (verb, lo, hi)


def test_group_windows_reuse_cached_states(twofiles):
    """A slide re-merges cached states: after the first windowed collect,
    the next one over the same dataset decodes nothing."""
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    w = ds.window(by="groups", size=3, step=2)
    r1 = w.collect("dfg")
    assert r1.report is not None and r1.report.groups_folded > 0
    r2 = ds.window(by="groups", size=4, step=3).collect("dfg")
    assert r2.report.groups_read == 0
    assert r2.report.groups_cached == r1.report.groups_folded


def test_time_windows_bitwise_equal_filter_collect(twofiles):
    """Time windows == eager filter(between)+collect, bitwise, for a
    mergeable and an order-sensitive verb."""
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    wt = ds.window(by="time", size=30.0, step=15.0)
    for verb in ("dfg", "stats"):
        got = wt.collect(verb)
        assert len(got.bounds) >= 3 and got.by == "time"
        for (tlo, thi), res in zip(got.bounds, got.results):
            ref = ds.filter(col(TIMESTAMP).between(tlo, thi)).collect(
                verb, engine="eager").result
            assert eq(ref, res), (verb, tlo, thi)
    # overlapping windows shared interior-group states through the cache
    assert wt.collect("dfg").report.groups_cached > 0


def test_incremental_append_decodes_only_fresh_groups(twofiles):
    """Acceptance: after appending a file, collect re-decodes only the new
    file's groups; result stays bitwise equal to mining from scratch."""
    _, paths = twofiles
    for verb in VERBS:
        spec = engine.kernel_spec(verb)
        if spec.make(engine.Dims(N_ACTS, N_CASES)).stitch is None:
            continue                      # order-sensitive: no cached path
        _fresh()
        r1 = _open(paths[:1]).collect(verb, engine="streaming")
        old = r1.report.groups_folded
        assert old == r1.report.groups_read > 0
        ds_engines.clear_result_cache()
        r2 = _open(paths).collect(verb, engine="streaming")
        fresh = r2.report.groups_total - old
        assert r2.report.groups_cached == old, verb
        assert r2.report.groups_read == fresh > 0, verb
        _fresh()
        scratch = _open(paths).collect(verb, engine="eager")
        assert eq(r2.result, scratch.result), verb


def test_drift_and_conformance(twofiles):
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    wt = ds.window(by="time", size=30.0, step=15.0)
    n = len(wt.bounds())
    d = wt.drift()
    assert len(d) == n and d[0] == 1.0
    assert all(0.0 <= x <= 1.0 for x in d)
    # a fixed reference DFG scores every window against the same footprint
    ref = ds.dfg()
    dref = wt.drift(reference=ref)
    assert len(dref) == n and all(0.0 <= x <= 1.0 for x in dref)
    for model in (ds.alpha(), ds.heuristics()):
        cf = wt.conformance(model)
        assert len(cf) == n and all(0.0 <= x <= 1.0 for x in cf)


def test_windowed_collect_many(twofiles):
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    w = ds.window(by="groups", size=2, step=2)
    cm = w.collect_many(["dfg", "case_sizes"])
    singles = {v: w.collect(v) for v in ("dfg", "case_sizes")}
    assert cm.bounds == singles["dfg"].bounds
    for i in range(len(cm.bounds)):
        for v in ("dfg", "case_sizes"):
            assert eq(cm.results[i][v], singles[v].results[i]), (v, i)


def test_explain_prints_state_cache_accounting(twofiles):
    """Satellite: explain() reports groups merged-from-cache vs freshly
    decoded, before and after the cache warms."""
    _, paths = twofiles
    ds = _open(paths)
    _fresh()
    cold = ds.explain("dfg")
    assert "state-cache" in cold
    probe = ds_engines.cache_probe(ds, "dfg")
    assert probe["cached"] == 0 and probe["fresh"] == probe["units"] > 0
    ds.collect("dfg", engine="streaming")
    warm = ds_engines.cache_probe(ds, "dfg")
    assert warm["cached"] == probe["units"] and warm["fresh"] == 0
    assert "0 freshly decoded" in ds.explain("dfg")


def test_window_argument_validation(twofiles):
    _, paths = twofiles
    ds = _open(paths)
    with pytest.raises(ValueError):
        ds.window(by="cases", size=2)
    with pytest.raises(ValueError):
        ds.window(by="groups", size=0)
    with pytest.raises(ValueError):
        ds.window(by="groups", size=2, step=-1)
    with pytest.raises(ValueError):
        ds.window(by="groups", size=2.5)  # units are whole row groups
    with pytest.raises(ValueError):
        ds.filter(repro.cases_containing(2)).window(by="groups", size=2)
    # in-memory datasets cannot window by groups (no row groups to slide)
    mem = repro.open(twofiles[0], num_activities=N_ACTS, num_cases=N_CASES)
    with pytest.raises(ValueError):
        mem.window(by="groups", size=2)
