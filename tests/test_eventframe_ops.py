"""Paper §5.3 transformation functions + conversion §5.2 round trip."""
import numpy as np
from _prop import given, settings, strategies as st

from repro.core import ACTIVITY, CASE, TIMESTAMP, ClassicEventLog, EventFrame
from repro.core import ops

from helpers import random_log, sorted_frame


def test_conversion_roundtrip():
    rng = np.random.default_rng(0)
    log = random_log(rng, n_cases=10, n_acts=4, extra_attrs=2)
    frame, tables = log.to_eventframe()
    back = ClassicEventLog.from_eventframe(frame, tables)
    assert len(back.events) == len(log.events)
    for a, b in zip(back.events, log.events):
        assert set(a) == set(b)
        for k in a:
            if isinstance(b[k], float):   # timestamps pass through float32
                assert abs(a[k] - b[k]) <= 1e-5 * max(1.0, abs(b[k]))
            else:
                assert a[k] == b[k], (k, a[k], b[k])


def test_shift_concat_proj_mergstrv_compose():
    """The shifting-and-counting pipeline of Fig. 3, step by step."""
    rng = np.random.default_rng(1)
    log = random_log(rng, n_cases=8, n_acts=4)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    sh = ops.shift(frame)
    assert np.asarray(sh[ACTIVITY])[:-1].tolist() == np.asarray(frame[ACTIVITY])[1:].tolist()
    assert not bool(sh.rows_valid()[-1])
    both = ops.concat(frame, sh, ".2")
    assert CASE + ".2" in both
    kept = ops.proj(both, both[CASE] == both[CASE + ".2"])
    merged = ops.mergstrv(kept, "pair", ACTIVITY, ACTIVITY + ".2", a)
    pairs = np.asarray(merged["pair"])[np.asarray(kept.rows_valid())]
    src, dst = pairs // a, pairs % a
    assert (src < a).all() and (dst < a).all()


def test_sort_stability_and_order():
    rng = np.random.default_rng(2)
    log = random_log(rng, n_cases=12, n_acts=3)
    frame, _ = log.to_eventframe()
    s = ops.sort(frame, (TIMESTAMP, CASE))
    case = np.asarray(s[CASE])
    ts = np.asarray(s[TIMESTAMP])
    assert (np.diff(case) >= 0).all()
    for c in np.unique(case):
        assert (np.diff(ts[case == c]) >= 0).all()


def test_group_segments():
    rng = np.random.default_rng(3)
    log = random_log(rng, n_cases=9, n_acts=3)
    frame, _ = log.to_eventframe()
    sf, seg, starts = ops.group_segments(frame, CASE)
    case = np.asarray(sf[CASE])
    seg = np.asarray(seg)
    # same case <-> same segment
    assert len(np.unique(seg)) == len(np.unique(case))
    for s_id in np.unique(seg):
        assert len(np.unique(case[seg == s_id])) == 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_proj_idempotent_and_monotone(seed):
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=10, n_acts=5)
    frame, _ = log.to_eventframe()
    m1 = np.asarray(frame[ACTIVITY]) % 2 == 0
    f1 = ops.proj(frame, m1)
    f2 = ops.proj(f1, m1)
    np.testing.assert_array_equal(np.asarray(f1.rows_valid()),
                                  np.asarray(f2.rows_valid()))
    # projection can only shrink
    assert int(f1.rows_valid().sum()) <= frame.nrows


def test_select_column_projection():
    rng = np.random.default_rng(4)
    log = random_log(rng, n_cases=5, n_acts=3, extra_attrs=3)
    frame, _ = log.to_eventframe()
    two = frame.select([CASE, ACTIVITY])
    assert set(two.names) == {CASE, ACTIVITY}


def test_value_counts():
    import jax.numpy as jnp
    col = jnp.asarray([0, 1, 1, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(ops.value_counts(col, 4)), [1, 2, 3, 0])
