"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.dfg_count import dfg_count_pallas, dfg_count_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_pallas

rng = np.random.default_rng(0)


@pytest.mark.parametrize("a,e", [(4, 100), (11, 1000), (42, 4096), (130, 2000),
                                 (256, 512), (11, 1)])
def test_dfg_count_shapes(a, e):
    src = jnp.asarray(rng.integers(0, a, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, a, e), jnp.int32)
    w = jnp.asarray(rng.random(e) < 0.7, jnp.float32)
    got = dfg_count_pallas(src, dst, w, a, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(dfg_count_ref(src, dst, w, a)))


@pytest.mark.parametrize("be,ba", [(256, 128), (1024, 256)])
def test_dfg_count_blocks(be, ba):
    a, e = 100, 3000
    src = jnp.asarray(rng.integers(0, a, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, a, e), jnp.int32)
    w = jnp.ones((e,), jnp.float32)
    got = dfg_count_pallas(src, dst, w, a, block_e=be, block_a=ba, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(dfg_count_ref(src, dst, w, a)))


def test_dfg_count_weighted():
    a = 8
    src = jnp.asarray([0, 1, 2, 0], jnp.int32)
    dst = jnp.asarray([1, 2, 3, 1], jnp.int32)
    w = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
    got = np.asarray(dfg_count_pallas(src, dst, w, a, interpret=True))
    assert got[0, 1] == 2 and got[1, 2] == 0 and got[2, 3] == 1


@pytest.mark.parametrize(
    "b,h,kvh,sq,sk,d,causal,win",
    [(1, 4, 2, 128, 128, 64, True, None),
     (2, 8, 2, 256, 256, 64, True, 512),
     (1, 4, 4, 200, 200, 32, True, None),
     (1, 4, 1, 1, 384, 64, False, None),
     (1, 2, 2, 96, 96, 128, True, 32),
     (2, 4, 2, 64, 64, 16, False, None)])
def test_flash_attention_shapes(b, h, kvh, sq, sk, d, causal, win):
    q = jnp.asarray(rng.standard_normal((b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), jnp.float32)
    kvlen = jnp.int32(sk - 17) if sk > 64 else None
    got = flash_attention_pallas(q, k, v, kvlen, causal=causal, window=win,
                                 interpret=True)
    ref = attention_ref(q, k, v, kvlen, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    b, h, kvh, s, d = 1, 4, 2, 128, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kvh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kvh, s, d)), dtype)
    got = flash_attention_pallas(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_attention_blocks():
    b, h, kvh, s, d = 1, 2, 2, 256, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kvh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kvh, s, d)), jnp.float32)
    ref = attention_ref(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128)]:
        got = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
