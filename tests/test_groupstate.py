"""Group-state algebra property tests (satellite of the merge-fold PR).

For every registered *mergeable* KernelSpec, under both
``REPRO_SEGMENT_BACKEND`` implementations: ``merge_group_states`` is
associative, ``empty_group_state`` is its identity, and any merge-tree
over fresh folds of contiguous slices — including single-row units and
states straddling row-group and file boundaries — finalizes bitwise
equal to mining the whole log in one fold.
"""
import dataclasses

import numpy as np
import pytest

from _prop import given, settings, strategies as st
from helpers import random_log, sorted_frame

import repro
from repro.core import backend, engine
from repro.core.eventframe import EventFrame
from repro.dataset import engines as ds_engines
from repro.query.statecache import state_cache
from repro.storage import edf

_DIMS = engine.Dims(5, 24)


def _mergeable_specs():
    out = []
    for name in sorted(engine.kernel_specs()):
        spec = engine.kernel_spec(name)
        if engine.mergeable(spec.make(_DIMS)):
            out.append(name)
    return out


MERGEABLE = _mergeable_specs()


def eq(a, b):
    """Structural bitwise equality over dataclasses/dicts/tuples/arrays
    (AlphaModel's elementwise ``__eq__`` breaks plain comparison)."""
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        return type(a) is type(b) and all(
            eq(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, dict):
        return set(a) == set(b) and all(eq(a[k], b[k]) for k in a)
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(eq(x, y) for x, y in zip(a, b))
    if hasattr(a, "shape") or hasattr(b, "shape"):
        return np.array_equal(np.asarray(a), np.asarray(b))
    return a == b


def _slice(frame, a, b):
    return EventFrame({k: v[a:b] for k, v in frame.columns.items()},
                      {k: v[a:b] for k, v in frame.valid.items()},
                      frame.rows_valid()[a:b])


def _fold_slices(kernel, frame, bounds):
    return [engine.fold_group(kernel, [_slice(frame, a, b)] if b > a else [])
            for a, b in bounds]


@pytest.fixture(scope="module")
def log24():
    rng = np.random.default_rng(11)
    frame, tables = sorted_frame(
        random_log(rng, n_cases=24, n_acts=5, max_len=7))
    return frame


def test_registry_has_mergeable_kernels():
    # the algebra must cover the whole registry except the three
    # order-sensitive float folds
    assert set(MERGEABLE) >= {"dfg", "variants", "case_sizes",
                              "case_durations", "activity_counts",
                              "eventually_follows", "alpha", "heuristics",
                              "discovery"}


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000), ca=st.integers(0, 120),
       cb=st.integers(0, 120), pick=st.integers(0, 1))
def test_merge_associativity_and_identity(seed, ca, cb, pick):
    """merge(merge(a,b),c) == merge(a,merge(b,c)); empty is the identity.

    Cut points are arbitrary row offsets, so slices routinely straddle a
    case (the stitch's hard path) and may be empty (the identity path).
    Each example draws one of the two segment backends.
    """
    with backend.use_backend(["xla", "pallas"][pick]):
        rng = np.random.default_rng(seed)
        frame, _ = sorted_frame(
            random_log(rng, n_cases=10, n_acts=5, max_len=6))
        n = frame.nrows
        i, j = sorted((min(ca, n), min(cb, n)))
        for name in MERGEABLE:
            kernel = engine.kernel_spec(name).make(engine.Dims(5, 10))
            a, b, c = _fold_slices(kernel, frame, [(0, i), (i, j), (j, n)])
            left = engine.merge_group_states(
                kernel, engine.merge_group_states(kernel, a, b), c)
            right = engine.merge_group_states(
                kernel, a, engine.merge_group_states(kernel, b, c))
            whole = engine.fold_group(kernel, [frame])
            r_left = engine.finalize_group(kernel, left)
            assert eq(r_left, engine.finalize_group(kernel, right)), name
            assert eq(r_left, engine.finalize_group(kernel, whole)), name
            # identity: merging the zero-row fold in on either side is a no-op
            empty = engine.empty_group_state(kernel)
            for s in (a, b, c):
                if s.rows:
                    assert engine.merge_group_states(kernel, empty, s) is s
                    assert engine.merge_group_states(kernel, s, empty) is s


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_single_row_units_merge_to_whole(impl, log24):
    """The extreme chunking: every physical row its own unit — every merge
    is a boundary stitch — still reduces to the whole-log bits."""
    with backend.use_backend(impl):
        frame = log24
        bounds = [(r, r + 1) for r in range(frame.nrows)]
        for name in MERGEABLE:
            kernel = engine.kernel_spec(name).make(_DIMS)
            states = _fold_slices(kernel, frame, bounds)
            got = engine.finalize_group(
                kernel, engine.merge_tree(kernel, states))
            ref = engine.finalize_group(
                kernel, engine.fold_group(kernel, [frame]))
            assert eq(ref, got), name


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), pieces=st.integers(1, 9))
def test_merge_tree_shape_free(seed, pieces):
    """Balanced tree == left-to-right fold of merges: the tree shape is a
    free scheduling choice, not part of the result."""
    rng = np.random.default_rng(seed)
    frame, _ = sorted_frame(random_log(rng, n_cases=8, n_acts=4, max_len=5))
    cuts = sorted(int(rng.integers(0, frame.nrows + 1))
                  for _ in range(pieces - 1))
    bounds = list(zip([0] + cuts, cuts + [frame.nrows]))
    for name in ("dfg", "variants", "discovery", "eventually_follows"):
        kernel = engine.kernel_spec(name).make(engine.Dims(4, 8))
        states = _fold_slices(kernel, frame, bounds)
        tree = engine.merge_tree(kernel, states)
        linear = engine.empty_group_state(kernel)
        for s in states:
            linear = engine.merge_group_states(kernel, linear, s)
        assert eq(engine.finalize_group(kernel, tree),
                  engine.finalize_group(kernel, linear)), name


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_states_straddle_group_and_file_boundaries(impl, tmp_path, log24):
    """Group states harvested from on-disk row groups — cases straddling
    both row-group and file boundaries — re-merge to the scratch fold."""
    with backend.use_backend(impl):
        frame = log24
        n = frame.nrows
        p1 = str(tmp_path / f"a_{impl}.edf")
        p2 = str(tmp_path / f"b_{impl}.edf")
        # a mid-case cut between the files, tiny row groups within them
        edf.write(p1, _slice(frame, 0, 2 * n // 3), {}, version=3,
                  row_group_rows=13)
        edf.write(p2, _slice(frame, 2 * n // 3, n), {}, version=3,
                  row_group_rows=13)
        ds = repro.open([p1, p2], num_activities=_DIMS[0],
                        num_cases=_DIMS[1])
        state_cache().clear()
        for name in MERGEABLE:
            kernel, states, report = ds_engines.group_states_for(ds, name)
            assert report.groups_total >= 4     # boundaries actually exist
            got = engine.finalize_group(
                kernel, engine.merge_tree(kernel, states))
            ref = engine.finalize_group(
                kernel, engine.fold_group(kernel, [frame]))
            assert eq(ref, got), name
