"""Distributed DFG / sort / compression: validated in an 8-device subprocess
(the XLA device-count flag must never leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


_PRE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import dfg
from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from repro.data import synthetic

frame, tables = synthetic.generate(num_cases=5000, num_activities=13, seed=9)
n = frame.nrows
pad = (-n) % 8
cols = {k: jnp.pad(v, (0, pad), constant_values=-1) for k, v in frame.columns.items()}
frame = EventFrame(cols, {}, jnp.pad(frame.rows_valid(), (0, pad)))
"""


def test_sharded_dfg_matches_local_and_streaming():
    """sharded DFG == streaming DFG == single-shot DFG, bitwise (counts,
    starts, ends) — all three are the same chunk-kernel."""
    out = run_child(_PRE + """
from repro.core import ChunkedEventFrame, run_streaming
from repro.core.dfg import dfg_kernel
from repro.distributed.dfg import dfg_sharded_host
ref = dfg(frame, 13, method="segment")
stream = run_streaming(dfg_kernel(13), ChunkedEventFrame.from_frame(frame, 4096))
for nm in ("counts", "starts", "ends"):
    assert (np.asarray(getattr(stream, nm)) == np.asarray(getattr(ref, nm))).all(), nm
for shards in (1, 2, 4, 8):
    got = dfg_sharded_host(frame, 13, shards)
    for nm in ("counts", "starts", "ends"):
        assert (np.asarray(getattr(got, nm)) == np.asarray(getattr(ref, nm))).all(), (shards, nm)
print("OK", int(ref.counts.sum()))
""")
    assert out.startswith("OK")


def test_sharded_discovery_matches_local_and_streaming():
    """sharded discovery state (DFG + L2 triple counts) == streamed ==
    single-shot, bitwise, and the finalized models agree."""
    out = run_child(_PRE + """
from repro.core import ChunkedEventFrame, discovery
from repro.distributed.discovery import discovery_state_sharded_host
ref = discovery.discovery_state(frame, 13)
stream = discovery.streaming_discovery_state(
    ChunkedEventFrame.from_frame(frame, 4096), 13)
assert (np.asarray(stream.l2_counts) == np.asarray(ref.l2_counts)).all()
assert (np.asarray(stream.dfg.counts) == np.asarray(ref.dfg.counts)).all()
ref_alpha = discovery.discover_alpha(ref.dfg)
ref_net = discovery.discover_heuristics(ref)
for shards in (1, 2, 4, 8):
    got = discovery_state_sharded_host(frame, 13, shards)
    assert (np.asarray(got.l2_counts) == np.asarray(ref.l2_counts)).all(), shards
    for nm in ("counts", "starts", "ends"):
        assert (np.asarray(getattr(got.dfg, nm))
                == np.asarray(getattr(ref.dfg, nm))).all(), (shards, nm)
    m = discovery.discover_alpha(got.dfg)
    assert m.places == ref_alpha.places
    assert m.start_activities == ref_alpha.start_activities
    net = discovery.discover_heuristics(got)
    assert (np.asarray(net.dependency) == np.asarray(ref_net.dependency)).all()
    assert (np.asarray(net.graph) == np.asarray(ref_net.graph)).all()
print("OK", int(ref.l2_counts.sum()))
""")
    assert out.startswith("OK")


def test_distributed_sort_by_case():
    out = run_child(_PRE + """
from repro.distributed.sort import sort_by_case_sharded
perm = np.random.default_rng(0).permutation(frame.nrows)
scrambled = frame.take(jnp.asarray(perm))
mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
case_s, act_s, ts_s, overflow = sort_by_case_sharded(scrambled, mesh)
assert not bool(overflow)
rows = np.asarray(case_s).reshape(8, -1)
for i, row in enumerate(rows):
    real = row[row >= 0]
    assert (np.diff(real) >= 0).all()
    assert (np.unique(real) % 8 == i).all()
# no case lost
total = sum(len(np.unique(r[r >= 0])) for r in rows)
orig = len(np.unique(np.asarray(frame[CASE])[np.asarray(frame.rows_valid())]))
assert total == orig, (total, orig)
print("OK")
""")
    assert out.strip().endswith("OK")


def test_psum_compressed_multidevice():
    out = run_child("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.train import compression

mesh = jax.sharding.Mesh(np.array(jax.devices()), ("pod",))
g = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 100.0

def f(gl):
    errs = compression.init_errors({"g": gl})
    mean, _ = compression.psum_compressed({"g": gl}, errs, "pod")
    return mean["g"]

got = shard_map(f, mesh=mesh, in_specs=(P("pod", None),), out_specs=P("pod", None))(g)
# every shard's result approximates the cross-pod mean
ref = g.mean(axis=0)
err = float(jnp.max(jnp.abs(got - ref[None])))
assert err < 0.05, err
print("OK", err)
""")
    assert out.startswith("OK")


def test_elastic_mesh_shrinks():
    out = run_child("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.train.ft import elastic_mesh
m = elastic_mesh(8, model_parallel=2)
assert dict(m.shape) == {"data": 4, "model": 2}
m = elastic_mesh(7, model_parallel=2)   # lost a device -> 3x2, 1 idle
assert dict(m.shape) == {"data": 3, "model": 2}
print("OK")
""")
    assert out.startswith("OK")


def test_sharded_pruned_query_matches_filter_then_mine():
    """distributed.query: zone-map-pruned scan sharded over 8 devices ==
    eager filter-then-mine, bitwise — ghost rows carry the halo across
    skipped row groups, the psum merge is the kernel's merge."""
    out = run_child("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import numpy as np
from repro.core import CASE, engine, ops
from repro.core.dfg import dfg_kernel
from repro.core.discovery import discovery_kernel
from repro.data import synthetic
from repro.storage import edf
from repro.query import Plan, col
from repro.distributed.query import (query_sharded_dfg_host,
                                     query_sharded_discovery_host)

frame, tables = synthetic.generate(num_cases=3000, num_activities=11, seed=4)
d = tempfile.mkdtemp()
p = os.path.join(d, "q.edf")
edf.write(p, frame, tables, row_group_rows=1111)
plan = Plan(p).filter(col(CASE).between(500, 900))
c = frame[CASE]
ff = ops.proj(frame, (c >= 500) & (c <= 900))
ref = engine.run_single(dfg_kernel(11), ff)
for shards in (1, 2, 4, 8):
    got, rep = query_sharded_dfg_host(plan, 11, shards)
    assert rep.groups_skipped > 0
    for nm in ("counts", "starts", "ends"):
        assert (np.asarray(getattr(got, nm)) == np.asarray(getattr(ref, nm))).all(), (shards, nm)
refd = engine.run_single(discovery_kernel(11), ff)
for shards in (2, 8):
    gotd, repd = query_sharded_discovery_host(plan, 11, shards)
    assert (np.asarray(gotd.l2_counts) == np.asarray(refd.l2_counts)).all()
    for nm in ("counts", "starts", "ends"):
        assert (np.asarray(getattr(gotd.dfg, nm)) == np.asarray(getattr(refd.dfg, nm))).all(), (shards, nm)
print("OK")
""")
    assert out.startswith("OK")
