"""DFG: all three dataframe lowerings vs the classic-log oracle (paper §5.4).

Property-based: any random log, the dense count matrix of every method must
equal the iteration-on-attr-maps baseline (Def. 1 / Table 4 comparison).
"""
import numpy as np
import pytest
from _prop import given, settings, strategies as st

from repro.core import ACTIVITY, CASE, dfg
from repro.core.dfg import dfg_matmul, dfg_segment, dfg_shift_count

from helpers import random_log, sorted_frame


def oracle_matrix(log, tables):
    acts = tables[ACTIVITY]
    a = len(acts)
    ref = log.dfg_iterative()
    m = np.zeros((a, a), np.int32)
    for (x, y), c in ref.items():
        m[acts.index(x), acts.index(y)] = c
    return m


@pytest.mark.parametrize("method", ["shift", "segment", "matmul", "kernel"])
def test_methods_match_oracle(method):
    rng = np.random.default_rng(0)
    log = random_log(rng, n_cases=40, n_acts=7, max_len=12)
    frame, tables = sorted_frame(log)
    d = dfg(frame, len(tables[ACTIVITY]), method=method)
    np.testing.assert_array_equal(np.asarray(d.counts), oracle_matrix(log, tables))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_cases=st.integers(1, 30),
       n_acts=st.integers(1, 8), max_len=st.integers(1, 9))
def test_property_all_methods_agree(seed, n_cases, n_acts, max_len):
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=n_cases, n_acts=n_acts, max_len=max_len)
    frame, tables = sorted_frame(log)
    a = max(len(tables.get(ACTIVITY, [])), 1)
    ref = oracle_matrix(log, tables) if ACTIVITY in tables else None
    d1 = dfg_shift_count(frame, a)
    d2 = dfg_segment(frame, a)
    d3 = dfg_matmul(frame, a)
    np.testing.assert_array_equal(np.asarray(d1.counts), np.asarray(d2.counts))
    np.testing.assert_array_equal(np.asarray(d2.counts), np.asarray(d3.counts))
    if ref is not None:
        np.testing.assert_array_equal(np.asarray(d2.counts), ref)
    np.testing.assert_array_equal(np.asarray(d1.starts), np.asarray(d2.starts))
    np.testing.assert_array_equal(np.asarray(d1.ends), np.asarray(d2.ends))


def test_start_end_activities():
    rng = np.random.default_rng(3)
    log = random_log(rng, n_cases=25, n_acts=5)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    d = dfg_segment(frame, len(acts))
    s_ref, e_ref = log.start_end_activities()
    starts = {acts[i]: int(v) for i, v in enumerate(np.asarray(d.starts)) if v}
    ends = {acts[i]: int(v) for i, v in enumerate(np.asarray(d.ends)) if v}
    assert starts == s_ref
    assert ends == e_ref
    # invariant: starts and ends both sum to #cases
    assert int(d.starts.sum()) == len(log.case_ids)
    assert int(d.ends.sum()) == len(log.case_ids)


def test_counts_sum_invariant():
    """sum(counts) == N - #cases (each case of length L yields L-1 pairs)."""
    rng = np.random.default_rng(7)
    log = random_log(rng, n_cases=30, n_acts=6)
    frame, tables = sorted_frame(log)
    d = dfg_segment(frame, len(tables[ACTIVITY]))
    assert int(d.counts.sum()) == len(log.events) - len(log.case_ids)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 5000), n_vals=st.integers(0, 40))
def test_filter_attr_values_isin_matches_broadcast(seed, n_vals):
    """Regression: the sorted-search isin must produce the exact mask of the
    old (N, V) broadcast — duplicates, absent values, empty sets, keep/drop."""
    import jax.numpy as jnp
    from repro.core import filtering

    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=15, n_acts=6)
    frame, tables = sorted_frame(log)
    # values may repeat, may be out of range (absent), may be empty
    values = rng.integers(-3, len(tables[ACTIVITY]) + 4, size=n_vals)
    col = np.asarray(frame[ACTIVITY])
    ref = np.isin(col, values)
    for keep in (True, False):
        got = filtering.filter_attr_values(frame, ACTIVITY, jnp.asarray(values),
                                           keep=keep)
        np.testing.assert_array_equal(np.asarray(got.rows_valid()),
                                      ref if keep else ~ref,
                                      err_msg=f"seed={seed} keep={keep}")


def test_event_filter_then_dfg():
    """Filtering events and compacting reconnects directly-follows pairs."""
    rng = np.random.default_rng(11)
    log = random_log(rng, n_cases=20, n_acts=5)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    from repro.core import filtering
    drop = acts.index("A")
    filtered = filtering.filter_attr_values(frame, ACTIVITY, [drop], keep=False)
    d = dfg_segment(filtered.compact(), len(acts))
    # oracle: same filter on the classic log
    ref_log = log.filter_events(ACTIVITY, set(a for a in acts if a != "A"))
    m = np.zeros((len(acts), len(acts)), np.int32)
    for (x, y), c in ref_log.dfg_iterative().items():
        m[acts.index(x), acts.index(y)] = c
    np.testing.assert_array_equal(np.asarray(d.counts), m)
