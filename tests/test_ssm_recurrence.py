"""Chunked parallel forms == sequential recurrences (mamba2 / mLSTM / sLSTM)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.config import ModelConfig
from repro.models.module import Initializer
from repro.models import mamba2 as M
from repro.models import xlstm as X


def _cfg(**kw):
    base = dict(name="t", family="hybrid", num_layers=1, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=100,
                ssm_state=16, ssm_chunk=8, compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("S,chunk", [(37, 8), (16, 16), (65, 16), (5, 8)])
def test_mamba2_chunked_equals_recurrent(S, chunk):
    cfg = _cfg(ssm_chunk=chunk)
    p = M.mamba2_init(Initializer(jax.random.PRNGKey(0)), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, S, 64)) * 0.5
    y_chunk, st_chunk = M.mamba2_apply(p, u, cfg, return_state=True)
    st = M.mamba2_init_state(cfg, 2)
    ys = []
    for t in range(S):
        yt, st = M.mamba2_step(p, u[:, t:t + 1], st, cfg)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.concatenate(ys, 1)), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk["h"]), np.asarray(st["h"]),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk["conv"]),
                               np.asarray(st["conv"]), atol=2e-3)


@pytest.mark.parametrize("S,chunk", [(37, 8), (24, 8), (8, 8)])
def test_mlstm_chunked_equals_recurrent(S, chunk):
    cfg = _cfg(family="ssm", d_ff=0, ssm_chunk=chunk)
    p = X.mlstm_init(Initializer(jax.random.PRNGKey(2)), cfg)
    u = jax.random.normal(jax.random.PRNGKey(3), (2, S, 64)) * 0.5
    y_chunk, st_c = X.mlstm_apply(p, u, cfg, return_state=True)
    st = X.mlstm_init_state(cfg, 2)
    ys = []
    for t in range(S):
        yt, st = X.mlstm_step(p, u[:, t:t + 1], st, cfg)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.concatenate(ys, 1)), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_c["h"]), np.asarray(st["h"]),
                               rtol=2e-3, atol=2e-3)


def test_slstm_state_carry():
    cfg = _cfg(family="ssm", d_ff=0)
    p = X.slstm_init(Initializer(jax.random.PRNGKey(4)), cfg)
    u = jax.random.normal(jax.random.PRNGKey(5), (2, 30, 64)) * 0.5
    full, _ = X.slstm_apply(p, u, cfg)
    y1, st = X.slstm_apply(p, u[:, :13], cfg)
    y2, _ = X.slstm_apply(p, u[:, 13:], cfg, st)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([y1, y2], 1)), atol=1e-5)


def test_attention_chunked_equals_ref():
    from repro.models.attention import attention_chunked, attention_ref
    rng = np.random.default_rng(0)
    for (b, s, h, kvh, d, causal, win, chunk) in [
            (2, 96, 8, 2, 32, True, None, 32),
            (1, 128, 4, 4, 64, True, 48, 64),
            (2, 100, 8, 4, 32, True, None, 64)]:
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
        r = attention_ref(q, k, v, causal=causal, window=win)
        c = attention_chunked(q, k, v, causal=causal, window=win, chunk=chunk)
        np.testing.assert_allclose(np.asarray(r), np.asarray(c), atol=1e-5)
