"""EDF columnar container + row baseline + XES interop (paper Tables 1/2)."""
import os

import numpy as np
import pytest

from repro.core import ACTIVITY, CASE, TIMESTAMP
from repro.data import synthetic
from repro.storage import edf, rowlog, xes

from helpers import random_log


@pytest.fixture
def frame_tables():
    return synthetic.generate(num_cases=500, num_activities=12, seed=3)


@pytest.mark.parametrize("codec", ["raw", "zlib1", "zlib6", "zlib9"])
def test_edf_roundtrip(tmp_path, frame_tables, codec):
    frame, tables = frame_tables
    p = str(tmp_path / "log.edf")
    edf.write(p, frame, tables, codec=codec)
    f2, t2 = edf.read(p)
    for k in frame.names:
        np.testing.assert_array_equal(np.asarray(frame[k]), np.asarray(f2[k]))
    assert t2[ACTIVITY] == tables[ACTIVITY]


def test_edf_column_projection(tmp_path, frame_tables):
    frame, tables = frame_tables
    p = str(tmp_path / "log.edf")
    edf.write(p, frame, tables)
    f2, _ = edf.read(p, columns=[CASE, ACTIVITY])
    assert set(f2.names) == {CASE, ACTIVITY}
    np.testing.assert_array_equal(np.asarray(frame[CASE]), np.asarray(f2[CASE]))


def test_edf_compression_monotone(tmp_path, frame_tables):
    """Higher codec level never yields a (meaningfully) larger file — the
    Snappy vs Gzip trade of Table 2."""
    frame, tables = frame_tables
    sizes = {}
    for codec in ("raw", "zlib1", "zlib9"):
        p = str(tmp_path / f"log_{codec}.edf")
        edf.write(p, frame, tables, codec=codec)
        sizes[codec] = os.path.getsize(p)
    assert sizes["zlib1"] < sizes["raw"]
    assert sizes["zlib9"] <= sizes["zlib1"] * 1.02


def test_edf_missing_values(tmp_path):
    rng = np.random.default_rng(0)
    log = random_log(rng, n_cases=6, n_acts=3)
    # knock out some attributes -> epsilon
    for i, e in enumerate(log.events):
        if i % 3 == 0:
            e.pop(TIMESTAMP)
    frame, tables = log.to_eventframe()
    assert TIMESTAMP in frame.valid
    p = str(tmp_path / "eps.edf")
    edf.write(p, frame, tables)
    f2, _ = edf.read(p)
    np.testing.assert_array_equal(np.asarray(frame.valid[TIMESTAMP]),
                                  np.asarray(f2.valid[TIMESTAMP]))


def _empty_frame():
    from repro.core import EventFrame

    return EventFrame.from_numpy(
        {CASE: np.zeros(0, np.int32), ACTIVITY: np.zeros(0, np.int32),
         TIMESTAMP: np.zeros(0, np.float32)},
        {ACTIVITY: np.zeros(0, bool)})


@pytest.mark.parametrize("row_group_rows", [None, 4])
def test_edf_zero_row_roundtrip(tmp_path, row_group_rows):
    """A zero-row frame writes a single empty row group (bounds = [0]) and
    must round-trip through read / read_streaming — schema, dictionary
    tables, dtypes and validity flags intact.  (write used to raise
    'row_group_rows must be positive' with the default group size.)"""
    frame = _empty_frame()
    tables = {ACTIVITY: ["a", "b"]}
    p = str(tmp_path / "empty.edf")
    header = edf.write(p, frame, tables, row_group_rows=row_group_rows)
    assert [g["nrows"] for g in header["groups"]] == [0]
    f2, t2 = edf.read(p)
    assert f2.nrows == 0
    assert set(f2.names) == set(frame.names)
    for k in frame.names:
        assert np.asarray(f2[k]).dtype == np.asarray(frame[k]).dtype, k
    assert ACTIVITY in f2.valid and np.asarray(f2.valid[ACTIVITY]).shape == (0,)
    assert t2[ACTIVITY] == tables[ACTIVITY]
    chunks = list(edf.read_streaming(p))
    assert len(chunks) == 1 and chunks[0][0].nrows == 0
    # the streaming engine just skips the empty group
    from repro.core import ChunkedEventFrame, run_streaming
    from repro.core.dfg import dfg_kernel

    d = run_streaming(dfg_kernel(2), ChunkedEventFrame.from_edf(p))
    assert int(d.counts.sum()) == 0 and int(d.starts.sum()) == 0


def test_edf_empty_trailing_group(tmp_path):
    """A file whose last row group is empty (another producer's layout, or
    zero-byte extents) reads without error and yields the full frame."""
    import json
    import struct

    frame, tables = synthetic.generate(num_cases=20, num_activities=4, seed=1)
    p = str(tmp_path / "trail.edf")
    edf.write(p, frame, tables, row_group_rows=frame.nrows)
    header, base = edf.read_header(p)
    end = os.path.getsize(p) - base
    header["groups"].append({
        "nrows": 0,
        "columns": {c["name"]: {"offset": end, "nbytes": 0, "raw_nbytes": 0}
                    for c in header["columns"]}})
    with open(p, "rb") as f:
        body = f.read()[base:]
    hjson = json.dumps(header).encode()
    with open(p, "wb") as f:
        f.write(edf.MAGIC_V2)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        f.write(body)
    f2, _ = edf.read(p)
    assert f2.nrows == frame.nrows
    for k in frame.names:
        np.testing.assert_array_equal(np.asarray(frame[k]), np.asarray(f2[k]))
    sizes = [fr.nrows for fr, _ in edf.read_streaming(p)]
    assert sizes == [frame.nrows, 0]


def test_rowlog_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    log = random_log(rng, n_cases=8, n_acts=4, extra_attrs=1)
    for compress in (False, True):
        p = str(tmp_path / f"rows{'.gz' if compress else ''}.jsonl")
        rowlog.write(p, log, compress=compress)
        back = rowlog.read(p, compress=compress)
        assert back.events == log.events


def test_xes_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    log = random_log(rng, n_cases=5, n_acts=3)
    p = str(tmp_path / "log.xes")
    xes.write(p, log)
    back = xes.read(p)
    assert len(back.events) == len(log.events)
    got = [(e[CASE], e[ACTIVITY]) for e in back.events]
    want = [(str(e[CASE]), e[ACTIVITY]) for e in log.events]
    assert got == want


def test_xes_timestamps_are_iso8601_with_utc_offset(tmp_path):
    """Timestamps serialize as XES <date> attributes in ISO-8601 with an
    explicit UTC offset (they were raw epoch <float>s), and a known epoch
    round-trips through write -> read exactly."""
    from repro.core import ClassicEventLog

    epoch = 1234567890.5
    log = ClassicEventLog([
        {CASE: "c0", ACTIVITY: "a", TIMESTAMP: epoch},
        {CASE: "c0", ACTIVITY: "b", TIMESTAMP: epoch + 1.25},
    ])
    p = str(tmp_path / "dates.xes")
    xes.write(p, log)
    text = open(p).read()
    assert ('<date key="time:timestamp" '
            'value="2009-02-13T23:31:30.500000+00:00"/>') in text
    assert "<float key=\"time:timestamp\"" not in text
    back = xes.read(p)
    assert [e[TIMESTAMP] for e in back.events] == [epoch, epoch + 1.25]
    # a trailing-Z offset (and naive-UTC) variants parse to the same epoch
    zulu = text.replace("+00:00", "Z")
    pz = str(tmp_path / "zulu.xes")
    open(pz, "w").write(zulu)
    assert [e[TIMESTAMP] for e in xes.read(pz).events] == [epoch,
                                                           epoch + 1.25]


def test_xes_attribute_quoting_roundtrip(tmp_path):
    """Values containing quotes/brackets/ampersands survive write -> read.

    escape() alone left double quotes unescaped inside value="...",
    producing malformed XML; the writer uses quoteattr now.
    """
    from repro.core import ClassicEventLog

    nasty = [
        'He said "hi"',
        "mixed 'single' and \"double\" quotes",
        "<tag> & entity",
        'trailing backslash \\ and "quote',
    ]
    events = [
        {CASE: 'case "zero"', ACTIVITY: act, TIMESTAMP: float(i),
         "note": nasty[(i + 1) % len(nasty)]}
        for i, act in enumerate(nasty)
    ]
    log = ClassicEventLog(events)
    p = str(tmp_path / "quotes.xes")
    xes.write(p, log)           # must be well-formed XML
    back = xes.read(p)          # ET.parse raises on malformed files
    assert [e[ACTIVITY] for e in back.events] == nasty
    assert [e["note"] for e in back.events] == [nasty[(i + 1) % 4]
                                                for i in range(4)]
    assert all(e[CASE] == 'case "zero"' for e in back.events)
