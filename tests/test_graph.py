"""Graph analytics subsystem + model export.

The load-bearing invariants:

* the semiring primitive is bitwise identical across its Pallas and XLA
  lowerings (all three semirings, ragged shapes), and the closures equal
  a host NumPy Floyd–Warshall / BFS exactly;
* every graph verb is engine-invariant — eager == streaming == sharded
  (subprocess, 8 virtual devices) == windowed, bitwise, because the
  heavy state is the one mergeable DFG fold;
* ``merge_tree`` over case-aligned span permutations and arbitrary tree
  shapes reproduces the same DFG adjacency bitwise;
* exports round-trip: PNML places parse back exactly, dfg.json is a
  bitwise DFG round-trip, and an XES re-import re-mines to bitwise
  identical DFG state.
"""
import dataclasses
import json
import os
import subprocess
import sys
import threading
import urllib.error
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from helpers import random_log, sorted_frame
from repro.core import ACTIVITY, CASE, backend, engine, ops
from repro.core.dfg import DFG, dfg_kernel
from repro.core.discovery import discover_alpha, discover_heuristics
from repro.data import synthetic
from repro.graph import (BottleneckPaths, ProcessGraph, alpha_to_pnml,
                         bottleneck_paths, compile_graph, dfg_from_json,
                         dfg_to_json, discover_process_tree, frame_from_xes,
                         graph_to_dot, heuristics_to_dot, pnml_places,
                         reachability, read_pnml)
from repro.kernels.graph_ops import (SEMIRINGS, bool_closure, maxmin_closure,
                                     minplus_closure, semiring_matmul_pallas,
                                     semiring_matmul_ref)
from repro.storage import edf

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
A = 6
NC = 120
GRAPH_VERBS = ("graph", "reachability", "bottleneck_paths", "node_centrality")


@pytest.fixture(scope="module")
def logset(tmp_path_factory):
    """Three v3 files partitioning one sorted synthetic log."""
    frame, tables = synthetic.generate(num_cases=NC, num_activities=A, seed=5)
    d = tmp_path_factory.mktemp("graphds")
    case = np.asarray(frame[CASE])
    bounds = [0] + [int(np.searchsorted(case, c)) for c in (40, 80)] \
        + [frame.nrows]
    paths = []
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        p = str(d / f"part{i}.edf")
        edf.write(p, frame.take(jnp.arange(lo, hi)), tables,
                  version=3, row_group_rows=64)
        paths.append(p)
    return paths, frame, tables


def _eq(a, b) -> bool:
    """Bitwise structural equality over the query-result dataclasses."""
    if dataclasses.is_dataclass(a):
        return type(a) is type(b) and all(
            _eq(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    if a is None or b is None:
        return a is b
    return np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- semiring primitive
def _tropical_oracle(a, b, semiring):
    if semiring == "min_plus":
        return np.min(a[:, :, None] + b[None, :, :], axis=1)
    return np.max(np.minimum(a[:, :, None], b[None, :, :]), axis=1)


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("shape", [(4, 4, 4), (17, 9, 23), (130, 7, 131)])
def test_semiring_matmul_pallas_equals_ref_bitwise(semiring, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash((semiring, shape)) % 2**31)
    a = rng.integers(0, 50, (m, k)).astype(np.float32)
    b = rng.integers(0, 50, (k, n)).astype(np.float32)
    if semiring == "min_plus":        # +inf marks absent edges
        a[rng.random((m, k)) < 0.4] = np.inf
        b[rng.random((k, n)) < 0.4] = np.inf
    if semiring == "max_min":
        a[rng.random((m, k)) < 0.4] = -np.inf
        b[rng.random((k, n)) < 0.4] = -np.inf
    got_p = np.asarray(semiring_matmul_pallas(jnp.asarray(a), jnp.asarray(b),
                                              semiring, interpret=True))
    got_r = np.asarray(semiring_matmul_ref(jnp.asarray(a), jnp.asarray(b),
                                           semiring))
    assert np.array_equal(got_p, got_r), semiring
    if semiring == "plus_times":
        oracle = a @ b
    else:
        oracle = _tropical_oracle(a, b, semiring)
    assert np.array_equal(got_p, oracle.astype(np.float32))


def test_closures_match_host_oracles_under_both_backends():
    rng = np.random.default_rng(17)
    n = 11
    w = rng.integers(1, 9, (n, n)).astype(np.float32)
    w[rng.random((n, n)) < 0.6] = np.inf          # sparse edges
    adj = np.isfinite(w)

    # Floyd–Warshall oracles (min-plus and max-min)
    dist = np.where(np.eye(n, dtype=bool), 0.0, w)
    cap = np.where(adj, w, -np.inf)
    wide = np.where(np.eye(n, dtype=bool), np.inf, cap)
    for mid in range(n):
        dist = np.minimum(dist, dist[:, mid:mid + 1] + dist[mid:mid + 1, :])
        wide = np.maximum(wide, np.minimum(wide[:, mid:mid + 1],
                                           wide[mid:mid + 1, :]))
    # BFS reachability horizons
    reach_k = [np.eye(n, dtype=bool)]
    while len(reach_k) <= n:
        reach_k.append(reach_k[-1] | (reach_k[-1].astype(np.float32)
                                      @ adj.astype(np.float32) > 0))
    outs = {}
    for impl in ("pallas", "xla"):
        with backend.use_backend(impl):
            d = np.asarray(minplus_closure(jnp.asarray(
                np.where(adj, w, np.inf))))
            c = np.asarray(maxmin_closure(jnp.asarray(cap)))
            ks = {k: np.asarray(bool_closure(jnp.asarray(adj), k))
                  for k in (0, 1, 2, 3, None)}
        assert np.array_equal(d, dist.astype(np.float32)), impl
        assert np.array_equal(c, wide.astype(np.float32)), impl
        for k, got in ks.items():
            want = reach_k[-1] if k is None else reach_k[k]
            assert np.array_equal(got, want), (impl, k)
        outs[impl] = (d, c, ks)
    # bitwise across lowerings
    assert np.array_equal(outs["pallas"][0], outs["xla"][0])
    assert np.array_equal(outs["pallas"][1], outs["xla"][1])
    for k in outs["pallas"][2]:
        assert np.array_equal(outs["pallas"][2][k], outs["xla"][2][k])


# -------------------------------------------------------------- the IR
def test_compile_graph_embeds_state_exactly(logset):
    _, frame, tables = logset
    ds = repro.open(frame, tables=tables)
    d = ds.dfg()
    g = compile_graph(d)
    a = d.num_activities
    assert g.num_nodes == a + 2 and g.source == a and g.sink == a + 1
    f = np.asarray(g.freq)
    assert np.array_equal(f[:a, :a], np.asarray(d.counts))
    assert np.array_equal(f[a, :a], np.asarray(d.starts))
    assert np.array_equal(f[:a, a + 1], np.asarray(d.ends))
    assert f[a + 1].sum() == 0 and f[:, a].sum() == 0
    lab = ds.graph().node_labels()
    assert lab[-2:] == ("▶", "■")
    assert set(lab[:a]) == set(tables[ACTIVITY])
    with pytest.raises(TypeError):
        compile_graph(object())
    with pytest.raises(ValueError):
        g.with_labels(("x",))


# ------------------------------------------------------- engine parity
def test_graph_verbs_engine_parity_and_pruning(logset):
    paths, _, _ = logset
    ds = repro.open(paths).filter(
        (repro.col(CASE) >= 20) & (repro.col(CASE) <= 95))
    for verb in GRAPH_VERBS:
        ref = ds.collect(verb, engine="eager")
        got = ds.collect(verb, engine="streaming")
        assert _eq(got.result, ref.result), verb
        assert got.report.groups_skipped > 0, verb
    # the timed overlay: f32 waits accumulate in row order on both paths
    gt_e = ds.collect("graph", engine="eager", timed=True).result
    gt_s = ds.collect("graph", engine="streaming", timed=True).result
    assert _eq(gt_e, gt_s)
    assert gt_e.perf is not None and float(np.asarray(gt_e.perf).sum()) > 0
    bp = ds.collect("bottleneck_paths", engine="streaming",
                    weights="performance").result
    assert _eq(bp, ds.collect("bottleneck_paths", engine="eager",
                              weights="performance").result)
    assert bp.weights == "performance"


def test_graph_query_results_are_consistent(logset):
    paths, _, _ = logset
    ds = repro.open(paths)
    g = ds.graph()
    r_full = ds.reachability()
    # full closure reaches the sink from the source
    assert bool(np.asarray(r_full.mask)[g.source, g.sink])
    r1 = ds.reachability(1)
    assert np.array_equal(
        np.asarray(r1.mask),
        np.asarray(np.eye(g.num_nodes, dtype=bool) | np.asarray(g.adjacency)))
    bp = ds.bottlenecks()
    assert bp.path[0] == g.source and bp.path[-1] == g.sink
    f = np.asarray(g.freq)
    caps = [f[a, b] for a, b in zip(bp.path[:-1], bp.path[1:])]
    assert min(caps) == bp.bottleneck > 0
    c = ds.centrality()
    assert np.array_equal(np.asarray(c.in_degree), np.asarray(f.sum(0)))
    assert np.array_equal(np.asarray(c.out_degree), np.asarray(f.sum(1)))
    assert abs(float(np.asarray(c.flow).sum()) - 1.0) < 1e-5


def test_graph_sharded_parity_subprocess(logset):
    """sharded == eager for every graph verb at 2 and 8 shards; the timed
    overlay refuses the distributed lowering."""
    paths, _, _ = logset
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import repro
from repro.core.eventframe import CASE
from repro.query import col

def eq(a, b):
    if dataclasses.is_dataclass(a):
        return type(a) is type(b) and all(
            eq(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(eq(x, y) for x, y in zip(a, b))
    if a is None or b is None:
        return a is b
    return np.array_equal(np.asarray(a), np.asarray(b))

paths = {paths!r}
ds = repro.open(paths).filter((col(CASE) >= 15) & (col(CASE) <= 100))
for verb in {GRAPH_VERBS!r}:
    ref = ds.collect(verb, engine="eager").result
    for shards in (2, 8):
        got = ds.collect(verb, engine="sharded", num_shards=shards)
        assert got.engine == "sharded", (verb, shards)
        assert eq(got.result, ref), (verb, shards)
try:
    ds.collect("graph", engine="sharded", num_shards=2, timed=True)
    raise SystemExit("timed=True must refuse the sharded engine")
except ValueError as e:
    assert "no exact distributed lowering" in str(e)
try:
    ds.collect("bottleneck_paths", engine="sharded", num_shards=2,
               weights="performance")
    raise SystemExit("performance weights must refuse the sharded engine")
except ValueError as e:
    assert "no exact distributed lowering" in str(e)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    assert res.stdout.strip().endswith("OK")


def test_graph_verbs_under_both_segment_backends(logset, tmp_path):
    """REPRO_SEGMENT_BACKEND={pallas,xla} subprocesses produce bitwise
    identical reachability masks and graph frequencies."""
    paths, _, _ = logset
    outs = {}
    for be in ("pallas", "xla"):
        out_npz = str(tmp_path / f"graph_{be}.npz")
        code = f"""
import numpy as np
import repro
ds = repro.open({paths!r})
g = ds.collect("graph").result
r = ds.collect("reachability", k=3).result
np.savez({out_npz!r}, freq=np.asarray(g.freq), mask=np.asarray(r.mask))
print("OK")
"""
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        env["REPRO_SEGMENT_BACKEND"] = be
        res = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=560)
        assert res.returncode == 0, res.stderr[-3000:]
        assert res.stdout.strip().endswith("OK")
        outs[be] = dict(np.load(out_npz))
    assert np.array_equal(outs["pallas"]["freq"], outs["xla"]["freq"])
    assert np.array_equal(outs["pallas"]["mask"], outs["xla"]["mask"])


def test_windowed_graph_equals_compiled_windowed_dfg(logset):
    paths, _, _ = logset
    ds = repro.open(paths)
    w = ds.window(by="groups", size=3, step=3)
    graphs = w.collect("graph")
    dfgs = w.collect("dfg")
    assert len(graphs.results) == len(dfgs.results) > 1
    for g, d in zip(graphs.results, dfgs.results):
        assert _eq(g, compile_graph(d))


# ------------------------------------------- merge-permutation property
def test_merge_tree_span_permutations_identical_dfg(logset):
    """Case-aligned spans hold whole cases, so any span order (and any
    merge-tree shape) must reproduce the same DFG state bitwise."""
    _, frame, _ = logset
    a = int(np.asarray(frame[ACTIVITY]).max()) + 1
    kernel = dfg_kernel(a)
    case = np.asarray(frame[CASE])
    bounds = [0] + list(np.flatnonzero(case[1:] != case[:-1]) + 1) \
        + [frame.nrows]
    cuts = bounds[::7] + ([frame.nrows] if bounds[::7][-1] != frame.nrows
                          else [])
    spans = [frame.take(jnp.arange(lo, hi))
             for lo, hi in zip(cuts[:-1], cuts[1:])]
    groups = [engine.fold_group(kernel, [s]) for s in spans]
    ref = engine.finalize_group(kernel, engine.merge_tree(kernel, groups))
    # left fold == balanced tree (ordered)
    acc = groups[0]
    for g in groups[1:]:
        acc = engine.merge_group_states(kernel, acc, g)
    assert _eq(engine.finalize_group(kernel, acc), ref)
    # arbitrary permutations (spans are case-aligned: no straddle)
    rng = np.random.default_rng(23)
    for _ in range(4):
        perm = rng.permutation(len(groups))
        got = engine.finalize_group(
            kernel, engine.merge_tree(kernel, [groups[i] for i in perm]))
        assert _eq(got, ref), perm
        assert _eq(compile_graph(got), compile_graph(ref))


# ------------------------------------------------------ registry errors
def test_unknown_verb_raises_listing_and_suggesting():
    with pytest.raises(KeyError) as ei:
        engine.kernel_spec("reachabillity")
    msg = str(ei.value)
    assert "did you mean" in msg and "'reachability'" in msg
    assert "registered:" in msg and "'dfg'" in msg
    frame, tables = sorted_frame(random_log(np.random.default_rng(1),
                                            n_cases=4))
    with pytest.raises(KeyError) as ei2:
        repro.open(frame, tables=tables).collect("nosuch")
    assert "registered:" in str(ei2.value)


# ------------------------------------------------------------- exports
def _structured_log():
    return make_log([
        ("c1", ["a", "b", "d"]),
        ("c2", ["a", "c", "d"]),
        ("c3", ["a", "b", "d"]),
    ])


def make_log(cases):
    from repro.core import make_classic_log

    t = [0.0]

    def trace(acts):
        out = []
        for x in acts:
            t[0] += 1.0
            out.append((x, t[0]))
        return out

    return make_classic_log([(cid, trace(acts)) for cid, acts in cases])


def test_pnml_roundtrip_structural():
    frame, tables = sorted_frame(_structured_log())
    ds = repro.open(frame, tables=tables)
    model = ds.alpha()
    xml = alpha_to_pnml(model, labels=tables[ACTIVITY])
    places, transitions, arcs = read_pnml(xml)
    assert places["source"] == 1 and places["sink"] == 0
    assert len(places) == len(model.places) + 2
    assert sorted(transitions.values()) == sorted(tables[ACTIVITY])
    pairs, starts, ends = pnml_places(xml)
    assert pairs == model.places
    assert starts == model.start_activities
    assert ends == model.end_activities
    assert len(model.places) > 0


def test_dot_exports_are_wellformed():
    frame, tables = sorted_frame(_structured_log())
    ds = repro.open(frame, tables=tables)
    dot = heuristics_to_dot(ds.heuristics(), labels=tables[ACTIVITY])
    assert dot.startswith("digraph") and "__start ->" in dot \
        and "-> __end" in dot
    gdot = graph_to_dot(ds.graph())
    assert gdot.startswith("digraph")
    for lab in tables[ACTIVITY]:
        assert lab in gdot


def test_process_tree_notation():
    # pure sequence
    f, t = sorted_frame(make_log([("c1", ["a", "b", "c"]),
                                  ("c2", ["a", "b", "c"])]))
    assert discover_process_tree(repro.open(f, tables=t).dfg(),
                                 labels=t[ACTIVITY]) == "->( 'a', 'b', 'c' )"
    # choice inside a sequence
    f2, t2 = sorted_frame(_structured_log())
    tree = discover_process_tree(repro.open(f2, tables=t2).dfg(),
                                 labels=t2[ACTIVITY])
    assert tree.startswith("->(") and "X(" in tree
    # self-loop leaf
    f3, t3 = sorted_frame(make_log([("c1", ["a", "a", "b"])]))
    tree3 = discover_process_tree(repro.open(f3, tables=t3).dfg(),
                                  labels=t3[ACTIVITY])
    assert "*( 'a', tau )" in tree3
    # a ProcessGraph source works too and empty state is tau
    ds2 = repro.open(f2, tables=t2)
    assert discover_process_tree(ds2.graph()) == tree
    empty = DFG(jnp.zeros((3, 3), jnp.int32), jnp.zeros((3,), jnp.int32),
                jnp.zeros((3,), jnp.int32))
    assert discover_process_tree(empty) == "tau"


def test_dfg_json_roundtrip_bitwise(logset):
    _, frame, tables = logset
    ds = repro.open(frame, tables=tables)
    d = ds.dfg()
    text = dfg_to_json(d, labels=tables[ACTIVITY])
    doc = json.loads(text)
    assert set(doc) == {"activities", "dfg", "start_activities",
                        "end_activities"}
    d2, lab2 = dfg_from_json(text)
    assert lab2 == list(tables[ACTIVITY])
    for f in ("counts", "starts", "ends"):
        assert np.array_equal(np.asarray(getattr(d, f)),
                              np.asarray(getattr(d2, f))), f


def test_xes_export_reimport_remine_bitwise(tmp_path, logset):
    """write XES -> read it back -> re-mine: the DFG state (and therefore
    the compiled graph) is bitwise identical."""
    _, frame, tables = logset
    ds = repro.open(frame, tables=tables)
    p = str(tmp_path / "export.xes")
    ds.to_xes(p)
    frame2, tables2 = frame_from_xes(p)
    from repro.core import TIMESTAMP, EventFrame
    # XES carries labels, not codes: re-import dictionary-encodes in
    # first-occurrence order, so realign activity ids to the original
    # dictionary before comparing state bit for bit.
    perm = np.array([tables[ACTIVITY].index(lbl)
                     for lbl in tables2[ACTIVITY]], np.int32)
    cols = {k: np.asarray(frame2[k]) for k in frame2.names}
    cols[ACTIVITY] = perm[cols[ACTIVITY]]
    frame2 = EventFrame.from_numpy(
        cols, {k: np.asarray(v) for k, v in frame2.valid.items()})
    frame2 = ops.sort(frame2, (TIMESTAMP, CASE))
    ds2 = repro.open(frame2, tables={**tables2, ACTIVITY: tables[ACTIVITY]})
    d1, d2 = ds.dfg(), ds2.dfg()
    for f in ("counts", "starts", "ends"):
        assert np.array_equal(np.asarray(getattr(d1, f)),
                              np.asarray(getattr(d2, f))), f
    assert _eq(ds.collect("graph").result, ds2.collect("graph").result)


# ------------------------------------------------------------- service
def test_http_graph_endpoint(tmp_path):
    from repro.service import serve

    rng = np.random.default_rng(31)
    frame, tables = sorted_frame(random_log(rng, n_cases=16, n_acts=4))
    pdir = str(tmp_path / "parts")
    os.makedirs(pdir)
    edf.write(os.path.join(pdir, "part_00000.edf"), frame, tables,
              version=3, row_group_rows=16)
    httpd = serve(pdir, port=0, case_capacity=32)
    port = httpd.server_address[1]
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    try:
        def get(path):
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}", timeout=30) as r:
                return json.loads(r.read())

        out = get("/graph?query=bottleneck_paths")
        assert out["ok"]
        g = out["graph"]
        ref = repro.open(frame, tables=tables,
                         num_cases=out["snapshot"]["num_cases"]).graph()
        assert np.array_equal(np.asarray(g["freq"]), np.asarray(ref.freq))
        assert g["labels"] == list(ref.node_labels())
        assert g["source"] == ref.source and g["sink"] == ref.sink
        q = out["query"]
        assert q["_type"] == "BottleneckPaths" and q["bottleneck"] > 0
        plain = get("/graph")
        assert plain["ok"] and "query" not in plain
        with pytest.raises(urllib.error.HTTPError) as e400:
            get("/graph?query=nosuch")
        assert e400.value.code == 400
    finally:
        httpd.shutdown()
