"""DFG-footprint conformance: deviations, discovery thresholds, edge cases."""
import numpy as np
import jax.numpy as jnp

from repro.core import ACTIVITY, conformance
from repro.core.dfg import DFG, dfg_segment

from helpers import random_log, sorted_frame


def _dfg_from_counts(counts):
    c = jnp.asarray(np.asarray(counts, np.int32))
    a = c.shape[0]
    return DFG(c, jnp.zeros((a,), jnp.int32), jnp.zeros((a,), jnp.int32))


def test_footprint_deviations_contents():
    counts = [[0, 5, 0], [2, 0, 3], [0, 0, 7]]
    allowed = jnp.asarray([[False, True, False],
                           [False, False, True],
                           [False, False, False]])
    dev = np.asarray(conformance.footprint_deviations(
        _dfg_from_counts(counts), allowed))
    # disallowed cells keep their observed counts, allowed cells are zeroed
    np.testing.assert_array_equal(dev, [[0, 0, 0], [2, 0, 0], [0, 0, 7]])
    # fitness is the allowed fraction: (5 + 3) / 17
    fit = float(conformance.footprint_fitness(_dfg_from_counts(counts), allowed))
    np.testing.assert_allclose(fit, 8 / 17, rtol=1e-6)


def test_footprint_fitness_bounds():
    counts = [[1, 2], [3, 4]]
    all_ok = jnp.ones((2, 2), bool)
    none_ok = jnp.zeros((2, 2), bool)
    assert float(conformance.footprint_fitness(_dfg_from_counts(counts), all_ok)) == 1.0
    assert float(conformance.footprint_fitness(_dfg_from_counts(counts), none_ok)) == 0.0


def test_discover_model_noise_thresholds():
    # row 0: max outgoing 10; row 1: max outgoing 4
    counts = [[10, 1, 0], [0, 4, 2], [0, 0, 0]]
    d = _dfg_from_counts(counts)
    m0 = np.asarray(conformance.discover_model(d, noise_threshold=0.0))
    np.testing.assert_array_equal(m0, np.asarray(counts) > 0)
    m05 = np.asarray(conformance.discover_model(d, noise_threshold=0.5))
    # keeps edges with count > 0.5 * row max: 10 (>5), 4 (>2), drops 1, 2
    np.testing.assert_array_equal(
        m05, [[True, False, False], [False, True, False], [False, False, False]])
    # threshold 1.0 drops everything (count > row_max is impossible)
    m1 = np.asarray(conformance.discover_model(d, noise_threshold=1.0))
    assert not m1.any()


def test_discover_model_zero_count_rows():
    """All-zero rows use the max(row_max, 1) guard: no NaN, no edges kept."""
    d = _dfg_from_counts(np.zeros((4, 4), np.int32))
    m = np.asarray(conformance.discover_model(d, noise_threshold=0.2))
    assert m.shape == (4, 4) and not m.any()


def test_footprint_zero_count_log():
    """Empty observation is vacuously conformant: an empty (or fully
    filtered) log deviates from nothing, so fitness is 1.0 — not the 0.0
    the old ``max(tot, 1)`` guard produced — regardless of the model."""
    d = _dfg_from_counts(np.zeros((3, 3), np.int32))
    for allowed in (jnp.ones((3, 3), bool), jnp.zeros((3, 3), bool)):
        fit = float(conformance.footprint_fitness(d, allowed))
        assert fit == 1.0 and not np.isnan(fit)
    dev = np.asarray(conformance.footprint_deviations(d, jnp.zeros((3, 3), bool)))
    assert not dev.any()


def test_fully_filtered_log_is_vacuously_conformant():
    """The end-to-end shape of the bug: filter away every event, mine the
    empty rest, replay — the score must be 1.0, not total deviation."""
    from repro.core import filtering

    rng = np.random.default_rng(8)
    log = random_log(rng, n_cases=10, n_acts=4, max_len=6)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    model = conformance.discover_model(dfg_segment(frame, a))
    empty = filtering.filter_attr_values(frame, ACTIVITY, [], keep=True)
    assert int(empty.rows_valid().sum()) == 0
    fit = float(conformance.footprint_fitness(dfg_segment(empty, a), model))
    assert fit == 1.0


def test_alpha_replay_detects_deviation():
    """A log with an extra unseen transition scores < 1 against the model
    discovered from the clean log; the clean log scores exactly 1."""
    from repro.core import discovery

    from test_discovery import _log_from_traces

    clean = _log_from_traces([list("abcd")] * 4 + [list("acbd")] * 4)
    frame, tables = sorted_frame(clean)
    acts = tables[ACTIVITY]
    a = len(acts)
    model = discovery.alpha(frame, a)
    d = dfg_segment(frame, a)
    assert float(conformance.alpha_fitness(d, model)) == 1.0
    assert float(conformance.footprint_conformance(d, model)) == 1.0
    assert not np.asarray(conformance.footprint_disagreements(d, model)).any()
    # deviant log: d -> a jumps backwards (never observed in the clean log)
    deviant = _log_from_traces([list("abcd")] * 4 + [list("abcdad")] * 2)
    dframe, dtables = sorted_frame(deviant)
    assert dtables[ACTIVITY] == acts  # same alphabet/encoding
    dd = dfg_segment(dframe, a)
    assert float(conformance.alpha_fitness(dd, model)) < 1.0
    assert float(conformance.footprint_conformance(dd, model)) < 1.0
    assert np.asarray(conformance.footprint_disagreements(dd, model)).any()


def test_heuristics_replay_fitness_bounds():
    from repro.core import discovery

    rng = np.random.default_rng(21)
    log = random_log(rng, n_cases=25, n_acts=5, max_len=8)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    state = discovery.discovery_state(frame, a)
    # threshold -1 keeps every observed edge -> perfect replay of own log
    permissive = discovery.discover_heuristics(state, dependency_threshold=-1.0)
    assert float(conformance.heuristics_fitness(state.dfg, permissive)) == 1.0
    # default thresholds keep a subset -> fitness in (0, 1]
    net = discovery.discover_heuristics(state)
    fit = float(conformance.heuristics_fitness(state.dfg, net))
    assert 0.0 <= fit <= 1.0


def test_discovered_model_is_self_conformant():
    """A model discovered from a log at threshold 0 allows every observed
    pair of that log — fitness 1.0 by construction."""
    rng = np.random.default_rng(5)
    log = random_log(rng, n_cases=20, n_acts=5, max_len=8)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    d = dfg_segment(frame, a)
    model = conformance.discover_model(d, noise_threshold=0.0)
    assert float(conformance.footprint_fitness(d, model)) == 1.0
    assert not np.asarray(conformance.footprint_deviations(d, model)).any()
    # and aggressive cleaning strictly reduces allowed mass on noisy logs
    tight = conformance.discover_model(d, noise_threshold=0.9)
    assert (float(conformance.footprint_fitness(d, tight))
            <= float(conformance.footprint_fitness(d, model)))