"""Chunked out-of-core engine: chunk-invariance properties + EDF v2.

The load-bearing invariant: ANY chunking of a (case,time)-sorted log —
including chunks of one row and cases split across many chunks — yields
results bitwise-identical to the whole-log jitted path, because the carries
stitch every boundary. Plus EDFV0002 round-trip/back-compat and the
disk -> device streaming path.
"""
import os

import numpy as np
import pytest
from _prop import given, settings, strategies as st

from repro.core import (ACTIVITY, CASE, TIMESTAMP, ChunkedEventFrame,
                        EventFrame, dfg, engine, filtering, run_streaming,
                        stats, variants)
from repro.core.dfg import dfg_kernel, dfg_segment
from repro.core.performance import (eventually_follows,
                                    eventually_follows_kernel,
                                    performance_dfg, performance_dfg_kernel)
from repro.data import synthetic
from repro.storage import edf

from helpers import random_log, sorted_frame


def _random_cuts(rng, n, k):
    return sorted(int(c) for c in rng.integers(1, max(n, 2), size=k))


def _assert_dfg_equal(a, b, msg=""):
    for nm in ("counts", "starts", "ends"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, nm)), np.asarray(getattr(b, nm)), err_msg=f"{msg}:{nm}")


# ------------------------------------------------------- chunk invariance
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 5000), n_chunks=st.integers(1, 12))
def test_dfg_chunk_invariance(seed, n_chunks):
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=25, n_acts=6, max_len=9)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    ref = dfg_segment(frame, a)
    src = ChunkedEventFrame.from_cuts(frame, _random_cuts(rng, frame.nrows, n_chunks))
    _assert_dfg_equal(run_streaming(dfg_kernel(a), src), ref, f"seed={seed}")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 5000))
def test_stats_variants_chunk_invariance(seed):
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=20, n_acts=5, max_len=8)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    c = len(log.case_ids)
    src = ChunkedEventFrame.from_cuts(frame, _random_cuts(rng, frame.nrows, 7))
    np.testing.assert_array_equal(
        np.asarray(run_streaming(stats.case_sizes_kernel(c), src)),
        np.asarray(stats.case_sizes(frame, c)))
    np.testing.assert_array_equal(
        np.asarray(run_streaming(stats.case_durations_kernel(c), src)),
        np.asarray(stats.case_durations(frame, c)))
    np.testing.assert_array_equal(
        np.asarray(run_streaming(stats.activity_counts_kernel(a), src)),
        np.asarray(stats.activity_counts(frame, a)))
    np.testing.assert_array_equal(
        np.asarray(run_streaming(stats.sojourn_times_kernel(a), src)),
        np.asarray(stats.sojourn_times(frame, a)))
    assert variants.streaming_variant_counts(src, c) == variants.variant_counts(frame)
    pc, pm = run_streaming(performance_dfg_kernel(a), src)
    rc, rm = performance_dfg(frame, a)
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(pm), np.asarray(rm))
    np.testing.assert_array_equal(
        np.asarray(run_streaming(eventually_follows_kernel(a), src)),
        np.asarray(eventually_follows(frame, a)))


def test_case_split_across_three_plus_chunks():
    """One case of 11 events cut into 2-row chunks: 6 chunks, one case."""
    n = 11
    frame = EventFrame.from_numpy({
        CASE: np.zeros(n, np.int32),
        ACTIVITY: (np.arange(n) % 3).astype(np.int32),
        TIMESTAMP: np.arange(n, dtype=np.float32),
    })
    src = ChunkedEventFrame.from_frame(frame, 2)
    assert len(src) == 6
    ref = dfg_segment(frame, 3)
    _assert_dfg_equal(run_streaming(dfg_kernel(3), src), ref)
    assert int(ref.counts.sum()) == n - 1
    np.testing.assert_array_equal(
        np.asarray(run_streaming(stats.case_sizes_kernel(1), src)), [n])
    assert variants.streaming_variant_counts(src, 1) == variants.variant_counts(frame)


def test_single_row_chunks_and_all_methods():
    frame, tables = synthetic.generate(num_cases=30, num_activities=5, seed=11)
    src = ChunkedEventFrame.from_frame(frame, 1)
    for method in ("segment", "matmul"):
        _assert_dfg_equal(run_streaming(dfg_kernel(5, method), src),
                          dfg(frame, 5, method=method), method)


def test_streaming_case_filters_match_whole_log():
    frame, tables = synthetic.generate(num_cases=50, num_activities=6, seed=3)
    c = 50
    src = ChunkedEventFrame.from_frame(frame, 37)
    keep = filtering.streaming_cases_containing(src, 2, c)
    wl = filtering.filter_cases_containing(frame, 2, c)
    got = np.concatenate([np.asarray(ch.rows_valid())
                          for ch in filtering.stream_apply_case_mask(src, keep)])
    np.testing.assert_array_equal(got, np.asarray(wl.rows_valid()))


def test_compose_single_pass():
    frame, tables = synthetic.generate(num_cases=40, num_activities=7, seed=5)
    src = ChunkedEventFrame.from_frame(frame, 29)
    out = run_streaming(engine.compose({
        "dfg": dfg_kernel(7), "acts": stats.activity_counts_kernel(7)}), src)
    _assert_dfg_equal(out["dfg"], dfg_segment(frame, 7))
    np.testing.assert_array_equal(np.asarray(out["acts"]),
                                  np.asarray(stats.activity_counts(frame, 7)))


def test_merge_combines_disjoint_case_partitions():
    """merge() fuses states of partitions that do not split a case —
    the host-side analogue of the distributed psum."""
    f1, _ = synthetic.generate(num_cases=20, num_activities=5, seed=1)
    f2raw, _ = synthetic.generate(num_cases=20, num_activities=5, seed=2)
    shifted = {k: (np.asarray(v) + (20 if k == CASE else 0))
               for k, v in f2raw.columns.items()}
    f2 = EventFrame.from_numpy(shifted)
    whole = EventFrame.from_numpy(
        {k: np.concatenate([np.asarray(f1[k]), np.asarray(f2[k])])
         for k in f1.names})
    k = dfg_kernel(5)

    def part_state(fr):
        s, c = k.init()
        s, c = k.update(s, c, fr)
        return k.finalize(s, c)

    merged = k.merge(part_state(f1), part_state(f2))
    _assert_dfg_equal(merged, dfg_segment(whole, 5))


# ------------------------------------------------------------------- EDF
@pytest.fixture
def frame_tables():
    return synthetic.generate(num_cases=400, num_activities=9, seed=13)


def test_edf_v2_roundtrip_and_groups(tmp_path, frame_tables):
    frame, tables = frame_tables
    p = str(tmp_path / "v2.edf")
    edf.write(p, frame, tables, row_group_rows=257)
    assert edf.num_row_groups(p) >= 8
    f2, t2 = edf.read(p)
    for kk in frame.names:
        np.testing.assert_array_equal(np.asarray(frame[kk]), np.asarray(f2[kk]))
    assert t2[ACTIVITY] == tables[ACTIVITY]
    # per-group column projection
    g0, _ = edf.read_group(p, 0, columns=[CASE])
    assert set(g0.names) == {CASE}
    np.testing.assert_array_equal(np.asarray(g0[CASE]),
                                  np.asarray(frame[CASE])[:257])
    # group sizes tile the file
    sizes = [f.nrows for f, _ in edf.read_streaming(p)]
    assert sum(sizes) == frame.nrows
    assert all(s == 257 for s in sizes[:-1])


def test_edf_v1_back_compat(tmp_path, frame_tables):
    """v1 files written by the old layout stay readable (and streamable)."""
    frame, tables = frame_tables
    p = str(tmp_path / "v1.edf")
    header = edf.write(p, frame, tables, version=1)
    assert header.get("version", 1) == 1
    with open(p, "rb") as f:
        assert f.read(8) == edf.MAGIC
    f2, t2 = edf.read(p)
    for kk in frame.names:
        np.testing.assert_array_equal(np.asarray(frame[kk]), np.asarray(f2[kk]))
    assert t2[ACTIVITY] == tables[ACTIVITY]
    assert edf.num_row_groups(p) == 1
    chunks = list(edf.read_streaming(p))
    assert len(chunks) == 1 and chunks[0][0].nrows == frame.nrows
    src = ChunkedEventFrame.from_edf(p)
    _assert_dfg_equal(run_streaming(dfg_kernel(9), src), dfg_segment(frame, 9))


def test_edf_v2_missing_values_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    log = random_log(rng, n_cases=9, n_acts=3)
    for i, e in enumerate(log.events):
        if i % 3 == 0:
            e.pop(TIMESTAMP)
    frame, tables = log.to_eventframe()
    p = str(tmp_path / "eps2.edf")
    edf.write(p, frame, tables, row_group_rows=7)
    f2, _ = edf.read(p)
    np.testing.assert_array_equal(np.asarray(frame.valid[TIMESTAMP]),
                                  np.asarray(f2.valid[TIMESTAMP]))


def test_stream_from_edf_matches_whole_log(tmp_path, frame_tables):
    frame, tables = frame_tables
    p = str(tmp_path / "s.edf")
    edf.write(p, frame, tables, row_group_rows=193)
    src = ChunkedEventFrame.from_edf(p, columns=[CASE, ACTIVITY, TIMESTAMP])
    assert len(src) >= 8
    _assert_dfg_equal(run_streaming(dfg_kernel(9), src), dfg_segment(frame, 9))
    assert src.tables[ACTIVITY] == tables[ACTIVITY]
    # re-iterable: a second pass sees the same chunks
    assert sum(c.nrows for c in src) == frame.nrows


def test_from_synthetic_is_sorted_and_chunked():
    src = ChunkedEventFrame.from_synthetic(num_cases=100, cases_per_chunk=13,
                                           num_activities=6, seed=2)
    assert len(src) == 8
    whole = src.materialize()
    case = np.asarray(whole[CASE])
    assert (np.diff(case) >= 0).all()
    assert len(np.unique(case)) == 100
    _assert_dfg_equal(run_streaming(dfg_kernel(6), src), dfg_segment(whole, 6))
