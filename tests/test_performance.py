"""Performance DFG / eventually-follows / remaining-time (timed relations)."""
import numpy as np
from _prop import given, settings, strategies as st

from repro.core import ACTIVITY, CASE, TIMESTAMP
from repro.core.performance import (eventually_follows, performance_dfg,
                                    remaining_time_targets)

from helpers import random_log, sorted_frame


def _efg_oracle(log, acts):
    a = len(acts)
    m = np.zeros((a, a), np.int64)
    for cid, idxs in log.case_ev().items():
        seq = [acts.index(log.act(i)) for i in idxs]
        for i in range(len(seq)):
            for j in range(i + 1, len(seq)):
                m[seq[i], seq[j]] += 1
    return m


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500))
def test_eventually_follows_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=12, n_acts=5, max_len=8)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    got = np.asarray(eventually_follows(frame, len(acts)))
    np.testing.assert_array_equal(got, _efg_oracle(log, acts))


def test_performance_dfg():
    rng = np.random.default_rng(1)
    log = random_log(rng, n_cases=10, n_acts=4)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    counts, mean = performance_dfg(frame, len(acts))
    # counts agree with the plain DFG
    from repro.core import dfg
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(dfg(frame, len(acts)).counts))
    # waits are nonnegative (sorted timestamps) and zero where no edge
    m = np.asarray(mean)
    c = np.asarray(counts)
    assert (m >= -1e-5).all()
    assert (m[c == 0] == 0).all()


def test_remaining_time():
    rng = np.random.default_rng(2)
    log = random_log(rng, n_cases=8, n_acts=3)
    frame, tables = sorted_frame(log)
    rt = np.asarray(remaining_time_targets(frame))
    assert (rt >= -1e-5).all()
    # last event of each case has remaining time 0
    case = np.asarray(frame[CASE])
    ends = np.concatenate([case[1:] != case[:-1], [True]])
    np.testing.assert_allclose(rt[ends], 0.0, atol=1e-5)
