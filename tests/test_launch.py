"""Launch layer: spec sanitization, rules resolution, HLO accounting,
roofline math, and a real (reduced-mesh) lower+compile in a subprocess."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import roofline as RL
from repro.launch.hlo import analyze, collective_bytes

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_sanitize_spec_divisibility():
    from repro.launch.mesh import sanitize_spec
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    # vocab 51865 not divisible by 16 -> dropped
    s = sanitize_spec((51865, 1024), P("model", "data"), mesh)
    assert s == P(None, "data")
    # batch 1 can't shard at all
    s = sanitize_spec((1, 524288), P(("pod", "data"), "model"), mesh)
    assert s == P(None, "model")
    # batch 8 keeps the 'pod' prefix of ('pod','data')
    s = sanitize_spec((8, 128), P(("pod", "data"), None), mesh)
    assert s == P("pod", None)
    # fully divisible is untouched
    s = sanitize_spec((512, 4096), P(("pod", "data"), "model"), mesh)
    assert s == P(("pod", "data"), "model")


def test_rules_moe_resolution():
    from repro.launch.mesh import make_rules
    mesh = _FakeMesh({"data": 16, "model": 16})
    r_q = make_rules(mesh, get_config("qwen3-moe-30b-a3b"))
    assert r_q.expert == "model" and r_q.mlp is None     # EP
    r_m = make_rules(mesh, get_config("mixtral-8x7b"))
    assert r_m.expert is None and r_m.mlp == "model"     # TP d_ff
    r_d = make_rules(mesh, get_config("yi-6b"))
    assert r_d.mlp == "model"


def test_hlo_analyze_counts_loops():
    hlo = """
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %gte = f32[8,8] get-tuple-element((s32[], f32[8,8]) %p), index=1
  %ar = f32[8,8] all-reduce(%gte), to_apply=%add
  %dot.1 = f32[8,8] dot(%ar, %gte), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}
%add (x: f32[], y: f32[]) -> f32[] {
  ROOT %a = f32[] add(f32[] %x, f32[] %y)
}
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]) while((s32[], f32[8,8]) %t), condition=%cond, body=%body
}
"""
    a = analyze(hlo)
    # dot: 2 * 64 * 8 = 1024 flops, x7 iterations
    assert a["dot_flops"] == 1024 * 7
    assert a["collective_bytes"] == 8 * 8 * 4 * 7
    assert a["coll_by_op"] == {"all-reduce": 8 * 8 * 4 * 7}


def test_roofline_math():
    rec = {"arch": "yi-6b", "shape": "train_4k",
           "flops_per_device": 197e12,          # exactly 1s of compute
           "bytes_per_device": 819e9 / 2,       # 0.5s of HBM
           "collective_bytes_per_device": 50e9 / 4,  # 0.25s of ICI
           "params": 6e9, "active_params": 6e9}
    a = RL.analyze_record(rec, chips=256)
    assert a["bottleneck"] == "compute"
    assert abs(a["t_compute"] - 1.0) < 1e-9
    assert abs(a["t_memory"] - 0.5) < 1e-9
    assert abs(a["t_collective"] - 0.25) < 1e-9
    useful = 6 * 6e9 * 256 * 4096 / 256
    assert abs(a["useful_ratio"] - useful / 197e12) < 1e-6
    assert 0 < a["roofline_fraction"] <= 1.0


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """A real lower+compile of the smallest cell on the production mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-medium",
         "--shape", "decode_32k", "--out", "/tmp/test_dryrun_cell.jsonl"],
        capture_output=True, text=True, env=env, timeout=560, cwd="/tmp")
    assert res.returncode == 0, res.stdout + res.stderr[-2000:]
    rec = json.loads(open("/tmp/test_dryrun_cell.jsonl").read().splitlines()[-1])
    assert rec["ok"] and rec["flops_per_device"] > 0
    assert rec["memory"]["temp_bytes"] < 16 * 2**30   # fits v5e HBM
