"""Unified Dataset facade: multi-file plans, engine parity, shims, pooling.

The load-bearing invariant (the PR's acceptance bar): for every terminal
verb K and any multi-file Dataset D with a filter F, ``D.filter(F).K()``
is **bitwise equal** to ``K(filter(concat(read(files))))`` at every
engine — eager, streaming, and (for DFG/discovery-backed verbs) sharded
over 1..8 devices.  Plus the satellites: mixed v1/v2/v3 file sets under
both segment backends, deprecation shims with unchanged results, and
re-iteration safety when a pooled reader is closed mid-stream.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (ACTIVITY, CASE, TIMESTAMP, backend, engine,
                        filtering, ops)
from repro.core.dfg import dfg_kernel
from repro.core.discovery import discovery_kernel
from repro.core.stats import stats_kernel
from repro.core.variants import variants_kernel
from repro.data import synthetic
from repro.dataset import engines
from repro.query import Plan, col, case_size, cases_containing, pruned_source
from repro.storage import edf

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
A = 7          # activities in the shared fixture
NC = 240       # cases in the shared fixture


def _split_paths(frame, tables, tmpdir, case_cuts, versions=None,
                 row_group_rows=97):
    """Write the (case,time)-sorted frame as consecutive case-range files."""
    case = np.asarray(frame[CASE])
    bounds = [0] + [int(np.searchsorted(case, c)) for c in case_cuts] \
        + [frame.nrows]
    paths = []
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        version = versions[i] if versions else 3
        kw = {} if version == 1 else {"row_group_rows": row_group_rows}
        p = str(tmpdir / f"part{i}_v{version}.edf")
        edf.write(p, frame.take(jnp.arange(lo, hi)), tables,
                  version=version, **kw)
        paths.append(p)
    return paths


@pytest.fixture(scope="module")
def logset(tmp_path_factory):
    """Three v3 files partitioning one sorted log + the whole frame."""
    frame, tables = synthetic.generate(num_cases=NC, num_activities=A, seed=3)
    d = tmp_path_factory.mktemp("ds")
    paths = _split_paths(frame, tables, d, case_cuts=[80, 160])
    return paths, frame, tables


def _assert_tree_equal(a, b, msg=""):
    """Structural bitwise equality: arrays elementwise, models field by
    field (AlphaModel/HeuristicsNet are not flat pytrees)."""
    import dataclasses

    if isinstance(a, (jax.Array, np.ndarray)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=msg)
    elif dataclasses.is_dataclass(a) and not isinstance(a, type):
        assert type(a) is type(b), msg
        for f in dataclasses.fields(a):
            _assert_tree_equal(getattr(a, f.name), getattr(b, f.name),
                               f"{msg}.{f.name}")
    elif isinstance(a, dict):
        assert set(a) == set(b), msg
        for k in a:
            _assert_tree_equal(a[k], b[k], f"{msg}[{k}]")
    elif isinstance(a, (tuple, list)):
        assert len(a) == len(b), msg
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_tree_equal(x, y, f"{msg}[{i}]")
    else:
        assert a == b, msg


def _ref_frame(whole, name):
    """The eager reference chain each Dataset filter must match bitwise."""
    c, a = whole[CASE], whole[ACTIVITY]
    if name == "band":
        return ops.proj(whole, (c >= 50) & (c <= 170))
    if name == "isin":
        return ops.proj(whole, filtering.isin_mask(a, np.array([2, 5])))
    if name == "chain":
        f = ops.proj(whole, filtering.isin_mask(a, np.array([1, 2, 4])))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return filtering.filter_cases_containing(f, 3, NC)
    raise KeyError(name)


def _filtered(ds, name):
    if name == "band":
        return ds.filter((col(CASE) >= 50) & (col(CASE) <= 170))
    if name == "isin":
        return ds.filter(col(ACTIVITY).isin([2, 5]))
    if name == "chain":
        return ds.filter(col(ACTIVITY).isin([1, 2, 4])).filter(
            cases_containing(3))
    raise KeyError(name)


VERBS = ["dfg", "stats", "variants", "alpha", "heuristics", "discovery",
         "eventually_follows", "performance_dfg"]


@pytest.mark.parametrize("pred", ["band", "isin", "chain"])
def test_every_verb_eager_equals_streaming_equals_reference(logset, pred):
    """The acceptance bar: D.filter(F).K() == K(filter(concat(files)))
    bitwise, at both local engines, for every registered verb."""
    paths, whole, _ = logset
    ds = _filtered(repro.open(paths), pred)
    ref_frame = _ref_frame(whole, pred)
    dims = engine.Dims(A, NC)
    for verb in VERBS:
        spec = engine.kernel_spec(verb)
        ref = engine.run_single(spec.make(dims), ref_frame)
        for eng in ("eager", "streaming"):
            got = ds.collect(verb, engine=eng)
            assert got.engine == eng
            _assert_tree_equal(got.result, ref, f"{pred}/{verb}/{eng}")
            if eng == "streaming":
                assert got.report.bytes_read <= got.report.bytes_total


def test_multi_file_plan_prunes_cold_groups(logset):
    """A selective multi-log query must skip whole row groups across the
    file set — including entire files outside the case band — and read
    well under the full byte budget."""
    paths, whole, _ = logset
    ds = repro.open(paths).filter((col(CASE) >= 90) & (col(CASE) <= 110))
    r = ds.collect("dfg", engine="streaming")
    assert r.report.groups_skipped > 0
    assert r.report.bytes_read < 0.5 * r.report.bytes_total
    assert len(r.report.per_file) == 3
    # the first and last files are entirely outside the band
    assert r.report.per_file[0].groups_read == 0
    assert r.report.per_file[2].groups_read == 0
    ref = engine.run_single(
        dfg_kernel(A),
        ops.proj(whole, (whole[CASE] >= 90) & (whole[CASE] <= 110)))
    _assert_tree_equal(r.result, ref, "pruned multi-file")


def test_union_matches_list_open_and_is_immutable(logset):
    paths, whole, _ = logset
    u = repro.open(paths[0]).union(repro.open(paths[1])).union(
        repro.open(paths[2]))
    assert u.paths == tuple(paths)
    base = repro.open(paths)
    flt = base.filter(col(CASE) <= 100)
    assert base.steps == ()            # immutable: filter returned a copy
    _assert_tree_equal(
        u.filter(col(CASE) <= 100).dfg(engine="streaming"),
        flt.dfg(engine="streaming"), "union == list open")
    with pytest.raises(ValueError):
        flt.union(base)                # differing filter state
    with pytest.raises(TypeError):
        base.filter("not a predicate")
    # capacity hints never leak across a union (regression: a stale
    # num_cases hint would silently undersize case-indexed kernels)
    hinted = repro.open(paths[0], num_cases=80).union(repro.open(paths[1]))
    assert hinted.num_cases == 160     # re-derived, not 80


def test_case_predicates_spanning_files(logset):
    """cases_containing / case_size keep masks are global: phase one
    streams across all files with one kernel, keep slices broadcast per
    file — results match the whole-log chain bitwise."""
    paths, whole, _ = logset
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ref = filtering.filter_case_size(whole, 3, 7, NC)
    ref_sizes = engine.run_single(
        stats_kernel(A, NC), ref)["case_sizes"]
    ds = repro.open(paths).filter(case_size(3, 7))
    for eng in ("eager", "streaming"):
        got = ds.collect("stats", engine=eng).result["case_sizes"]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_sizes),
                                      err_msg=eng)


def test_case_straddling_file_boundary(tmp_path):
    """A case split *across two files* is still one case: the carry flows
    over the boundary and the segment offsets back up by one."""
    frame, tables = synthetic.generate(num_cases=60, num_activities=5,
                                       seed=11)
    case = np.asarray(frame[CASE])
    mid = int(np.searchsorted(case, 30)) + 2   # cut INSIDE case 30
    assert case[mid - 1] == case[mid] == 30
    p0, p1 = str(tmp_path / "a.edf"), str(tmp_path / "b.edf")
    edf.write(p0, frame.take(jnp.arange(0, mid)), tables, row_group_rows=53)
    edf.write(p1, frame.take(jnp.arange(mid, frame.nrows)), tables,
              row_group_rows=53)
    ds = repro.open([p0, p1])
    assert ds.num_cases == 60                  # not 61
    ref = engine.run_single(stats_kernel(5, 60), frame)
    for eng in ("eager", "streaming"):
        got = ds.collect("stats", engine=eng).result
        _assert_tree_equal(got, ref, eng)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        reff = filtering.filter_cases_containing(frame, 2, 60)
    refd = engine.run_single(dfg_kernel(5), reff)
    got = repro.open([p0, p1]).filter(cases_containing(2)).dfg(
        engine="streaming")
    _assert_tree_equal(got, refd, "contains across boundary")


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mixed_version_multi_log_both_backends(tmp_path, impl):
    """Satellite: a Dataset over one v1, one v2, and one v3 file mines
    bitwise-equal to the concatenated in-memory frame, under both
    REPRO_SEGMENT_BACKENDs."""
    with backend.use_backend(impl):
        frame, tables = synthetic.generate(num_cases=90, num_activities=6,
                                           seed=7)
        paths = _split_paths(frame, tables, tmp_path, case_cuts=[30, 60],
                             versions=[1, 2, 3], row_group_rows=71)
        ds = repro.open(paths)
        assert ds.num_cases == 90 and ds.num_activities == 6
        dims = engine.Dims(6, 90)
        flt = ds.filter(col(ACTIVITY).isin([0, 2, 3]))
        mask = filtering.isin_mask(frame[ACTIVITY], np.array([0, 2, 3]))
        ref_frame = ops.proj(frame, mask)
        for verb in ("dfg", "stats", "variants", "heuristics"):
            spec = engine.kernel_spec(verb)
            ref = engine.run_single(spec.make(dims), ref_frame)
            for eng in ("eager", "streaming"):
                got = flt.collect(verb, engine=eng)
                _assert_tree_equal(got.result, ref,
                                   f"v123/{impl}/{verb}/{eng}")
        # v1 has no row groups to skip, but v2/v3 still prune
        r = ds.filter((col(CASE) >= 61) & (col(CASE) <= 75)).collect(
            "dfg", engine="streaming")
        assert r.report.groups_skipped > 0


def test_sharded_engine_1_to_8_shards(logset):
    """Dataset sharded dispatch == eager reference at 1..8 shards (8
    virtual devices in a subprocess; DFG + alpha + heuristics +
    variants)."""
    paths, _, _ = logset
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import repro
from repro.query import col
from repro.core.eventframe import CASE

paths = {paths!r}
ds = repro.open(paths).filter((col(CASE) >= 50) & (col(CASE) <= 170))
ref = ds.dfg(engine="eager")
ref_alpha = ds.alpha(engine="eager")
ref_net = ds.heuristics(engine="eager")
for shards in (1, 2, 4, 8):
    r = ds.collect("dfg", engine="sharded", num_shards=shards)
    assert r.engine == "sharded"
    assert r.report.groups_skipped > 0
    for nm in ("counts", "starts", "ends"):
        assert (np.asarray(getattr(r.result, nm))
                == np.asarray(getattr(ref, nm))).all(), (shards, nm)
for shards in (2, 8):
    m = ds.alpha(engine="sharded", num_shards=shards)
    assert m.places == ref_alpha.places and \
        m.start_activities == ref_alpha.start_activities
    net = ds.heuristics(engine="sharded", num_shards=shards)
    assert (np.asarray(net.graph) == np.asarray(ref_net.graph)).all()
rv = ds.collect("variants", engine="sharded", num_shards=4)
ref_var = ds.collect("variants", engine="eager")
fp1, fp2, nc = rv.result
r1, r2, rnc = ref_var.result
assert (np.asarray(fp1) == np.asarray(r1)).all()
assert (np.asarray(fp2) == np.asarray(r2)).all()
assert int(nc) == int(rnc)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    assert res.stdout.strip().endswith("OK")


def test_engine_auto_is_cost_based(logset, monkeypatch):
    """auto must switch engines as the fitted costs move — the decision
    compares the calibrated eager/streaming time predictions (per-byte
    rates x the zone-map estimate), not a static byte threshold."""
    paths, _, _ = logset
    ds = repro.open(paths)
    # a pinned calibration (no intercepts, streaming 20% dearer per byte)
    # makes the decision hinge purely on estimated selectivity
    monkeypatch.setattr(engines, "_CALIBRATION",
                        engines.Calibration(0.0, 1.0, 0.0, 1.2, 0.0, "test"))
    # unselective: streaming reads the same bytes at a worse rate -> eager
    r = ds.collect("dfg")
    assert r.engine == "eager" and r.estimate is not None
    assert r.estimate.selectivity == 1.0
    # a selective band -> zone maps refute most groups -> streaming
    sel = ds.filter((col(CASE) >= 90) & (col(CASE) <= 110))
    r2 = sel.collect("dfg")
    assert r2.engine == "streaming"
    assert r2.estimate.selectivity < 0.5
    cal = engines.calibration()
    assert cal.streaming_us(r2.estimate) <= cal.eager_us(r2.estimate)
    # recalibrate: every eager byte ruinous -> even the unselective scan
    # streams (the knob is the fitted coefficients now, not a threshold)
    monkeypatch.setattr(engines, "_CALIBRATION",
                        engines.Calibration(0.0, 1e9, 0.0, 1.2, 0.0, "test"))
    assert ds.collect("dfg").engine == "streaming"
    # in-memory datasets always run eagerly
    frame, tables = synthetic.generate(num_cases=30, num_activities=5,
                                       seed=1)
    mem = repro.open(frame, tables=tables)
    assert mem.collect("dfg").engine == "eager"
    with pytest.raises(ValueError):
        mem.collect("dfg", engine="warp")


def test_in_memory_dataset_matches_files(logset):
    paths, whole, tables = logset
    mem = repro.open(whole, tables=tables)
    assert mem.num_activities == A and mem.num_cases == NC
    f = (col(CASE) >= 50) & (col(CASE) <= 170)
    _assert_tree_equal(mem.filter(f).dfg(),
                       repro.open(paths).filter(f).dfg(engine="streaming"),
                       "memory == files")
    tf = mem.filter(f).project([CASE, ACTIVITY]).to_frame()
    ref = ops.proj(whole, (whole[CASE] >= 50) & (whole[CASE] <= 170))
    ref = ref.select([CASE, ACTIVITY]).compact()
    np.testing.assert_array_equal(np.asarray(tf[CASE]), np.asarray(ref[CASE]))
    assert set(tf.names) == {CASE, ACTIVITY}


def test_frame_union_preserves_masks(logset):
    """In-memory union keeps epsilon masks and the lazy row_valid mask
    separate (folding them together would change rows_valid())."""
    paths, whole, tables = logset
    half = whole.nrows // 2
    a = whole.take(jnp.arange(0, half))
    b = whole.take(jnp.arange(half, whole.nrows))
    a = ops.proj(a, a[ACTIVITY] >= 0)       # attach a row_valid mask
    u = repro.open(a, tables=tables).union(repro.open(b, tables=tables))
    np.testing.assert_array_equal(np.asarray(u.frame.rows_valid()),
                                  np.ones(whole.nrows, bool))
    _assert_tree_equal(u.dfg(), repro.open(whole, tables=tables).dfg(),
                       "frame union")
    with pytest.raises(ValueError):
        repro.open(a, tables=tables).union(repro.open(paths[0]))


def test_to_frame_matches_compact(logset):
    paths, whole, _ = logset
    got = repro.open(paths).filter(col(ACTIVITY) == 2).to_frame()
    ref = ops.proj(whole, whole[ACTIVITY] == 2).compact()
    for k in (CASE, ACTIVITY):
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]))


def test_deprecation_shims_warn_and_match(logset):
    """Satellite: the old eager entry points still work bitwise, but tell
    the user where the new API lives."""
    paths, whole, _ = logset
    ds = repro.open(paths)
    with pytest.warns(DeprecationWarning, match="Dataset"):
        old = filtering.filter_attr_values(whole, ACTIVITY, [2, 5])
    new = ds.filter(col(ACTIVITY).isin([2, 5])).collect(
        "activity_counts", engine="streaming").result
    ref = engine.run_single(
        engine.kernel_spec("activity_counts").make(engine.Dims(A, NC)), old)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(ref))
    with pytest.warns(DeprecationWarning, match="between"):
        old_t = filtering.filter_time_range(whole, TIMESTAMP, 3e5, 7e5)
    new_t = ds.filter(col(TIMESTAMP).between(3e5, 7e5))
    _assert_tree_equal(new_t.dfg(engine="streaming"),
                       engine.run_single(dfg_kernel(A), old_t), "time range")
    with pytest.warns(DeprecationWarning, match="cases_containing"):
        old_c = filtering.filter_cases_containing(whole, 3, NC)
    _assert_tree_equal(
        ds.filter(cases_containing(3)).dfg(engine="streaming"),
        engine.run_single(dfg_kernel(A), old_c), "contains")
    with pytest.warns(DeprecationWarning, match="case_size"):
        old_s = filtering.filter_case_size(whole, 3, 7, NC)
    _assert_tree_equal(
        ds.filter(case_size(3, 7)).dfg(engine="streaming"),
        engine.run_single(dfg_kernel(A), old_s), "case size")
    with pytest.warns(DeprecationWarning, match="repro.open"):
        from repro.query import scan

        plan = scan(paths[0])
    assert isinstance(plan, Plan)


def test_pruned_source_survives_reader_close(logset):
    """Satellite bugfix: closing the pooled EDFReader between iterations
    must not break a re-iterable pruned source — the reader reopens."""
    paths, whole, _ = logset
    plan = Plan(paths[0]).filter(col(CASE) <= 75)
    src, rep = pruned_source(plan)
    first = engine.run_streaming(dfg_kernel(A), src)
    reader = edf.pooled_reader(paths[0])
    assert not reader.closed            # the scan left a live handle
    reader.close()
    assert reader.closed
    second = engine.run_streaming(dfg_kernel(A), src)   # reopens on demand
    _assert_tree_equal(first, second, "re-iteration after close")
    # pool eviction closes handles the same way; a tiny pool exercises it
    pool = edf.ReaderPool(capacity=1)
    r0 = pool.get(paths[0])
    pool.get(paths[1])                  # evicts r0 -> closed
    assert r0.closed
    assert r0.read_group(0).nrows > 0   # but still readable (reopen)
    # the pool hands back the same reader while the file is unchanged
    assert edf.pooled_reader(paths[0]) is edf.pooled_reader(paths[0])


def test_closed_reader_refuses_rewritten_file(tmp_path):
    """Reopening against a file rewritten in place must fail loudly (the
    cached header would decode the new bytes as garbage); the pool hands
    out a fresh reader instead."""
    frame, tables = synthetic.generate(num_cases=20, num_activities=4,
                                       seed=2)
    p = str(tmp_path / "mut.edf")
    edf.write(p, frame, tables, row_group_rows=31)
    reader = edf.pooled_reader(p)
    assert reader.read_group(0).nrows > 0
    reader.close()
    os.utime(p, ns=(1, 1))              # simulate an in-place rewrite
    with pytest.raises(ValueError, match="changed on disk"):
        reader.read_group(0)
    fresh = edf.pooled_reader(p)        # pool re-stats and replaces it
    assert fresh is not reader
    assert fresh.read_group(0).nrows > 0


def test_kernel_registry_is_public_and_complete():
    specs = engine.kernel_specs()
    for verb in VERBS + ["activity_counts", "case_sizes", "case_durations",
                         "sojourn_times"]:
        assert verb in specs, verb
        assert callable(specs[verb].make)
    assert specs["dfg"].sharded_state == "dfg"
    assert specs["alpha"].sharded_state == "dfg"
    assert specs["heuristics"].sharded_state == "discovery"
    assert specs["variants"].sharded_state == "variants"
    with pytest.raises(KeyError, match="registered"):
        engine.kernel_spec("nope")
