"""Property-testing shim: real hypothesis when installed, else a tiny
seeded-random fallback implementing the ``given/settings/strategies`` subset
these tests use (integer strategies as keyword arguments).

The fallback is deliberately dumb: it draws ``max_examples`` pseudo-random
samples from a fixed-seed generator, so runs are deterministic and failures
reproducible, but there is no shrinking and no database. Install hypothesis
to get the real thing; nothing here needs changing when you do.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 20
    _SEED = 0xEDF

    class _Integers:
        def __init__(self, min_value: int, max_value: int):
            self.min_value = int(min_value)
            self.max_value = int(max_value)

        def draw(self, rng: "np.random.Generator") -> int:
            return int(rng.integers(self.min_value, self.max_value + 1))

    class strategies:  # noqa: N801 - mimic the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Integers:
            return _Integers(min_value, max_value)

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = np.random.default_rng(_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # keep pytest from treating the strategy kwargs as fixtures
            wrapper.__signature__ = inspect.Signature()
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
