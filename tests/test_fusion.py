"""Fused multi-verb collection + double-buffered pruned scans.

The PR's acceptance bar: ``ds.collect_many([v1, v2, ...])`` is bitwise
equal, verb for verb, to the separate ``ds.collect(v)`` calls — under the
eager, streaming, and sharded engines, over multi-file plans, at any row
group size, with the prefetcher on or off.  Plus the satellites: the
``compose()`` column-union regression (a fused kernel must not starve a
member of a projected column), ``ReaderPool`` safety under the prefetch
thread, and pruning exactness with a variants member (header sketches
replay skipped runs, so the fused scan skips groups whatever the mix).
"""
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import ACTIVITY, CASE, TIMESTAMP, backend, engine
from repro.core.stats import sojourn_times_kernel
from repro.core.performance import performance_dfg_kernel
from repro.data import synthetic
from repro.query import col, cases_containing
from repro.query.exec import prefetch_depth, pruned_source
from repro.storage import edf
from repro.storage.edf import EDFReader, pooled_reader

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
A = 6
NC = 150

VERBS = ("dfg", "stats", "variants", "alpha", "heuristics")


def _split_paths(frame, tables, tmpdir, case_cuts, row_group_rows=97):
    case = np.asarray(frame[CASE])
    bounds = [0] + [int(np.searchsorted(case, c)) for c in case_cuts] \
        + [frame.nrows]
    paths = []
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        p = str(tmpdir / f"part{i}.edf")
        edf.write(p, frame.take(jnp.arange(lo, hi)), tables, version=3,
                  row_group_rows=row_group_rows)
        paths.append(p)
    return paths


@pytest.fixture(scope="module")
def logset(tmp_path_factory):
    frame, tables = synthetic.generate(num_cases=NC, num_activities=A, seed=5)
    d = tmp_path_factory.mktemp("fusion")
    paths = _split_paths(frame, tables, d, case_cuts=[50, 100])
    return paths, frame, tables


def _assert_tree_equal(a, b, msg=""):
    import dataclasses

    if isinstance(a, (jax.Array, np.ndarray)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=msg)
    elif dataclasses.is_dataclass(a) and not isinstance(a, type):
        assert type(a) is type(b), msg
        for f in dataclasses.fields(a):
            _assert_tree_equal(getattr(a, f.name), getattr(b, f.name),
                               f"{msg}.{f.name}")
    elif isinstance(a, dict):
        assert set(a) == set(b), msg
        for k in a:
            _assert_tree_equal(a[k], b[k], f"{msg}[{k}]")
    elif isinstance(a, (tuple, list)):
        assert len(a) == len(b), msg
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_tree_equal(x, y, f"{msg}[{i}]")
    else:
        assert a == b, f"{msg}: {a!r} != {b!r}"


# --------------------------------------------------- S1: compose() columns
def test_compose_unions_member_columns():
    """Regression: compose() used to drop per-kernel ``columns``, so a
    projected scan could starve a fused member of a column it reads."""
    soj = sojourn_times_kernel(A)
    perf = performance_dfg_kernel(A)
    assert TIMESTAMP in soj.columns and TIMESTAMP in perf.columns
    fused = engine.compose({"sojourn_times": soj, "performance_dfg": perf})
    assert set(fused.columns) == set(soj.columns) | set(perf.columns)
    # any member with unknown requirements poisons the union (read all)
    blind = engine.ChunkKernel("blind", soj.init, soj.update, soj.merge,
                               soj.finalize, columns=())
    assert engine.compose({"a": soj, "b": blind}).columns == ()


def test_fused_projection_carries_member_columns(logset):
    """The end-to-end form of the regression: a fused stats+performance
    collection over a *timestamp-projected* dataset must read the
    timestamp extent (projection = the fused union), bitwise equal to the
    separate runs."""
    paths, frame, _ = logset
    ds = repro.open(paths)
    res = ds.collect_many(["stats", "performance_dfg"], engine="streaming")
    assert TIMESTAMP in res.report.columns
    for verb in ("stats", "performance_dfg"):
        sep = ds.collect(verb, engine="streaming")
        _assert_tree_equal(res[verb], sep.result, verb)
    # an explicit projection narrower than the union is rejected, not
    # silently starved
    with pytest.raises(ValueError):
        ds.project([CASE, ACTIVITY]).collect_many(
            ["dfg", "stats"], engine="streaming")


def test_compose_specs_fused_spec():
    """The fused KernelSpec: union columns, sharded_state intersection,
    per-verb kwargs routing."""
    specs = {v: engine.kernel_spec(v) for v in ("dfg", "alpha")}
    fused = engine.compose_specs(specs)
    assert fused.members == ("dfg", "alpha")
    assert set(fused.columns) == {CASE, ACTIVITY}
    assert fused.sharded_state == "fused"       # every member shardable
    mixed = engine.compose_specs(
        {v: engine.kernel_spec(v) for v in ("dfg", "variants")})
    assert mixed.sharded_state == "fused"       # variants shards too now
    dims = engine.Dims(A, NC)
    k = fused.make(dims, verb_kwargs={"alpha": {"min_count": 2}})
    assert k.mask_exact
    with pytest.raises(KeyError):
        fused.make(dims, verb_kwargs={"nope": {}})
    with pytest.raises(ValueError):
        engine.compose_specs({})


# ------------------------------------------- S3: collect_many == collect
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_collect_many_matches_separate_collects(logset, impl):
    """One fused scan == N separate scans, verb for verb, multi-file,
    filtered, under both segment backends and both engines."""
    paths, frame, _ = logset
    with backend.use_backend(impl):
        ds = repro.open(paths).filter(col(ACTIVITY) != 2)
        for eng in ("eager", "streaming"):
            res = ds.collect_many(VERBS, engine=eng)
            assert res.engine == eng and res.verbs == VERBS
            for verb in VERBS:
                sep = ds.collect(verb, engine=eng)
                _assert_tree_equal(res[verb], sep.result,
                                   f"{impl}/{eng}/{verb}")


def test_collect_many_chunk_invariance(tmp_path):
    """Fused results are invariant to the row-group size the files were
    written with (the carry crosses group boundaries, fused or not)."""
    frame, tables = synthetic.generate(num_cases=80, num_activities=5,
                                       seed=11)
    results = []
    for rg in (37, 97, 10_000):
        d = tmp_path / f"rg{rg}"
        d.mkdir()
        paths = _split_paths(frame, tables, d, case_cuts=[40],
                             row_group_rows=rg)
        ds = repro.open(paths).filter(col(CASE) >= 10)
        results.append(ds.collect_many(VERBS, engine="streaming").results)
    for other in results[1:]:
        _assert_tree_equal(results[0], other, "chunk invariance")


def test_collect_many_case_predicate(logset):
    """A two-pass case predicate in the fused plan: phase one runs once,
    every member sees the same keep-mask broadcast."""
    paths, _, _ = logset
    ds = repro.open(paths).filter(cases_containing(1))
    res = ds.collect_many(["dfg", "stats"], engine="streaming")
    for verb in ("dfg", "stats"):
        _assert_tree_equal(res[verb],
                           ds.collect(verb, engine="streaming").result, verb)


def test_variants_member_keeps_pruning_and_results(logset):
    """Regression for the old ``mask_exact`` degradation cliff: adding
    variants to a fused set must NOT force the composite onto the
    unpruned stream — header sketches replay the skipped runs, so the
    fused scan still skips refuted groups and every member (variants
    included) stays bitwise equal to its separate run."""
    paths, _, _ = logset
    ds = repro.open(paths).filter((col(CASE) >= 20) & (col(CASE) <= 45))
    pruned = ds.collect_many(["dfg", "stats"], engine="streaming")
    assert pruned.report.groups_skipped > 0
    fused = ds.collect_many(["dfg", "stats", "variants"],
                            engine="streaming")
    assert fused.report.groups_skipped > 0          # no degradation branch
    assert fused.report.groups_skipped == pruned.report.groups_skipped
    for verb in ("dfg", "stats"):
        _assert_tree_equal(pruned.results[verb], fused.results[verb], verb)
    _assert_tree_equal(fused.results["variants"],
                       ds.collect("variants", engine="streaming").result,
                       "variants")
    _assert_tree_equal(fused.results["variants"],
                       ds.collect("variants", engine="eager").result,
                       "variants vs eager")


def test_collect_many_sharded_1_to_8(logset):
    """Fused sharded collection (one gathered stream, dfg + discovery
    states deduped, one shard_map) == eager, at 1..8 virtual devices."""
    paths, _, _ = logset
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import repro
from repro.query import col
from repro.core.eventframe import CASE

paths = {paths!r}
ds = repro.open(paths).filter((col(CASE) >= 30) & (col(CASE) <= 120))
VERBS = ("dfg", "alpha", "heuristics", "variants")
ref = {{v: ds.collect(v, engine="eager").result for v in VERBS}}
for shards in (1, 2, 4, 8):
    res = ds.collect_many(VERBS, engine="sharded", num_shards=shards)
    assert res.engine == "sharded"
    d, rd = res["dfg"], ref["dfg"]
    for nm in ("counts", "starts", "ends"):
        assert (np.asarray(getattr(d, nm))
                == np.asarray(getattr(rd, nm))).all(), (shards, nm)
    assert res["alpha"].places == ref["alpha"].places
    assert res["alpha"].start_activities == ref["alpha"].start_activities
    assert (np.asarray(res["heuristics"].graph)
            == np.asarray(ref["heuristics"].graph)).all(), shards
    fp1, fp2, nc = res["variants"]
    rf1, rf2, rnc = ref["variants"]
    assert (np.asarray(fp1) == np.asarray(rf1)).all(), shards
    assert (np.asarray(fp2) == np.asarray(rf2)).all(), shards
    assert int(nc) == int(rnc), shards
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    assert res.stdout.strip().endswith("OK")


def test_explain_and_profile(logset):
    paths, _, _ = logset
    ds = repro.open(paths)
    text = ds.explain(verbs=["dfg", "stats", "variants"])
    assert "fused [dfg, stats, variants]" in text
    assert "pruned" in text and "prefetch" in text and "cost eager~" in text
    assert "unpruned" not in text       # variants no longer degrades
    prof = ds.profile(engine="eager")
    assert set(prof.verbs) >= {"dfg", "stats", "variants", "alpha",
                               "heuristics", "performance_dfg"}
    _assert_tree_equal(prof["dfg"], ds.collect("dfg", engine="eager").result,
                       "profile dfg")
    with pytest.raises(ValueError):
        ds.collect_many(["dfg", "dfg"])


# -------------------------------- S2: prefetcher + ReaderPool under threads
def test_prefetch_on_off_bitwise_identical(logset):
    """The double buffer changes wall clock, never bytes or results: the
    chunk streams at depth 0, 1 and 3 are element-for-element identical
    (columns, validity, masks), and so are fused results."""
    paths, _, _ = logset
    ds = repro.open(paths).filter(col(CASE) <= 90)
    plan = ds.plan(columns=(CASE, ACTIVITY, TIMESTAMP))
    streams, reports = [], []
    for depth in (0, 1, 3):
        src, rep = pruned_source(plan, prefetch=depth)
        streams.append([c for c in src])
        reports.append(rep)
        assert rep.prefetch == depth
    assert reports[0].bytes_read == reports[1].bytes_read \
        == reports[2].bytes_read
    for other in streams[1:]:
        assert len(streams[0]) == len(other)
        for a, b in zip(streams[0], other):
            assert set(a.columns) == set(b.columns)
            for k in a.columns:
                np.testing.assert_array_equal(np.asarray(a[k]),
                                              np.asarray(b[k]))
            np.testing.assert_array_equal(np.asarray(a.rows_valid()),
                                          np.asarray(b.rows_valid()))
    _assert_tree_equal(
        ds.collect_many(("dfg", "stats"), engine="streaming",
                        prefetch=0).results,
        ds.collect_many(("dfg", "stats"), engine="streaming",
                        prefetch=3).results, "prefetch parity")


def test_prefetch_env_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_QUERY_PREFETCH", raising=False)
    assert prefetch_depth() == 1          # default: one group ahead
    assert prefetch_depth(0) == 0 and prefetch_depth(4) == 4
    monkeypatch.setenv("REPRO_QUERY_PREFETCH", "2")
    assert prefetch_depth() == 2
    monkeypatch.setenv("REPRO_QUERY_PREFETCH", "0")
    assert prefetch_depth() == 0
    assert prefetch_depth(-3) == 0        # clamped, never negative


def test_prefetch_survives_midstream_reader_close(logset):
    """Closing the pooled reader while the prefetch thread is mid-file
    exercises the auto-reopen path under contention; results unchanged."""
    paths, _, _ = logset
    ds = repro.open(paths)
    ref = ds.collect_many(("dfg", "stats"), engine="streaming",
                          prefetch=0).results
    src, _ = pruned_source(ds.plan(columns=(CASE, ACTIVITY, TIMESTAMP)),
                           prefetch=2)
    chunks = []
    for i, chunk in enumerate(src):
        if i == 1:
            for p in paths:
                pooled_reader(p).close()    # yanked mid-iteration
        chunks.append(chunk)
    got = engine.run_streaming(
        engine.compose_specs(
            {v: engine.kernel_spec(v) for v in ("dfg", "stats")}
        ).make(engine.Dims(ds.num_activities, ds.num_cases)), chunks)
    _assert_tree_equal(got, ref, "close mid-stream")


def test_reader_pool_threaded_stress(tmp_path):
    """S2: one pooled reader hammered by concurrent readers + closers must
    never double-open, read through a closed handle, or interleave
    seek/read pairs — every thread sees bitwise-correct groups."""
    frame, tables = synthetic.generate(num_cases=60, num_activities=5,
                                       seed=23)
    p = str(tmp_path / "stress.edf")
    edf.write(p, frame, tables, version=3, row_group_rows=53)
    ref_reader = EDFReader(p)
    expected = [{k: np.asarray(v) for k, v in
                 ref_reader.read_group(g).columns.items()}
                for g in range(ref_reader.num_groups)]
    ref_reader.close()

    errors: list = []
    stop = threading.Event()

    def hammer():
        try:
            r = pooled_reader(p)
            for _ in range(30):
                for g in range(r.num_groups):
                    frame_g = r.read_group(g)
                    for k, v in frame_g.columns.items():
                        if not np.array_equal(np.asarray(v), expected[g][k]):
                            raise AssertionError(f"group {g} col {k} corrupt")
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    def closer():
        while not stop.is_set():
            pooled_reader(p).close()

    threads = [threading.Thread(target=hammer) for _ in range(6)]
    chaos = threading.Thread(target=closer, daemon=True)
    for t in threads:
        t.start()
    chaos.start()
    for t in threads:
        t.join(timeout=120)
    stop.set()
    chaos.join(timeout=10)
    assert not errors, errors[0]


def test_group_meta_synthesis_thread_safe(tmp_path):
    """v2 files synthesize zone metadata lazily; two threads racing on
    ``group_meta`` must agree (one synthesis per group, no torn dicts)."""
    frame, tables = synthetic.generate(num_cases=40, num_activities=5,
                                       seed=29)
    p = str(tmp_path / "v2.edf")
    edf.write(p, frame, tables, version=2, row_group_rows=41)
    reader = EDFReader(p)
    out: list = [None, None]

    def grab(slot):
        out[slot] = [reader.group_meta(g) for g in range(reader.num_groups)]

    ts = [threading.Thread(target=grab, args=(i,)) for i in (0, 1)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert out[0] is not None and out[1] is not None
    for m0, m1 in zip(out[0], out[1]):
        assert m0 is m1                   # same cached dict, not a re-synth
    reader.close()
