"""Segmented-primitive layer: Pallas (interpret) == XLA bitwise parity,
backend dispatch rules, and end-to-end algorithm equivalence on the pallas
backend.  This file is the CPU-only CI gate for kernel regressions."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import ACTIVITY, CASE, TIMESTAMP, ChunkedEventFrame, backend
from repro.core import run_streaming, stats, variants
from repro.core.dfg import dfg_kernel, dfg_segment
from repro.core.performance import eventually_follows, eventually_follows_kernel
from repro.kernels import segment_ops as so

from helpers import random_log, sorted_frame

rng = np.random.default_rng(7)


def _consecutive_sorted_ids(n, approx_segments):
    seg = np.sort(rng.integers(0, approx_segments, n)).astype(np.int32)
    if n:
        seg = (np.cumsum(np.concatenate([[1], np.diff(seg) != 0])) - 1).astype(np.int32)
    return seg


# ------------------------------------------------------------ parity: bitwise
@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("n,block", [(1, 128), (300, 64), (1000, 128), (513, 512)])
def test_segment_reduce_parity(op, n, block):
    seg = _consecutive_sorted_ids(n, max(n // 7, 2))
    s = int(seg.max()) + 1 if n else 1
    vals = jnp.asarray(rng.integers(-50, 50, n), jnp.int32)
    a = so.segment_reduce(vals, jnp.asarray(seg), s, op, impl="xla")
    b = so.segment_reduce(vals, jnp.asarray(seg), s, op, impl="pallas",
                          block_e=block)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_segment_reduce_drops_out_of_range():
    seg = _consecutive_sorted_ids(400, 40)
    s = int(seg.max()) + 1
    seg[:7] = -1            # the engine's pre-first-row carry id
    seg[-7:] = s + 1000     # beyond the configured capacity
    vals = jnp.asarray(rng.integers(0, 9, 400), jnp.int32)
    a = so.segment_reduce(vals, jnp.asarray(seg), s, "sum", impl="xla")
    b = so.segment_reduce(vals, jnp.asarray(seg), s, "sum", impl="pallas",
                          block_e=128)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(a).sum()) == int(np.asarray(vals)[7:-7].sum())


def test_segment_reduce_float_minmax_and_bool():
    seg = _consecutive_sorted_ids(500, 30)
    s = int(seg.max()) + 1
    ts = jnp.asarray(rng.random(500) * 1e6, jnp.float32)
    for op in ("min", "max"):
        a = so.segment_reduce(ts, jnp.asarray(seg), s, op, impl="xla")
        b = so.segment_reduce(ts, jnp.asarray(seg), s, op, impl="pallas",
                              block_e=128)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    hit = jnp.asarray(rng.random(500) < 0.2)
    a = so.segment_reduce(hit, jnp.asarray(seg), s, "max", impl="xla")
    b = so.segment_reduce(hit, jnp.asarray(seg), s, "max", impl="pallas",
                          block_e=128)
    assert a.dtype == jnp.bool_
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("nbins,n,blocks", [(5, 1000, (128, 32)),
                                            (48, 777, (256, 128)),
                                            (300, 1000, (128, 64)),
                                            (7, 1, (512, 128))])
def test_histogram_parity(nbins, n, blocks):
    v = jnp.asarray(rng.integers(-2, nbins + 3, n), jnp.int32)
    w = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
    be, bb = blocks
    for weights in (None, w):
        a = so.histogram(v, nbins, weights, impl="xla")
        b = so.histogram(v, nbins, weights, impl="pallas", block_e=be, block_b=bb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_histogram_into_accumulates():
    v = jnp.asarray(rng.integers(0, 6, 100), jnp.int32)
    prev = jnp.asarray(rng.integers(0, 9, 6), jnp.int32)
    out = so.histogram(v, 6, into=prev, impl="xla")
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(prev) + np.asarray(so.histogram(v, 6, impl="xla")))


@pytest.mark.parametrize("ns,nd,n", [(11, 7, 1000), (130, 130, 2000), (3, 200, 500)])
def test_pair_count_parity_three_lowerings(ns, nd, n):
    s = jnp.asarray(rng.integers(-1, ns + 1, n), jnp.int32)
    d = jnp.asarray(rng.integers(-1, nd + 1, n), jnp.int32)
    m = jnp.asarray(rng.random(n) < 0.7)
    ref = np.asarray(so.pair_count(s, d, ns, nd, m, impl="xla"))
    for impl in ("matmul", "pallas"):
        got = so.pair_count(s, d, ns, nd, m, impl=impl, block_e=256)
        np.testing.assert_array_equal(np.asarray(got), ref, err_msg=impl)


@pytest.mark.parametrize("n,block", [(64, 64), (1000, 64), (513, 256), (1, 128)])
def test_segmented_polyhash_parity(n, block):
    acts = jnp.asarray(rng.integers(1, 30, n), jnp.uint32)
    starts = np.asarray(rng.random(n) < 0.2)
    starts[0] = True
    h0 = jnp.uint32(rng.integers(0, 2**31))
    a_ys, a_c = so.segmented_scan(acts, jnp.asarray(starts), h0, "polyhash",
                                  base=1_000_003, impl="xla")
    b_ys, b_c = so.segmented_scan(acts, jnp.asarray(starts), h0, "polyhash",
                                  base=1_000_003, impl="pallas", block_e=block)
    np.testing.assert_array_equal(np.asarray(a_ys), np.asarray(b_ys))
    assert int(a_c) == int(b_c)


@pytest.mark.parametrize("n,block", [(64, 64), (1000, 64), (513, 256), (1, 128)])
def test_segmented_affine_parity(n, block):
    """Per-row (mul, add) affine scan: pallas == xla bitwise, carry and
    all — the primitive under sketch-folding variants."""
    mul = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    add = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    starts = np.asarray(rng.random(n) < 0.2)
    starts[0] = True
    h0 = jnp.uint32(rng.integers(0, 2**31))
    a_ys, a_c = so.segmented_affine(mul, add, jnp.asarray(starts), h0,
                                    impl="xla")
    b_ys, b_c = so.segmented_affine(mul, add, jnp.asarray(starts), h0,
                                    impl="pallas", block_e=block)
    np.testing.assert_array_equal(np.asarray(a_ys), np.asarray(b_ys))
    assert int(a_c) == int(b_c)
    # degenerate polyhash: mul == BASE, add == token reproduces the
    # polyhash scan exactly
    acts = jnp.asarray(rng.integers(1, 30, n), jnp.uint32)
    p_ys, p_c = so.segmented_scan(acts, jnp.asarray(starts), jnp.uint32(0),
                                  "polyhash", base=1_000_003, impl="xla")
    e_ys, e_c = so.segmented_affine(jnp.full(n, 1_000_003, jnp.uint32),
                                    acts, jnp.asarray(starts), jnp.uint32(0),
                                    impl="xla")
    np.testing.assert_array_equal(np.asarray(p_ys), np.asarray(e_ys))
    assert int(p_c) == int(e_c)


@pytest.mark.parametrize("k", [1, 6])
def test_segmented_sum_scan_parity(k):
    n = 700
    oh = np.eye(k, dtype=np.float32)[rng.integers(0, k, n)]
    starts = np.asarray(rng.random(n) < 0.15)
    carry = rng.integers(0, 4, k).astype(np.float32)
    a_ys, a_c = so.segmented_scan(jnp.asarray(oh), jnp.asarray(starts),
                                  jnp.asarray(carry), "sum", impl="xla")
    b_ys, b_c = so.segmented_scan(jnp.asarray(oh), jnp.asarray(starts),
                                  jnp.asarray(carry), "sum", impl="pallas",
                                  block_e=128)
    np.testing.assert_array_equal(np.asarray(a_ys), np.asarray(b_ys))
    np.testing.assert_array_equal(np.asarray(a_c), np.asarray(b_c))


def test_scan_carry_chains_across_chunks():
    """Seeding a scan with the previous chunk's carry_out reproduces the
    whole-stream scan — the streaming engine's stitching property, at the
    primitive level, on both lowerings."""
    n, cut = 900, 391
    acts = jnp.asarray(rng.integers(1, 9, n), jnp.uint32)
    starts = np.asarray(rng.random(n) < 0.2)
    starts[0] = True
    whole, cw = so.segmented_scan(acts, jnp.asarray(starts), jnp.uint32(0),
                                  "polyhash", base=257, impl="xla")
    for impl in ("xla", "pallas"):
        y1, c1 = so.segmented_scan(acts[:cut], jnp.asarray(starts[:cut]),
                                   jnp.uint32(0), "polyhash", base=257,
                                   impl=impl, block_e=128)
        y2, c2 = so.segmented_scan(acts[cut:], jnp.asarray(starts[cut:]),
                                   c1, "polyhash", base=257,
                                   impl=impl, block_e=128)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(y1), np.asarray(y2)]), np.asarray(whole))
        assert int(c2) == int(cw)


# ------------------------------------------------------- dispatch semantics
def test_backend_dispatch_and_float_gate():
    assert backend.resolve("pallas") == "pallas"
    assert backend.resolve("xla") == "xla"
    with backend.use_backend("xla"):
        assert backend.get_backend() == "xla"
    with pytest.raises(ValueError):
        backend.set_backend("cuda")
    # float-weighted accumulation is order-sensitive: under the pallas
    # backend it must still take the row-order XLA scatter by default
    n = 1000
    v = jnp.asarray(rng.integers(0, 8, n), jnp.int32)
    w = jnp.asarray(rng.random(n), jnp.float32)
    with backend.use_backend("pallas"):
        gated = so.histogram(v, 8, w)
    np.testing.assert_array_equal(np.asarray(gated),
                                  np.asarray(so.histogram(v, 8, w, impl="xla")))


def test_mergstrv_int32_overflow_guard():
    from repro.core import EventFrame, ops

    frame = EventFrame.from_numpy({
        "a": np.asarray([1, 2**16], np.int32),
        "b": np.asarray([3, 4], np.int32),
    })
    with pytest.raises(OverflowError, match="int32"):
        ops.mergstrv(frame, "m", "a", "b", 2**16)
    # in-range encodings still work and stay injective
    small = EventFrame.from_numpy({
        "a": np.asarray([1, 2000], np.int32),
        "b": np.asarray([3, 4], np.int32),
    })
    out = ops.mergstrv(small, "m", "a", "b", 2**16)
    assert int(out["m"][0]) == 2**16 + 3
    assert int(out["m"][1]) == 2000 * 2**16 + 4


# ------------------------------------------- end-to-end on the pallas backend
def _small_frame(seed=3):
    r = np.random.default_rng(seed)
    log = random_log(r, n_cases=18, n_acts=5, max_len=7)
    frame, tables = sorted_frame(log)
    return log, frame, len(tables[ACTIVITY])


def test_dfg_streaming_invariance_on_pallas_backend():
    log, frame, a = _small_frame()
    ref = dfg_segment(frame, a)          # XLA scatter whole-log oracle
    src = ChunkedEventFrame.from_frame(frame, 29)
    with backend.use_backend("pallas"):
        got = run_streaming(dfg_kernel(a), src)
    for nm in ("counts", "starts", "ends"):
        np.testing.assert_array_equal(np.asarray(getattr(got, nm)),
                                      np.asarray(getattr(ref, nm)), err_msg=nm)


def test_stats_variants_efg_on_pallas_backend():
    log, frame, a = _small_frame(11)
    c = len(log.case_ids)
    src = ChunkedEventFrame.from_frame(frame, 23)
    ref_sizes = np.asarray(stats.case_sizes(frame, c))
    ref_dur = np.asarray(stats.case_durations(frame, c))
    ref_var = variants.variant_counts(frame)
    ref_efg = np.asarray(eventually_follows(frame, a))
    with backend.use_backend("pallas"):
        np.testing.assert_array_equal(
            np.asarray(run_streaming(stats.case_sizes_kernel(c), src)), ref_sizes)
        np.testing.assert_array_equal(
            np.asarray(run_streaming(stats.case_durations_kernel(c), src)), ref_dur)
        assert variants.streaming_variant_counts(src, c) == ref_var
        np.testing.assert_array_equal(
            np.asarray(run_streaming(eventually_follows_kernel(a), src)), ref_efg)