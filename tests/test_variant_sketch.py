"""Per-group variant sketches: header-band pruning for the variants verb.

The PR's bar: ``variants`` is pruning-exact.  A skipped row group
contributes its header sketch (the collapsed affine maps of its case
runs) instead of its rows, and the folded fingerprints are bitwise what
a full decode produces — per file version (including v3 files written
*before* the sketch band), per segment backend, per chunk size (down to
one-row groups), per shard count, and across case runs that straddle
file boundaries.  ``variant_in``/``variant_of`` predicates resolve at
header-read time: zero phase-one I/O.
"""
import json
import os
import struct
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import ACTIVITY, CASE, backend, engine, ops
from repro.core.polyhash import (BASE1, BASE2, compose, segment_sketch,
                                 sequence_fingerprint)
from repro.core.variants import variants_kernel
from repro.data import synthetic
from repro.query import cases_containing, col, variant_in, variant_of
from repro.query.expr import VariantOf
from repro.storage import edf

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
M32 = 0xFFFFFFFF


def _case_sequences(frame):
    """{case_id: tuple(activity ids)} from an in-memory frame."""
    case = np.asarray(frame[CASE])
    act = np.asarray(frame[ACTIVITY])
    seqs = {}
    for c, a in zip(case.tolist(), act.tolist()):
        seqs.setdefault(c, []).append(a)
    return {c: tuple(a) for c, a in seqs.items()}


def _keep_frame(frame, keep_cases):
    mask = np.isin(np.asarray(frame[CASE]), np.asarray(sorted(keep_cases)))
    return ops.proj(frame, jnp.asarray(mask))


def _strip_sketch_band(path):
    """Rewrite an EDFV0003 file as if written before the sketch band:
    drop every group's ``sketch`` entry, keep the data blocks untouched
    (block offsets are relative to the header end, so a shorter header
    is fine)."""
    with open(path, "rb") as f:
        magic = f.read(8)
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        body = f.read()
    assert magic == edf.MAGIC_V3
    stripped = 0
    for g in header["groups"]:
        stripped += int("sketch" in g)
        g.pop("sketch", None)
    assert stripped > 0, "fixture file had no sketch band to strip"
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(magic)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        f.write(body)


def _variants_equal(got, ref, msg=""):
    g1, g2, gn = got
    r1, r2, rn = ref
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(r1), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(r2), err_msg=msg)
    assert int(gn) == int(rn), msg


# ---------------------------------------------------------------- units
def test_sketch_compose_matches_direct_fold():
    """Composing per-run affine maps across an arbitrary split reproduces
    the whole-sequence fingerprint — the identity the optimizer leans on
    when it stitches group sketches across boundaries."""
    rng = np.random.default_rng(5)
    for _ in range(20):
        seq = rng.integers(0, 50, rng.integers(1, 12)).tolist()
        cut = int(rng.integers(0, len(seq) + 1))
        fp1, fp2 = sequence_fingerprint(seq)
        for base, idx in ((BASE1, 0), (BASE2, 1)):
            parts = []
            for part in (seq[:cut], seq[cut:]):
                m, a = 1, 0
                for tok in part:
                    m, a = compose(m, a, base, (int(tok) + 1) & M32)
                parts.append((m, a))
            m, a = compose(*parts[0], *parts[1])
            # h_in = 0 for a fresh case, so the fingerprint is just `a`
            assert a == (fp1, fp2)[idx]


def test_segment_sketch_matches_sequence_fingerprint():
    act = np.array([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
    case = np.array([0, 0, 0, 1, 1, 2, 2, 2], np.int64)
    sk = segment_sketch(act, case)
    for i, seq in enumerate(([3, 1, 4], [1, 5], [9, 2, 6])):
        fp1, fp2 = sequence_fingerprint(seq)
        assert int(sk["add1"][i]) == fp1 and int(sk["add2"][i]) == fp2
        assert int(sk["mul1"][i]) == pow(BASE1, len(seq), 2**32)


def test_variant_of_unresolved_raises():
    pred = VariantOf(sequence=(1, 2, 3))
    with pytest.raises(RuntimeError, match="resolve"):
        pred.phase1_kernel(10)
    with pytest.raises(RuntimeError, match="resolve"):
        pred.finalize_keep(None)


# ------------------------------------------------------- predicate e2e
@pytest.fixture(scope="module")
def varlog(tmp_path_factory):
    frame, tables = synthetic.generate(num_cases=200, num_activities=6,
                                       seed=13)
    d = tmp_path_factory.mktemp("vs")
    p = str(d / "log.edf")
    edf.write(p, frame, tables, row_group_rows=117)
    return p, frame, tables


def test_variant_in_zero_phase_one_io(varlog):
    """A variant-band filter refutes groups from the header alone: rows
    read match the surviving variant exactly, and *no* phase-one pass
    runs (the sketch keeps resolve before any I/O)."""
    p, frame, tables = varlog
    seqs = _case_sequences(frame)
    target = seqs[7]
    fp = sequence_fingerprint(target)
    keep = {c for c, s in seqs.items() if s == target}
    ref_frame = _keep_frame(frame, keep)
    ref = engine.run_single(variants_kernel(200), ref_frame)

    r = repro.open(p).filter(variant_in([fp])).collect(
        "variants", engine="streaming")
    _variants_equal(r.result, ref, "variant_in streaming")
    assert r.report.groups_skipped > 0
    assert r.report.phase1_groups_read == 0
    # eager path resolves the same predicate against the whole frame
    e = repro.open(p).filter(variant_in([fp])).collect(
        "variants", engine="eager")
    _variants_equal(e.result, ref, "variant_in eager")


def test_variant_of_resolves_strings(varlog):
    """String sequences resolve against the file's dictionary table and
    select exactly the cases with that literal trace."""
    p, frame, tables = varlog
    seqs = _case_sequences(frame)
    target = seqs[3]
    names = tuple(tables[ACTIVITY][a] for a in target)
    keep = {c for c, s in seqs.items() if s == target}
    ref = engine.run_single(variants_kernel(200), _keep_frame(frame, keep))

    r = repro.open(p).filter(variant_of(names)).collect(
        "variants", engine="streaming")
    _variants_equal(r.result, ref, "variant_of strings")
    # integer ids resolve identically
    r2 = repro.open(p).filter(variant_of(target)).collect(
        "variants", engine="streaming")
    _variants_equal(r2.result, ref, "variant_of ids")


def test_variant_in_empty_band_refutes_everything(varlog):
    p, frame, _ = varlog
    r = repro.open(p).filter(variant_in([])).collect(
        "dfg", engine="streaming")
    assert r.report.groups_read == 0
    assert int(np.asarray(r.result.counts).sum()) == 0


# --------------------------------------------- version / layout parity
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mixed_versions_including_preband_v3(tmp_path, impl):
    """One v1, one v2, one pre-sketch-band v3, one current v3 file:
    pruned variants are bitwise the whole-frame reference, and pruning
    still fires (older files synthesize their sketches lazily on open)."""
    with backend.use_backend(impl):
        frame, tables = synthetic.generate(num_cases=120, num_activities=6,
                                           seed=29)
        case = np.asarray(frame[CASE])
        bounds = [0] + [int(np.searchsorted(case, c)) for c in
                        (30, 60, 90)] + [frame.nrows]
        paths = []
        for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
            version = (1, 2, 3, 3)[i]
            kw = {} if version == 1 else {"row_group_rows": 83}
            p = str(tmp_path / f"part{i}_v{version}.edf")
            edf.write(p, frame.take(jnp.arange(lo, hi)), tables,
                      version=version, **kw)
            paths.append(p)
        _strip_sketch_band(paths[2])        # v3 file from before the band

        ds = repro.open(paths)
        ref = engine.run_single(variants_kernel(120), frame)
        for eng in ("eager", "streaming"):
            got = ds.collect("variants", engine=eng)
            _variants_equal(got.result, ref, f"mixed/{impl}/{eng}")

        seqs = _case_sequences(frame)
        fp = sequence_fingerprint(seqs[95])   # lives in the pre-band file
        keep = {c for c, s in seqs.items() if s == seqs[95]}
        refk = engine.run_single(variants_kernel(120),
                                 _keep_frame(frame, keep))
        r = ds.filter(variant_in([fp])).collect("variants",
                                                engine="streaming")
        _variants_equal(r.result, refk, f"mixed-pruned/{impl}")
        assert r.report.groups_skipped > 0


def test_preband_v3_reader_synthesizes_sketch(tmp_path):
    """group_sketch on a stripped file decodes nothing from the header
    but still returns the exact sketch (synthesized under the lock),
    and repeated calls hit the cache."""
    frame, tables = synthetic.generate(num_cases=40, num_activities=5,
                                       seed=4)
    p = str(tmp_path / "old.edf")
    edf.write(p, frame, tables, row_group_rows=61)
    reader = edf.pooled_reader(p)
    want = [reader.group_sketch(g) for g in range(reader.num_groups)]
    _strip_sketch_band(p)
    old = edf.pooled_reader(p)
    assert old is not reader            # pool re-stats the rewritten file
    for g, sk in enumerate(want):
        got = old.group_sketch(g)
        assert got is old.group_sketch(g)       # cached
        for k in ("mul1", "add1", "mul2", "add2"):
            np.testing.assert_array_equal(got[k], sk[k], err_msg=f"g{g}/{k}")


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_one_row_groups_chunk_invariance(impl, tmp_path):
    """row_group_rows=1: every group is a single event, every case run
    is a boundary continuation.  Pruned variants still compose sketches
    exactly."""
    with backend.use_backend(impl):
        frame, tables = synthetic.generate(num_cases=12, num_activities=4,
                                           seed=8)
        p = str(tmp_path / "tiny.edf")
        edf.write(p, frame, tables, row_group_rows=1)
        ref = engine.run_single(variants_kernel(12), frame)
        got = repro.open(p).collect("variants", engine="streaming")
        _variants_equal(got.result, ref, f"1row/{impl}")

        seqs = _case_sequences(frame)
        fp = sequence_fingerprint(seqs[5])
        keep = {c for c, s in seqs.items() if s == seqs[5]}
        refk = engine.run_single(variants_kernel(12),
                                 _keep_frame(frame, keep))
        r = repro.open(p).filter(variant_in([fp])).collect(
            "variants", engine="streaming")
        _variants_equal(r.result, refk, f"1row-pruned/{impl}")
        assert r.report.groups_skipped > 0


def test_case_straddles_file_boundary_pruned_variants(tmp_path):
    """A case cut across two files is one case: its sketch composes over
    the boundary and the variant-band filter keeps (or refutes) the
    whole case, never half of it."""
    frame, tables = synthetic.generate(num_cases=60, num_activities=5,
                                       seed=11)
    case = np.asarray(frame[CASE])
    mid = int(np.searchsorted(case, 30)) + 2   # cut INSIDE case 30
    assert case[mid - 1] == case[mid] == 30
    p0, p1 = str(tmp_path / "a.edf"), str(tmp_path / "b.edf")
    edf.write(p0, frame.take(jnp.arange(0, mid)), tables, row_group_rows=53)
    edf.write(p1, frame.take(jnp.arange(mid, frame.nrows)), tables,
              row_group_rows=53)
    ds = repro.open([p0, p1])

    seqs = _case_sequences(frame)
    fp = sequence_fingerprint(seqs[30])        # the straddling case itself
    keep = {c for c, s in seqs.items() if s == seqs[30]}
    assert 30 in keep
    ref = engine.run_single(variants_kernel(60), _keep_frame(frame, keep))
    r = ds.filter(variant_in([fp])).collect("variants", engine="streaming")
    _variants_equal(r.result, ref, "straddle")


def test_sharded_pruned_variants_1_to_8(varlog):
    """Sharded variants == eager at 1..8 shards (8 virtual devices in a
    subprocess), with a pruning filter in front so skipped groups feed
    the shards ghost sketch rows instead of events."""
    p, _, _ = varlog
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import repro
from repro.query import col
from repro.core.eventframe import CASE

ds = repro.open({p!r}).filter((col(CASE) >= 40) & (col(CASE) <= 150))
ref = ds.collect("variants", engine="eager")
r1, r2, rn = ref.result
for shards in (1, 2, 4, 8):
    r = ds.collect("variants", engine="sharded", num_shards=shards)
    fp1, fp2, nc = r.result
    assert (np.asarray(fp1) == np.asarray(r1)).all(), shards
    assert (np.asarray(fp2) == np.asarray(r2)).all(), shards
    assert int(nc) == int(rn), shards
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    assert res.stdout.strip().endswith("OK")


# ------------------------------------------------- single-pass schedule
def test_single_pass_cases_containing_accounting(varlog, monkeypatch):
    """Data-dependent case predicates run as one fused scan: every group
    is touched at most once (phase-one reads and scan reads partition the
    groups actually read), results stay bitwise, and squeezing the buffer
    to one frame only shifts accounting, never results."""
    p, frame, _ = varlog
    seqs = _case_sequences(frame)
    keep = {c for c, s in seqs.items() if 4 in s}
    ref = engine.run_single(variants_kernel(200), _keep_frame(frame, keep))

    r = repro.open(p).filter(cases_containing(4)).collect(
        "variants", engine="streaming")
    _variants_equal(r.result, ref, "single-pass")
    rep = r.report
    assert rep.groups_read + rep.phase1_groups_read <= rep.groups_total
    assert rep.groups_read + rep.groups_skipped == rep.groups_total

    monkeypatch.setenv("REPRO_QUERY_SP_BUFFER", "1")
    r2 = repro.open(p).filter(cases_containing(4)).collect(
        "variants", engine="streaming")
    _variants_equal(r2.result, ref, "single-pass buffer=1")


def test_single_pass_restarts_idempotently(varlog):
    """Re-iterating the fused source (the facade re-runs the factory)
    resets accounting instead of double counting."""
    p, frame, _ = varlog
    ds = repro.open(p).filter(cases_containing(2))
    a = ds.collect("dfg", engine="streaming")
    b = ds.collect("dfg", engine="streaming")
    np.testing.assert_array_equal(np.asarray(a.result.counts),
                                  np.asarray(b.result.counts))
    assert a.report.groups_total == b.report.groups_total
    assert a.report.bytes_read == b.report.bytes_read
