"""Per-arch smoke tests (reduced configs): forward + one train step on CPU,
output shapes + no NaN — the deliverable-(f) requirement — plus
prefill/decode vs full-forward consistency and SSM chunked-vs-recurrent."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import model as Mdl
from repro.models.module import Initializer
from repro.train import trainstep as TS
from repro.train.optimizer import OptConfig

from helpers import LOCAL_RULES


def make(arch, seed=0):
    cfg = reduced_config(get_config(arch))
    params = Mdl.init_params(cfg, Initializer(jax.random.PRNGKey(seed)))
    return cfg, params


def frontends(cfg, B):
    if cfg.family == "vlm":
        return jax.random.normal(jax.random.PRNGKey(9),
                                 (B, cfg.num_patches, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        return jax.random.normal(jax.random.PRNGKey(9),
                                 (B, cfg.enc_seq, cfg.d_model)) * 0.1
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg, params = make(arch)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits = Mdl.forward(cfg, params, toks, rules=LOCAL_RULES,
                         frontend=frontends(cfg, B))
    expS = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, expS, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nan(arch):
    cfg, params = make(arch)
    B, S = 2, 16
    state = TS.init_state(cfg, params)
    step = jax.jit(TS.make_train_step(cfg, LOCAL_RULES, OptConfig(), 1))
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "targets": jnp.asarray(np.roll(toks, -1, 1)),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    fe = frontends(cfg, B)
    if fe is not None:
        batch["frontend"] = fe
        if cfg.family == "vlm":
            pass
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(state["params"]), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-moe-30b-a3b", "zamba2-7b",
                                  "xlstm-1.3b", "whisper-medium", "internvl2-2b",
                                  "gemma3-4b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy decode after prefill must equal argmax of the full forward —
    the strongest cache-correctness check we have.

    MoE archs get a large capacity factor: forward routes the full (S+extra)
    batch while prefill routes S tokens, so capacity-drop sets differ unless
    capacity is ample. Tolerance scales with logit magnitude (the KV cache is
    bf16; gemma3's tied-embedding logits have ~8x the scale of the others)."""
    cfg, params = make(arch)
    if cfg.num_experts:
        cfg = cfg.with_overrides(capacity_factor=16.0)
    B, S, extra = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0,
                              cfg.vocab_size)
    fe = frontends(cfg, B)
    # full forward logits at positions S-1 .. S+extra-1
    logits_full = Mdl.forward(cfg, params, toks, rules=LOCAL_RULES, frontend=fe)
    off = cfg.num_patches if cfg.family == "vlm" else 0
    atol = 3e-3 * max(1.0, float(jnp.std(logits_full)))
    lg, cache = Mdl.prefill(cfg, params, toks[:, :S], rules=LOCAL_RULES, frontend=fe)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, off + S - 1]),
                               atol=atol)
    # grow caches then feed the true next tokens; logits must keep matching
    for k in ("k", "v"):
        if k in cache:
            pad = [(0, 0)] * cache[k].ndim
            pad[2] = (0, extra + 1)
            cache[k] = jnp.pad(cache[k], pad)
    for t in range(extra):
        lg, cache = Mdl.decode_step(cfg, params, cache, toks[:, S + t:S + t + 1],
                                    rules=LOCAL_RULES)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, off + S + t]), atol=atol)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-4b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 34
    assert kinds[5] == 0 and kinds[11] == 0          # global every 6th
    assert sum(1 for k in kinds if k == 0) == 5      # 5 global layers in 34
    assert kinds[0] == kinds[1] == 1                 # locals elsewhere


def test_param_counts_match_scale():
    """Analytic param counts are in the right ballpark for the named scales."""
    expect = {"yi-6b": (5e9, 8e9), "phi3-mini-3.8b": (3e9, 5e9),
              "deepseek-67b": (55e9, 75e9), "mixtral-8x7b": (40e9, 55e9),
              "gemma3-4b": (3e9, 6e9), "xlstm-1.3b": (0.8e9, 2e9),
              "qwen3-moe-30b-a3b": (25e9, 36e9), "internvl2-2b": (1.5e9, 3e9),
              # our whisper uses SwiGLU (3-matrix) MLPs vs the original's
              # GELU (2-matrix): ~1.0B analytic vs 769M original — expected
              "whisper-medium": (0.25e9, 1.2e9), "zamba2-7b": (5e9, 9e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_moe_capacity_drop_and_combine():
    """MoE keeps top-k mass: with huge capacity no tokens drop, output is a
    convex combination of expert outputs."""
    from repro.models import layers as L
    cfg = reduced_config(get_config("qwen3-moe-30b-a3b")).with_overrides(
        capacity_factor=8.0)
    init = Initializer(jax.random.PRNGKey(0))
    p = L.moe_init(init, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y = L.moe_apply(p, x, cfg, LOCAL_RULES)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
