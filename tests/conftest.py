import os

# Tests must see exactly ONE CPU device (the 512-device flag is set only
# inside launch/dryrun.py and subprocess-based tests).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

assert len(jax.devices()) >= 1
