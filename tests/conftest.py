import os

# Tests must see exactly ONE CPU device (the 512-device flag is set only
# inside launch/dryrun.py and subprocess-based tests).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

assert len(jax.devices()) >= 1


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables():
    """Release jitted executables after each test module.

    The full suite compiles thousands of distinct (kernel, chunk-shape)
    programs; every live CPU executable holds mmap'd JIT code pages, and
    one process accumulating all of them can exhaust ``vm.max_map_count``
    (default 65530) and die in a compile-time segfault long before it
    runs out of memory.  Per-module cache clearing keeps the map count
    bounded; retracing in later modules is cheap relative to that."""
    yield
    jax.clear_caches()
