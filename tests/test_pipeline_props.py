"""Data pipeline + variants/stats/conformance property tests (hypothesis)."""
import numpy as np
import jax.numpy as jnp
from _prop import given, settings, strategies as st

from repro.core import ACTIVITY, CASE, TIMESTAMP, conformance, dfg, stats, variants
from repro.core import ops
from repro.data import pipeline, synthetic, tokenizer

from helpers import random_log, sorted_frame


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), n_cases=st.integers(1, 40))
def test_stream_structure(seed, n_cases):
    frame, tables = synthetic.generate(num_cases=n_cases, num_activities=8,
                                       seed=seed)
    tok = tokenizer.ActivityTokenizer(tables[ACTIVITY])
    stream = pipeline.frame_to_token_stream(frame, tok)
    # one BOS and one EOS per case; activities survive the round trip
    assert (stream == tokenizer.BOS).sum() == n_cases
    assert (stream == tokenizer.EOS).sum() == n_cases
    assert len(stream) == frame.nrows + 2 * n_cases
    body = stream[stream >= tokenizer.NUM_SPECIALS] - tokenizer.NUM_SPECIALS
    np.testing.assert_array_equal(body, np.asarray(frame[ACTIVITY]))


def test_host_sharding_partition():
    frame, tables = synthetic.generate(num_cases=100, num_activities=6, seed=1)
    tok = tokenizer.ActivityTokenizer(tables[ACTIVITY])
    full = pipeline.frame_to_token_stream(frame, tok)
    parts = [pipeline.frame_to_token_stream(frame, tok, h, 4) for h in range(4)]
    # partitions cover all events exactly once
    n_events = sum((p >= tokenizer.NUM_SPECIALS).sum() for p in parts)
    assert n_events == (full >= tokenizer.NUM_SPECIALS).sum()


def test_batches_next_token_alignment():
    stream = np.arange(3, 300, dtype=np.int32)
    for b in pipeline.batches(stream, 4, 16):
        flat_x = b.tokens.reshape(-1)
        flat_y = b.targets.reshape(-1)
        np.testing.assert_array_equal(flat_y[:-1], flat_x[1:])


def test_variants_distinguish_and_group():
    rng = np.random.default_rng(2)
    log = random_log(rng, n_cases=30, n_acts=4, max_len=6)
    frame, tables = sorted_frame(log)
    counts = variants.variant_counts(frame)
    # number of variant classes == number of distinct activity sequences
    seqs = {}
    for cid, idxs in log.case_ev().items():
        seqs.setdefault(tuple(log.act(i) for i in idxs), 0)
    assert len(counts) == len(seqs)
    assert sum(counts.values()) == len(log.case_ids)


def test_case_stats():
    rng = np.random.default_rng(3)
    log = random_log(rng, n_cases=12, n_acts=4)
    frame, tables = sorted_frame(log)
    sizes = np.asarray(stats.case_sizes(frame, 12))
    ref = {cid: len(ix) for cid, ix in log.case_ev().items()}
    # case ids are dictionary-encoded in order of first appearance; compare sorted multisets
    assert sorted(sizes.tolist()) == sorted(ref.values())
    durs = np.asarray(stats.case_durations(frame, 12))
    assert (durs >= 0).all()


def test_conformance_detects_deviation():
    rng = np.random.default_rng(4)
    log = random_log(rng, n_cases=20, n_acts=5)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    d = dfg(frame, a)
    model = conformance.discover_model(d)
    assert float(conformance.footprint_fitness(d, model)) == 1.0
    # forbid the most frequent edge -> fitness drops accordingly
    c = np.asarray(d.counts)
    i, j = np.unravel_index(c.argmax(), c.shape)
    model2 = np.asarray(model).copy()
    model2[i, j] = False
    fit = float(conformance.footprint_fitness(d, jnp.asarray(model2)))
    assert abs(fit - (1 - c[i, j] / c.sum())) < 1e-5
    dev = conformance.footprint_deviations(d, jnp.asarray(model2))
    assert int(np.asarray(dev)[i, j]) == int(c[i, j])


def test_sojourn_times_positive():
    rng = np.random.default_rng(5)
    log = random_log(rng, n_cases=15, n_acts=4)
    frame, tables = sorted_frame(log)
    s = np.asarray(stats.sojourn_times(frame, len(tables[ACTIVITY])))
    assert (s >= 0).all()
