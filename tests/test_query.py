"""Lazy query subsystem: zone-map pushdown, pruned-scan parity, EDFV0003.

The load-bearing invariant: for every supported predicate,
``execute(plan, mine=K)`` over an EDF file is **bitwise equal** to
``K(filter(read(path)))`` — while a selective predicate provably reads
fewer bytes (skip ratio > 0, asserted against the file_sizes accounting).
Plus the satellite regressions: ``filter_time_range`` validity,
``file_sizes`` totals, most-common-activity tie-breaking, filter
composition under both segment backends.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACTIVITY, CASE, TIMESTAMP, ChunkedEventFrame,
                        EventFrame, backend, engine, filtering, ops,
                        run_streaming)
from repro.core.dfg import dfg_kernel
from repro.core.discovery import discovery_kernel
from repro.core.performance import eventually_follows_kernel
from repro.core.stats import (activity_counts_kernel, case_durations_kernel,
                              case_sizes_kernel, sojourn_times_kernel)
from repro.core.variants import variants_kernel
from repro.data import synthetic
from repro.query import (Plan, case_size, cases_containing, col,
                         compile_plan, execute, execute_frame, pruned_source)
from repro.storage import edf


@pytest.fixture(scope="module")
def log(tmp_path_factory):
    """One v3 file + the loaded whole frame, shared by the parity tests."""
    frame, tables = synthetic.generate(num_cases=300, num_activities=8,
                                       seed=21)
    path = str(tmp_path_factory.mktemp("q") / "log.edf")
    edf.write(path, frame, tables, row_group_rows=199)
    whole, _ = edf.read(path)
    ncases = compile_plan(Plan(path)).num_cases
    return path, whole, ncases


def _assert_tree_equal(a, b, msg=""):
    import jax

    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=msg)


# --------------------------------------------------------------- EDFV0003
def test_v3_header_zones_segments_tail(tmp_path):
    frame = EventFrame.from_numpy(
        {CASE: np.array([0, 0, 1, 1, 2], np.int32),
         ACTIVITY: np.array([3, 1, 1, 2, 0], np.int32),
         TIMESTAMP: np.array([1., 2., 3., 4., 5.], np.float32)},
        {TIMESTAMP: np.array([True, False, True, True, True])})
    p = str(tmp_path / "z.edf")
    header = edf.write(p, frame, {ACTIVITY: list("abcd")}, row_group_rows=3)
    assert header["version"] == 3
    with open(p, "rb") as f:
        assert f.read(8) == edf.MAGIC_V3
    g0, g1 = header["groups"]
    assert g0["segments"] == 2 and g1["segments"] == 2
    z0 = g0["zones"]
    assert z0[ACTIVITY]["min"] == 1 and z0[ACTIVITY]["max"] == 3
    assert z0[TIMESTAMP]["nulls"] == 1
    assert g1["zones"][TIMESTAMP]["nulls"] == 0
    bits = np.unpackbits(np.frombuffer(
        bytes.fromhex(z0[ACTIVITY]["bits"]), np.uint8))
    np.testing.assert_array_equal(bits[:4], [False, True, False, True])
    assert g0["tail"]["values"][CASE] == 1
    assert g1["tail"]["values"][ACTIVITY] == 0
    assert g0["tail"]["valid"][TIMESTAMP] is True
    # the file still round-trips through every reader entry point
    f2, t2 = edf.read(p)
    for k in frame.names:
        np.testing.assert_array_equal(np.asarray(frame[k]), np.asarray(f2[k]))
    np.testing.assert_array_equal(np.asarray(frame.valid[TIMESTAMP]),
                                  np.asarray(f2.valid[TIMESTAMP]))
    assert [fr.nrows for fr, _ in edf.read_streaming(p)] == [3, 2]


@pytest.mark.parametrize("version", [1, 2, 3])
def test_file_sizes_total_equals_getsize(tmp_path, version):
    """Satellite: totals must equal the bytes on disk, with a per-group
    breakdown whose nbytes tile the data section."""
    frame, tables = synthetic.generate(num_cases=80, num_activities=5, seed=2)
    p = str(tmp_path / f"s{version}.edf")
    kw = {"row_group_rows": 137} if version >= 2 else {}
    edf.write(p, frame, tables, version=version, **kw)
    sizes = edf.file_sizes(p)
    assert sizes["total"] == os.path.getsize(p)
    assert sizes["header"] > 0
    groups = sizes["groups"]
    assert len(groups) == edf.num_row_groups(p)
    assert sum(g["nbytes"] for g in groups) == sizes["total"] - sizes["header"]
    assert sum(g["nrows"] for g in groups) == frame.nrows
    # per-group per-column bytes agree with the reader's accounting
    reader = edf.EDFReader(p)
    for i, g in enumerate(groups):
        assert g["nbytes"] == reader.group_nbytes(i)
        assert reader.group_nbytes(i, [CASE]) == g["columns"][CASE]


def test_reader_synthesizes_metadata_for_v2(tmp_path):
    frame, tables = synthetic.generate(num_cases=60, num_activities=6, seed=5)
    p = str(tmp_path / "v2.edf")
    edf.write(p, frame, tables, row_group_rows=101, version=2)
    reader = edf.EDFReader(p)
    meta = reader.group_meta(0)
    assert {"zones", "segments", "tail"} <= set(meta)
    case0 = np.asarray(frame[CASE])[:101]
    assert meta["zones"][CASE]["min"] == int(case0.min())
    assert meta["segments"] == len(np.unique(case0))
    assert meta["tail"]["values"][CASE] == int(case0[-1])


# -------------------------------------------------------- pruning parity
def _reference(whole, ncases, name):
    """The eager filter chain each plan's executor must match bitwise."""
    ts_lo, ts_hi = 3e5, 7e5
    if name == "isin":
        return filtering.filter_attr_values(whole, ACTIVITY, [2, 5])
    if name == "not_isin":
        return filtering.filter_attr_values(whole, ACTIVITY, [2, 5],
                                            keep=False)
    if name == "eq_case_band":
        c = whole[CASE]
        return ops.proj(whole, (c >= 90) & (c <= 140))
    if name == "time_range":
        return filtering.filter_time_range(whole, TIMESTAMP, ts_lo, ts_hi)
    if name == "bool_combo":
        c, a = whole[CASE], whole[ACTIVITY]
        return ops.proj(whole, ((c <= 60) | (c >= 250)) & ~(a == 3))
    if name == "contains":
        return filtering.filter_cases_containing(whole, 4, ncases)
    if name == "case_size":
        return filtering.filter_case_size(whole, 3, 7, ncases)
    if name == "chain":
        f = filtering.filter_attr_values(whole, ACTIVITY, [1, 2, 4, 6])
        f = filtering.filter_cases_containing(f, 4, ncases)
        return filtering.filter_time_range(f, TIMESTAMP, ts_lo, ts_hi)
    raise KeyError(name)


def _plan(path, name):
    ts_lo, ts_hi = 3e5, 7e5
    p = Plan(path)
    if name == "isin":
        return p.filter(col(ACTIVITY).isin([2, 5]))
    if name == "not_isin":
        return p.filter(~col(ACTIVITY).isin([2, 5]))
    if name == "eq_case_band":
        return p.filter((col(CASE) >= 90) & (col(CASE) <= 140))
    if name == "time_range":
        return p.filter(col(TIMESTAMP).between(ts_lo, ts_hi))
    if name == "bool_combo":
        return p.filter(((col(CASE) <= 60) | (col(CASE) >= 250))
                        & ~(col(ACTIVITY) == 3))
    if name == "contains":
        return p.filter(cases_containing(4))
    if name == "case_size":
        return p.filter(case_size(3, 7))
    if name == "chain":
        return (p.filter(col(ACTIVITY).isin([1, 2, 4, 6]))
                .filter(cases_containing(4))
                .filter(col(TIMESTAMP).between(ts_lo, ts_hi)))
    raise KeyError(name)


PREDICATES = ["isin", "not_isin", "eq_case_band", "time_range", "bool_combo",
              "contains", "case_size", "chain"]


@pytest.mark.parametrize("pred", PREDICATES)
def test_execute_matches_filter_then_mine(log, pred):
    path, whole, ncases = log
    ref_frame = _reference(whole, ncases, pred)
    plan = _plan(path, pred)
    kernels = {
        "dfg": dfg_kernel(8),
        "acts": activity_counts_kernel(8),
        "sizes": case_sizes_kernel(ncases),
        "durs": case_durations_kernel(ncases),
        "sojourn": sojourn_times_kernel(8),
        "efg": eventually_follows_kernel(8),
        "discovery": discovery_kernel(8),
        "variants": variants_kernel(ncases),
    }
    for kname, kernel in kernels.items():
        got, report = execute(plan, mine=kernel)
        ref = engine.run_single(kernel, ref_frame)
        _assert_tree_equal(got, ref, f"{pred}/{kname}")
        # pruning never over-reads relative to the full scan
        assert report.bytes_read <= report.bytes_total, (pred, kname)


def test_selective_predicate_skips_bytes(log):
    """Zone-map parity proof: the pruned scan reads strictly fewer bytes
    than the full scan on a selective predicate, same bitwise result."""
    path, whole, ncases = log
    plan = Plan(path).filter(col(CASE).between(90, 140))
    pruned, rep = execute(plan, mine=dfg_kernel(8))
    full, rep_full = execute(plan, mine=dfg_kernel(8), prune=False)
    _assert_tree_equal(pruned, full, "pruned vs full")
    assert rep.groups_skipped > 0
    assert rep_full.groups_skipped == 0
    assert rep.bytes_read < rep_full.bytes_read
    assert rep.bytes_total == rep_full.bytes_read  # full scan == every byte
    assert 0.0 < rep.skip_ratio <= 1.0
    assert rep.bytes_saved_ratio > 0.0


def test_refuted_everything_yields_empty_result(log):
    path, whole, ncases = log
    plan = Plan(path).filter(col(ACTIVITY) >= 100)   # impossible
    got, rep = execute(plan, mine=dfg_kernel(8))
    assert rep.groups_read == 0 and rep.bytes_read == 0
    assert int(np.asarray(got.counts).sum()) == 0
    assert int(np.asarray(got.starts).sum()) == 0


def test_variants_prunes_via_header_sketches(log):
    """Variants hash masked rows, yet the pruned scan skips refuted groups
    — the ghost chunks replay their hashes from the header sketch maps."""
    path, whole, ncases = log
    plan = Plan(path).filter(col(CASE).between(90, 140))
    got, rep = execute(plan, mine=variants_kernel(ncases))
    assert rep.groups_skipped > 0               # no degradation cliff
    c = whole[CASE]
    ref_frame = ops.proj(whole, (c >= 90) & (c <= 140))
    _assert_tree_equal(got, engine.run_single(variants_kernel(ncases),
                                              ref_frame))


def test_unpruned_stream_masks_refuted_groups(log):
    """Regression: a group the zone maps refute can still be *read* (an
    explicit mask_exact=False source forces a full read) — its refuting
    predicate must then be applied as a residual mask, not dropped."""
    path, whole, ncases = log
    plan = Plan(path).filter(col(CASE).between(90, 140))
    src, rep = pruned_source(plan, mask_exact=False)
    assert rep.groups_skipped == 0
    got = run_streaming(dfg_kernel(8), src)
    c = whole[CASE]
    ref = engine.run_single(dfg_kernel(8),
                            ops.proj(whole, (c >= 90) & (c <= 140)))
    _assert_tree_equal(got, ref, "mask_exact=False stream")
    # composed kernel containing variants stays pruning-exact: its
    # ghost_sketch flag propagates, and the fused scan still skips
    comp = engine.compose({"v": variants_kernel(ncases), "d": dfg_kernel(8)})
    assert comp.mask_exact and comp.ghost_sketch
    got2, rep2 = execute(plan, mine=comp)
    ref2 = engine.run_single(comp, ops.proj(whole, (c >= 90) & (c <= 140)))
    _assert_tree_equal(got2, ref2, "compose(variants, dfg)")
    assert rep2.groups_skipped > 0


def test_cases_containing_custom_column(log):
    """Regression: cases_containing(value, column=...) must test the named
    column, read it in phase one, and prune by its zones."""
    path, whole, ncases = log
    got, rep = execute(Plan(path).filter(cases_containing(500, column="attr0")),
                       mine=dfg_kernel(8))
    case = np.asarray(whole[CASE])
    hit_cases = np.unique(case[np.asarray(whole["attr0"]) == 500])
    ref = engine.run_single(dfg_kernel(8),
                            ops.proj(whole, jnp.asarray(np.isin(case, hit_cases))))
    _assert_tree_equal(got, ref, "contains on attr0")


def test_execute_frame_all_groups_refuted(log):
    path, whole, ncases = log
    frame, tables, rep = execute_frame(
        Plan(path).filter(col(ACTIVITY) >= 100).project([CASE]))
    assert frame.nrows == 0 and set(frame.names) == {CASE}
    assert ACTIVITY not in tables      # projection filters the tables too
    assert rep.groups_read == 0


def test_projection_pushdown_reads_fewer_columns(log):
    path, whole, ncases = log
    plan = Plan(path).filter(col(ACTIVITY).isin([2])).project(
        [CASE, ACTIVITY])
    _, rep = execute(plan, mine=dfg_kernel(8))
    reader = edf.EDFReader(path)
    all_cols = sum(reader.group_nbytes(g) for g in range(reader.num_groups))
    assert rep.bytes_total < all_cols          # projected scan < full width
    assert set(rep.columns) == {CASE, ACTIVITY}


def test_execute_frame_matches_compact(log):
    path, whole, ncases = log
    plan = (Plan(path).filter(col(CASE).between(90, 140))
            .project([CASE, ACTIVITY]))
    frame, tables, rep = execute_frame(plan)
    c = whole[CASE]
    ref = ops.proj(whole, (c >= 90) & (c <= 140)).compact()
    np.testing.assert_array_equal(np.asarray(frame[CASE]), np.asarray(ref[CASE]))
    np.testing.assert_array_equal(np.asarray(frame[ACTIVITY]),
                                  np.asarray(ref[ACTIVITY]))
    assert set(frame.names) == {CASE, ACTIVITY}
    assert rep.groups_skipped > 0
    assert ACTIVITY in tables


@pytest.mark.parametrize("version", [1, 2])
def test_older_versions_prune_via_synthesized_zones(tmp_path, log, version):
    path, whole, ncases = log
    p = str(tmp_path / f"old{version}.edf")
    kw = {"row_group_rows": 199} if version == 2 else {}
    edf.write(p, whole, edf.EDFReader(path).tables, version=version, **kw)
    plan = Plan(p).filter(col(CASE).between(90, 140))
    got, rep = execute(plan, mine=dfg_kernel(8))
    c = whole[CASE]
    ref_frame = ops.proj(whole, (c >= 90) & (c <= 140))
    ref = engine.run_single(dfg_kernel(8), ref_frame)
    _assert_tree_equal(got, ref, f"v{version}")
    if version == 2:
        assert rep.groups_skipped > 0      # zones synthesized on open
    # variant sketches synthesize on open too: older files prune variants
    gv, rv = execute(plan, mine=variants_kernel(ncases))
    _assert_tree_equal(gv, engine.run_single(variants_kernel(ncases),
                                             ref_frame), f"v{version} variants")
    if version == 2:
        assert rv.groups_skipped > 0


def test_pruned_source_feeds_streaming_engine(log):
    path, whole, ncases = log
    src, rep = pruned_source(Plan(path).filter(col(CASE) <= 75))
    got = run_streaming(dfg_kernel(8), src)
    ref = engine.run_single(dfg_kernel(8), ops.proj(whole, whole[CASE] <= 75))
    _assert_tree_equal(got, ref)
    # re-iterable: a second pass yields the same result
    _assert_tree_equal(run_streaming(dfg_kernel(8), src), ref)


def test_case_predicate_accepts_decoded_activity_name(log):
    path, whole, ncases = log
    table = edf.EDFReader(path).tables[ACTIVITY]
    got, _ = execute(Plan(path).filter(cases_containing(table[4])),
                     mine=dfg_kernel(8))
    ref = engine.run_single(dfg_kernel(8),
                            filtering.filter_cases_containing(whole, 4, ncases))
    _assert_tree_equal(got, ref)


def test_plan_describe_and_unknown_column(log):
    path, _, _ = log
    plan = Plan(path).filter(col(ACTIVITY) == 1).project([CASE, ACTIVITY])
    assert "scan" in plan.describe() and "project" in plan.describe()
    with pytest.raises(KeyError):
        execute(Plan(path).filter(col("nope") == 1), mine=dfg_kernel(8))
    with pytest.raises(TypeError):
        Plan(path).filter("not a predicate")


def test_float32_constant_never_refutes_matching_rows(tmp_path):
    """Regression: zone proofs compare in binary64, masks in the column's
    float32 — a constant like 0.1 must be snapped to the column dtype so
    a proof can never skip a group whose rows the mask would keep."""
    ts = np.array([np.float32(0.1), 0.5, 0.9], np.float32)
    frame = EventFrame.from_numpy({
        CASE: np.arange(3, dtype=np.int32),
        ACTIVITY: np.zeros(3, np.int32), TIMESTAMP: ts})
    p = str(tmp_path / "f32.edf")
    edf.write(p, frame, {ACTIVITY: ["a"]}, row_group_rows=1)
    for pred in (col(TIMESTAMP) <= 0.1, col(TIMESTAMP).between(0.05, 0.1),
                 col(TIMESTAMP) == 0.1):
        got, rep = execute(Plan(p).filter(pred), mine=activity_counts_kernel(1))
        full, _ = execute(Plan(p).filter(pred), mine=activity_counts_kernel(1),
                          prune=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(full))
        assert int(np.asarray(got)[0]) == 1, pred   # float32(0.1) row kept


# ------------------------------------------------------ satellite fixes
def test_filter_time_range_respects_validity():
    """Regression: an epsilon cell whose sentinel falls inside [lo, hi]
    must not survive the range filter."""
    frame = EventFrame.from_numpy(
        {CASE: np.zeros(3, np.int32),
         ACTIVITY: np.arange(3, dtype=np.int32),
         TIMESTAMP: np.array([1.0, 5.0, 9.0], np.float32)},
        {TIMESTAMP: np.array([True, False, True])})
    out = filtering.filter_time_range(frame, TIMESTAMP, 4.0, 6.0)
    np.testing.assert_array_equal(np.asarray(out.rows_valid()),
                                  [False, False, False])
    out2 = filtering.filter_time_range(frame, TIMESTAMP, 0.0, 10.0)
    np.testing.assert_array_equal(np.asarray(out2.rows_valid()),
                                  [True, False, True])


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_streaming_most_common_activity_tie_break(impl):
    """argmax tie-breaking: the lowest activity id wins, streaming ==
    whole-log, under both segment backends."""
    with backend.use_backend(impl):
        acts = np.array([4, 1, 4, 1, 2, 1, 4, 0], np.int32)  # 1 and 4 tie
        frame = EventFrame.from_numpy({
            CASE: np.zeros(len(acts), np.int32), ACTIVITY: acts,
            TIMESTAMP: np.arange(len(acts), dtype=np.float32)})
        whole = int(filtering.most_common_activity(frame, 6))
        for cuts in ([3], [1, 2, 5], list(range(1, len(acts)))):
            src = ChunkedEventFrame.from_cuts(frame, cuts)
            assert filtering.streaming_most_common_activity(src, 6) == whole
        assert whole == 1          # ties resolve to the smallest id


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_filter_composition_projection_chunk_invariance(impl):
    """filter_attr_values o filter_case_size on a column-projected frame:
    any chunking of the streamed two-phase pipeline matches the whole-log
    chain bitwise, on both segment backends."""
    with backend.use_backend(impl):
        frame, tables = synthetic.generate(num_cases=40, num_activities=6,
                                           seed=17)
        proj = frame.select([CASE, ACTIVITY])
        nc = 40
        ref = filtering.filter_case_size(
            filtering.filter_attr_values(proj, ACTIVITY, [1, 3, 5]),
            2, 6, nc)
        rng = np.random.default_rng(0)
        for trial in range(3):
            cuts = sorted(rng.integers(1, proj.nrows, size=5).tolist())
            base = ChunkedEventFrame.from_cuts(proj, cuts)
            masked = ChunkedEventFrame(
                lambda: (filtering.filter_attr_values(ch, ACTIVITY, [1, 3, 5])
                         for ch in base),
                num_chunks=base.num_chunks)
            keep = filtering.streaming_case_size_keep(masked, 2, 6, nc)
            got = np.concatenate(
                [np.asarray(ch.rows_valid()) for ch in
                 filtering.stream_apply_case_mask(masked, keep)])
            np.testing.assert_array_equal(got, np.asarray(ref.rows_valid()),
                                          err_msg=f"{impl}/cuts={cuts}")
