"""The live mining service: append path, ingestor, query server.

Covers the tentpole end to end — atomic ``edf.append`` (old groups
byte-identical, state cache hot), the crash-safe :class:`Ingestor`, and
the snapshot-consistent :class:`MiningService` — plus the satellite
regressions: pooled readers reopen under append (a second ``collect``
sees the new groups), result memoization survives a forced stat
collision (same size, same mtime_ns, different bytes), and the
mined-while-ingesting parity drill: every concurrently-returned result
bitwise equal to re-mining the snapshot it claims.
"""
import dataclasses
import json
import os
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from helpers import random_log, sorted_frame

import repro
from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from repro.dataset import engines as ds_engines
from repro.query.statecache import state_cache
from repro.service import (Ingestor, MiningService, ServiceError, serve,
                           to_jsonable)
from repro.service import ingest as ingest_mod
from repro.storage import edf

N_ACTS, N_CASES = 5, 40


def _fresh():
    state_cache().clear()
    ds_engines.clear_result_cache()


def _slice(frame, a, b):
    return EventFrame({k: v[a:b] for k, v in frame.columns.items()},
                      {k: v[a:b] for k, v in frame.valid.items()},
                      frame.rows_valid()[a:b])


def _case_cuts(frame, per):
    """Row offsets cutting ``frame`` on case boundaries every ``per``
    cases (batches stay case-aligned, like a real ingest feed)."""
    case = np.asarray(frame.columns[CASE])
    bounds = np.flatnonzero(case[1:] != case[:-1]) + 1
    cuts = [0] + [int(bounds[i]) for i in range(per - 1, len(bounds), per)]
    if cuts[-1] != frame.nrows:
        cuts.append(frame.nrows)
    return cuts


@pytest.fixture()
def log():
    rng = np.random.default_rng(11)
    return sorted_frame(random_log(rng, n_cases=N_CASES, n_acts=N_ACTS,
                                   max_len=8))


def _jeq(a, b):
    return json.dumps(to_jsonable(a)) == json.dumps(to_jsonable(b))


# ------------------------------------------------------------ append path
def test_append_roundtrip_and_signature_stability(tmp_path, log):
    frame, tables = log
    cut = _case_cuts(frame, N_CASES // 2)[1]
    p = str(tmp_path / "log.edf")
    edf.write(p, _slice(frame, 0, cut), tables, version=3, row_group_rows=17)
    r0 = edf.EDFReader(p)
    sigs0 = [r0.group_signature(g) for g in range(r0.num_groups)]
    edf.append(p, _slice(frame, cut, frame.nrows), tables, row_group_rows=17)
    r1 = edf.EDFReader(p)
    assert r1.num_groups > len(sigs0)
    # old groups' content signatures survive the append untouched
    assert [r1.group_signature(g) for g in range(len(sigs0))] == sigs0
    got, got_tables = edf.read(p)
    for name in frame.names:
        assert np.array_equal(np.asarray(got.columns[name]),
                              np.asarray(frame.columns[name])), name
    assert got_tables == {k: list(v) for k, v in tables.items()}
    # the file signature moved in all three components' terms: content tag
    assert r1._sig != r0._sig and r1._sig[2] != r0._sig[2]


def test_append_atomic_when_replace_fails(tmp_path, log, monkeypatch):
    frame, tables = log
    p = str(tmp_path / "log.edf")
    cut = _case_cuts(frame, N_CASES // 2)[1]
    edf.write(p, _slice(frame, 0, cut), tables, version=3, row_group_rows=17)
    before = open(p, "rb").read()

    def boom(src, dst):
        raise OSError("disk full")

    monkeypatch.setattr(edf.os, "replace", boom)
    with pytest.raises(OSError):
        edf.append(p, _slice(frame, cut, frame.nrows), tables)
    monkeypatch.undo()
    # nothing landed, nothing torn, no temp litter
    assert open(p, "rb").read() == before
    assert [n for n in os.listdir(tmp_path) if ".tmp" in n] == []
    got, _ = edf.read(p)
    assert got.nrows == cut


def test_append_validates_schema_and_order(tmp_path, log):
    frame, tables = log
    p = str(tmp_path / "log.edf")
    cut = _case_cuts(frame, N_CASES // 2)[1]
    edf.write(p, _slice(frame, 0, cut), tables, version=3)
    tail = _slice(frame, cut, frame.nrows)
    with pytest.raises(ValueError, match="case"):
        edf.append(p, _slice(frame, 0, cut), tables)    # reopens case 0
    with pytest.raises(ValueError, match="columns"):
        edf.append(p, tail.select([CASE, ACTIVITY]), tables)
    bad = EventFrame({**{k: np.asarray(v) for k, v in tail.columns.items()},
                      TIMESTAMP: np.asarray(tail.columns[TIMESTAMP],
                                            np.float64)}, dict(tail.valid))
    with pytest.raises(ValueError, match="dtype"):
        edf.append(p, bad, tables)
    with pytest.raises(ValueError, match="dictionary table"):
        edf.append(p, tail, {ACTIVITY: ["x", "y"]})     # not an extension
    # a v1 file refuses appends
    p1 = str(tmp_path / "v1.edf")
    edf.write(p1, _slice(frame, 0, cut), tables, version=1)
    with pytest.raises(ValueError, match="v1"):
        edf.append(p1, tail, tables)
    # zero-row appends are a no-op
    before = open(p, "rb").read()
    edf.append(p, _slice(frame, 0, 0), tables)
    assert open(p, "rb").read() == before


def test_append_keeps_state_cache_hot(tmp_path, log):
    frame, tables = log
    _fresh()
    p = str(tmp_path / "log.edf")
    cut = _case_cuts(frame, N_CASES // 2)[1]
    edf.write(p, _slice(frame, 0, cut), tables, version=3, row_group_rows=17)
    old_groups = edf.num_row_groups(p)
    ds = repro.open(p, num_cases=N_CASES)       # pinned capacity: the spec
    ds.collect("dfg", engine="streaming")       # fingerprint stays stable
    edf.append(p, _slice(frame, cut, frame.nrows), tables, row_group_rows=17)
    res = ds.collect("dfg", engine="streaming")
    # only the appended groups were decoded; the old ones merged from cache
    assert res.report.groups_cached == old_groups
    assert res.report.groups_folded == edf.num_row_groups(p) - old_groups
    scratch = repro.open(frame, tables=tables,
                         num_cases=N_CASES).collect("dfg", engine="eager")
    assert _jeq(res.result, scratch.result)


# ------------------------------------- satellite 1: staleness under append
def test_second_collect_sees_appended_groups(tmp_path, log):
    frame, tables = log
    _fresh()
    p = str(tmp_path / "log.edf")
    cut = _case_cuts(frame, N_CASES // 2)[1]
    edf.write(p, _slice(frame, 0, cut), tables, version=3, row_group_rows=17)
    ds = repro.open(p)                          # one handle, used twice
    first = ds.collect("activity_counts", engine="streaming")
    edf.append(p, _slice(frame, cut, frame.nrows), tables, row_group_rows=17)
    second = ds.collect("activity_counts", engine="streaming")
    assert second.report.groups_total > first.report.groups_total
    scratch = repro.open(frame, tables=tables).collect("activity_counts",
                                                       engine="eager")
    assert _jeq(second.result, scratch.result)
    assert not _jeq(first.result, second.result)


def test_stale_reader_fails_loudly_and_pin_holds_snapshot(tmp_path, log):
    frame, tables = log
    p = str(tmp_path / "log.edf")
    cut = _case_cuts(frame, N_CASES // 2)[1]
    edf.write(p, _slice(frame, 0, cut), tables, version=3, row_group_rows=17)
    stale = edf.EDFReader(p)
    stale.read_group(0)
    pinned = edf.EDFReader(p)
    with pinned.pin():
        edf.append(p, _slice(frame, cut, frame.nrows), tables)
        # an evicted (closed) stale reader refuses to decode the new bytes
        stale.close()
        with pytest.raises(edf.StaleFileError):
            stale.read_group(0)
        # but the pinned reader still reads its consistent old snapshot,
        # even through a deferred close (pool eviction mid-request)
        pinned.close()
        total = sum(pinned.read_group(g).nrows
                    for g in range(pinned.num_groups))
        assert total == cut
    assert pinned.closed                        # the deferred close landed
    # the pool hands out a fresh reader for the new generation
    assert edf.pooled_reader(p).nrows == frame.nrows


# -------------------------------- satellite 2: forced-stat-collision memo
def test_memo_survives_forced_stat_collision(tmp_path, log):
    frame, tables = log
    _fresh()
    acts = np.asarray(frame.columns[ACTIVITY])
    twin = EventFrame({**{k: np.asarray(v) for k, v in
                          frame.columns.items()},
                       ACTIVITY: ((acts + 1) % N_ACTS).astype(acts.dtype)},
                      dict(frame.valid))
    p = str(tmp_path / "log.edf")
    edf.write(p, frame, tables, codec="raw", version=3, row_group_rows=17)
    st = os.stat(p)
    first = repro.open(p).collect("activity_counts", engine="streaming")
    # rewrite with permuted single-digit activity ids: identical size, and
    # utime pins mtime_ns -> the stat signature alone cannot tell them apart
    edf.write(p, twin, tables, codec="raw", version=3, row_group_rows=17)
    os.utime(p, ns=(st.st_atime_ns, st.st_mtime_ns))
    assert (os.stat(p).st_size, os.stat(p).st_mtime_ns) == \
        (st.st_size, st.st_mtime_ns)
    second = repro.open(p).collect("activity_counts", engine="streaming")
    assert not _jeq(first.result, second.result)
    scratch = repro.open(twin, tables=tables).collect("activity_counts",
                                                      engine="eager")
    assert _jeq(second.result, scratch.result)


def test_header_tag_is_content_derived(tmp_path, log):
    frame, tables = log
    p, q = str(tmp_path / "a.edf"), str(tmp_path / "b.edf")
    edf.write(p, frame, tables, version=3, row_group_rows=17)
    edf.write(q, frame, tables, version=3, row_group_rows=17)
    assert edf.header_tag(p) == edf.header_tag(q)       # same content
    assert edf.file_sig(p)[2] == edf.header_tag(p)
    cut = _case_cuts(frame, N_CASES // 2)[1]
    edf.write(q, _slice(frame, 0, cut), tables, version=3, row_group_rows=17)
    assert edf.header_tag(p) != edf.header_tag(q)


# ------------------------------------------------------------ Dataset API
def test_dataset_append_api(tmp_path, log):
    frame, tables = log
    _fresh()
    cuts = _case_cuts(frame, 15)        # three case-aligned thirds
    p1, p2 = str(tmp_path / "a.edf"), str(tmp_path / "b.edf")
    edf.write(p1, _slice(frame, 0, cuts[1]), tables, version=3)
    edf.write(p2, _slice(frame, cuts[1], cuts[2]), tables, version=3)
    ds = repro.open([p1, p2])
    out = ds.append(_slice(frame, cuts[2], frame.nrows), row_group_rows=17)
    assert isinstance(out, repro.Dataset) and out.paths == ds.paths
    assert ds.num_cases == N_CASES              # live: this handle sees it
    scratch = repro.open(frame, tables=tables).collect("dfg", engine="eager")
    assert _jeq(ds.collect("dfg", engine="streaming").result, scratch.result)
    with pytest.raises(ValueError, match="last file"):
        ds.append(_slice(frame, 0, cuts[1]), path=p1)
    with pytest.raises(ValueError, match="file-backed"):
        repro.open(frame, tables=tables).append(frame)


# --------------------------------------------------------------- ingestor
def _write_batches(bdir, frame, tables, per=8, start=0, stop=None):
    cuts = _case_cuts(frame, per)
    stop = len(cuts) - 1 if stop is None else stop
    for i in range(start, stop):
        edf.write(os.path.join(bdir, f"batch_{i:04d}.edf"),
                  _slice(frame, cuts[i], cuts[i + 1]), tables, version=3)
    return stop - start


def test_ingestor_partitions_and_idempotence(tmp_path, log):
    frame, tables = log
    bdir, pdir = str(tmp_path / "in"), str(tmp_path / "out")
    os.makedirs(bdir)
    n = _write_batches(bdir, frame, tables)
    ing = Ingestor(pdir, bdir, partition_rows=frame.nrows // 3,
                   row_group_rows=16)
    assert ing.run_once() == n
    assert ing.run_once() == 0                  # skip-index: nothing redone
    assert len(ing.paths) >= 2                  # partition rollover happened
    got = [edf.read(p)[0] for p in ing.paths]
    assert sum(g.nrows for g in got) == frame.nrows
    joined = np.concatenate([np.asarray(g.columns[CASE]) for g in got])
    assert np.array_equal(joined, np.asarray(frame.columns[CASE]))
    # a new instance over the same index also redoes nothing
    assert Ingestor(pdir, bdir).run_once() == 0


def test_ingestor_crash_resume_both_windows(tmp_path, log):
    frame, tables = log
    bdir, pdir = str(tmp_path / "in"), str(tmp_path / "out")
    os.makedirs(bdir)
    cuts = _case_cuts(frame, 10)
    batches = [(f"batch_{i:04d}.edf", _slice(frame, cuts[i], cuts[i + 1]))
               for i in range(len(cuts) - 1)]
    for name, fr in batches:
        edf.write(os.path.join(bdir, name), fr, tables, version=3)
    ing = Ingestor(pdir, bdir, partition_rows=10**9, row_group_rows=16)
    ing.run_once(limit=1)
    part = os.path.basename(ing.paths[0])
    rows0 = edf.read_header(ing.paths[0])[0]["nrows"]

    # crash window A: pending recorded, apply never ran -> batch is redone
    ing._index["pending"] = {"batch": batches[1][0], "partition": part,
                             "rows": batches[1][1].nrows,
                             "nrows_before": rows0}
    ing._save_index()
    resumed = Ingestor(pdir, bdir, partition_rows=10**9, row_group_rows=16)
    assert batches[1][0] not in resumed.done_ids
    resumed.run_once(limit=1)
    rows1 = edf.read_header(resumed.paths[0])[0]["nrows"]
    assert rows1 == rows0 + batches[1][1].nrows

    # crash window B: apply landed, done never recorded -> acknowledged,
    # not re-applied (no duplicate rows)
    edf.append(resumed.paths[0], batches[2][1], tables, row_group_rows=16)
    resumed._index["pending"] = {"batch": batches[2][0], "partition": part,
                                 "rows": batches[2][1].nrows,
                                 "nrows_before": rows1}
    resumed._save_index()
    final = Ingestor(pdir, bdir, partition_rows=10**9, row_group_rows=16)
    assert batches[2][0] in final.done_ids
    final.run_once()                            # drains the remaining batches
    got, _ = edf.read(final.paths[0])
    assert got.nrows == frame.nrows
    assert np.array_equal(np.asarray(got.columns[CASE]),
                          np.asarray(frame.columns[CASE]))


def test_ingestor_retries_transient_write_failures(tmp_path, log,
                                                   monkeypatch):
    frame, tables = log
    bdir, pdir = str(tmp_path / "in"), str(tmp_path / "out")
    os.makedirs(bdir)
    _write_batches(bdir, frame, tables, per=N_CASES // 2)
    real_append, fails = edf.append, {"left": 2}

    def flaky(path, fr, tb=None, row_group_rows=None):
        if fails["left"]:
            fails["left"] -= 1
            raise OSError("transient")
        return real_append(path, fr, tb, row_group_rows)

    monkeypatch.setattr(ingest_mod.edf, "append", flaky)
    ing = Ingestor(pdir, bdir, partition_rows=10**9, row_group_rows=16,
                   max_retries=5, backoff=0.001)
    assert ing.run_once() == 2
    assert ing.retried == 2
    got, _ = edf.read(ing.paths[0])
    assert got.nrows == frame.nrows


# ---------------------------------------------------------- query service
def test_service_collect_claims_and_parity(tmp_path, log):
    frame, tables = log
    _fresh()
    pdir = str(tmp_path / "parts")
    os.makedirs(pdir)
    edf.write(os.path.join(pdir, "part_00000.edf"), frame, tables,
              version=3, row_group_rows=16)
    svc = MiningService(pdir, case_capacity=64)
    out = svc.collect("dfg", engine="streaming")
    claim = out["snapshot"]
    assert claim["rows"] == frame.nrows and claim["num_cases"] == 64
    assert claim["files"][0]["tag"] == edf.header_tag(
        os.path.join(pdir, "part_00000.edf"))
    ref = repro.open(frame, tables=tables,
                     num_cases=claim["num_cases"]).collect("dfg",
                                                           engine="eager")
    assert json.dumps(out["result"]) == json.dumps(to_jsonable(ref.result))
    with pytest.raises(ServiceError):
        svc.collect(None)
    with pytest.raises(ServiceError):
        MiningService(str(tmp_path / "empty")).collect("dfg")


def test_mined_while_ingesting_bitwise_parity(tmp_path):
    """The tentpole drill: one ingest thread appending case-aligned
    batches while client threads collect concurrently; every returned
    result must be bitwise equal (via canonical JSON) to re-mining the
    exact snapshot its claim names — which, appends being ordered and
    atomic, is a row prefix of the master log."""
    rng = np.random.default_rng(23)
    frame, tables = sorted_frame(random_log(rng, n_cases=60, n_acts=N_ACTS,
                                            max_len=7))
    _fresh()
    bdir, pdir = str(tmp_path / "in"), str(tmp_path / "out")
    os.makedirs(bdir)
    cuts = _case_cuts(frame, 6)
    ing = Ingestor(pdir, bdir, partition_rows=frame.nrows // 2,
                   row_group_rows=16, poll_interval=0.01)
    svc = MiningService(ing, case_capacity=64, max_attempts=6)

    def produce():
        for i in range(len(cuts) - 1):
            edf.write(os.path.join(bdir, f"batch_{i:04d}.edf"),
                      _slice(frame, cuts[i], cuts[i + 1]), tables, version=3)
            time.sleep(0.02)

    collected, errors = [], []

    def client():
        verbs = ("dfg", "activity_counts", "case_sizes")
        done, deadline = 0, time.monotonic() + 30
        while done < 6 and time.monotonic() < deadline:
            try:
                out = svc.collect(verbs[done % len(verbs)],
                                  engine="streaming")
                collected.append((out["verb"], out["snapshot"],
                                  json.dumps(out["result"])))
                done += 1
                time.sleep(0.01)
            except ServiceError:
                time.sleep(0.03)                # warming up: no partitions
            except Exception as e:              # pragma: no cover
                errors.append(e)
                return

    producer = threading.Thread(target=produce)
    producer.start()
    ing.start()
    time.sleep(0.05)
    clients = [threading.Thread(target=client) for _ in range(3)]
    for c in clients:
        c.start()
    for c in clients:
        c.join()
    producer.join()
    # drain the tail so the final parity check covers the whole log
    while ing.run_once():
        pass
    ing.stop()
    assert not errors
    assert collected, "no client ever got a successful collect"
    seen_rows = set()
    for verb, claim, result_json in collected:
        rows = claim["rows"]
        seen_rows.add(rows)
        prefix = _slice(frame, 0, rows)
        ref = repro.open(prefix, tables=tables,
                         num_cases=claim["num_cases"]).collect(
                             verb, engine="eager")
        assert result_json == json.dumps(to_jsonable(ref.result)), \
            f"{verb} diverged at a {rows}-row snapshot"
    final = svc.collect("dfg", engine="streaming")
    assert final["snapshot"]["rows"] == frame.nrows


def test_http_endpoints(tmp_path, log):
    frame, tables = log
    _fresh()
    pdir = str(tmp_path / "parts")
    os.makedirs(pdir)
    edf.write(os.path.join(pdir, "part_00000.edf"), frame, tables,
              version=3, row_group_rows=16)
    httpd = serve(pdir, port=0, case_capacity=64)
    port = httpd.server_address[1]
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    try:
        def get(path):
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}", timeout=30) as r:
                return json.loads(r.read())

        health = get("/health")
        assert health["ok"] and health["rows"] == frame.nrows
        got = get("/collect?verb=dfg&engine=streaming")
        ref = repro.open(frame, tables=tables,
                         num_cases=got["snapshot"]["num_cases"]).collect(
                             "dfg", engine="eager")
        assert json.dumps(got["result"]) == json.dumps(
            to_jsonable(ref.result))
        # POST body routes kwargs (min_count reaches the alpha kernel)
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/collect",
            data=json.dumps({"verb": "alpha", "min_count": 2}).encode(),
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=30) as r:
            alpha = json.loads(r.read())
        assert alpha["result"]["_type"] == "AlphaModel"
        win = get("/window?verb=dfg&by=groups&size=2&step=2")
        assert len(win["results"]) == len(win["bounds"])
        assert "state-cache" in get("/explain?verb=dfg")["explain"]
        with pytest.raises(urllib.error.HTTPError) as e404:
            get("/nope")
        assert e404.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as e400:
            get("/collect")                     # missing verb
        assert e400.value.code == 400
    finally:
        httpd.shutdown()
