"""Shared test utilities."""
from __future__ import annotations

import numpy as np

from repro.core import ACTIVITY, CASE, TIMESTAMP, ClassicEventLog, make_classic_log
from repro.core import ops
from repro.models.module import ShardingRules

LOCAL_RULES = ShardingRules(embed=None, vocab=None, heads=None, mlp=None,
                            expert=None, batch=None, seq=None)


def random_log(rng: np.random.Generator, n_cases=20, n_acts=6, max_len=10,
               extra_attrs=0) -> ClassicEventLog:
    acts = [chr(ord("A") + i) for i in range(n_acts)]
    cases = []
    t = 0.0
    for c in range(n_cases):
        ln = int(rng.integers(1, max_len + 1))
        trace = []
        for _ in range(ln):
            t += float(rng.random())
            trace.append((acts[int(rng.integers(0, n_acts))], t))
        cases.append((c, trace))
    return make_classic_log(cases, extra_attrs=extra_attrs)


def sorted_frame(log: ClassicEventLog):
    frame, tables = log.to_eventframe()
    frame = ops.sort(frame, (TIMESTAMP, CASE))
    return frame, tables
