"""Training runtime: loss goes down, microbatch equivalence, checkpoint
save/restore/auto-resume, failure injection, straggler monitor, compression."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as Mdl
from repro.models.module import Initializer
from repro.train import compression
from repro.train.checkpoint import CheckpointManager
from repro.train.ft import FailureInjector, StragglerMonitor
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, schedule
from repro.train import trainstep as TS

from helpers import LOCAL_RULES


def _setup(seed=0, arch="eventlm-100m"):
    cfg = reduced_config(get_config(arch))
    params = Mdl.init_params(cfg, Initializer(jax.random.PRNGKey(seed)))
    return cfg, params


def _batch(cfg, B=4, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(3, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
            "loss_mask": jnp.ones((B, S), jnp.float32)}


def test_loss_decreases():
    losses = []
    cfg, params = _setup()
    state = TS.init_state(cfg, params)
    step = jax.jit(TS.make_train_step(cfg, LOCAL_RULES,
                                      OptConfig(lr=1e-3, warmup_steps=2,
                                                total_steps=40), 1))
    b = _batch(cfg)  # overfit one batch
    for i in range(30):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_microbatch_equivalence():
    """num_microbatches=4 must give the same update as 1 (same global batch)."""
    cfg, params = _setup()
    b = _batch(cfg, B=8)
    oc = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1 = TS.init_state(cfg, params)
    s4 = jax.tree.map(jnp.copy, s1)
    st1, m1 = jax.jit(TS.make_train_step(cfg, LOCAL_RULES, oc, 1))(s1, b)
    st4, m4 = jax.jit(TS.make_train_step(cfg, LOCAL_RULES, oc, 4))(s4, b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, c in zip(jax.tree.leaves(st1["params"]), jax.tree.leaves(st4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


def test_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(oc, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    opt = init_opt_state(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(OptConfig(clip_norm=1.0), params, huge, opt)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, params = _setup()
    state = TS.init_state(cfg, params)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(10, state)
    mgr.save(20, state)
    mgr.save(30, state)
    assert mgr.all_steps() == [20, 30]  # keep=2 gc'd step 10
    step, restored = mgr.restore_latest(state)
    assert step == 30
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async(tmp_path):
    cfg, params = _setup()
    state = TS.init_state(cfg, params)
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, state)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_training_resume_bitexact(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: same params."""
    cfg, params = _setup()
    oc = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    step = jax.jit(TS.make_train_step(cfg, LOCAL_RULES, oc, 1))
    batches = [_batch(cfg, seed=i) for i in range(6)]

    s = TS.init_state(cfg, params)
    for b in batches:
        s, _ = step(s, b)

    s2 = TS.init_state(cfg, params)
    mgr = CheckpointManager(str(tmp_path))
    for b in batches[:3]:
        s2, _ = step(s2, b)
    mgr.save(3, s2)
    _, s3 = mgr.restore_latest(s2)
    for b in batches[3:]:
        s3, _ = step(s3, b)
    for a, b_ in zip(jax.tree.leaves(s["params"]), jax.tree.leaves(s3["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


def test_failure_injection_and_restart_loop():
    inj = FailureInjector({3})
    done = []
    for step_i in range(5):
        try:
            inj.check(step_i)
            done.append(step_i)
        except RuntimeError:
            pass
    assert 3 not in done and inj.failed == [3]


def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0)
    for _ in range(5):
        assert not mon.observe(1.0)
    assert mon.observe(5.0)      # 5x the EWMA
    assert mon.stragglers == 1


def test_int8_error_feedback_converges():
    """Repeated compressed transmission of the same gradient loses nothing
    on average thanks to error feedback."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(256).astype(np.float32))}
    errors = compression.init_errors(g)
    acc = jnp.zeros(256)
    n = 50
    for _ in range(n):
        q, s, errors = compression.compress_tree(g, errors)
        acc = acc + compression.dequantize(q["w"], s["w"])
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               atol=1e-2)


def test_quantize_roundtrip_bounds():
    x = jnp.asarray([-3.0, 0.0, 1.5, 3.0])
    q, s = compression.quantize(x)
    back = compression.dequantize(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-7
