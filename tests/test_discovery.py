"""Columnar discovery (alpha + heuristics) vs the classic-log oracle.

Parity: on any random log the columnar miners must reproduce the
row-oriented reference (``core.classic_log``) — places, start/end sets,
dependency/L2 measures, kept edges — under both segment backends.
Streaming: any chunking of a sorted log yields models bitwise-identical to
the whole-log pass (integer counting is order-exact; the two-row carry
stitches L2 triples across boundaries).
"""
import numpy as np
import pytest
from _prop import given, settings, strategies as st

from repro.core import (ACTIVITY, CASE, ChunkedEventFrame, conformance,
                        discovery, use_backend)
from repro.core.classic_log import (alpha_reference, heuristics_reference,
                                    make_classic_log)

from helpers import random_log, sorted_frame

BACKENDS = ("xla", "pallas")


def _log_from_traces(traces):
    t = 0.0
    cases = []
    for i, tr in enumerate(traces):
        timed = []
        for a in tr:
            t += 1.0
            timed.append((a, t))
        cases.append((i, timed))
    return make_classic_log(cases)


def _labeled_places(model, acts):
    return {(frozenset(acts[i] for i in a), frozenset(acts[i] for i in b))
            for a, b in model.places}


def _labels(ids, acts):
    return frozenset(acts[i] for i in ids)


def _ref_matrix(measure: dict, acts) -> np.ndarray:
    m = np.zeros((len(acts), len(acts)), np.float64)
    for (x, y), v in measure.items():
        m[acts.index(x), acts.index(y)] = v
    return m


# ------------------------------------------------------------- textbook
def test_alpha_textbook_l1():
    """van der Aalst's L1: the miner must recover the canonical Y_L."""
    log = _log_from_traces([list("abcd")] * 3 + [list("acbd")] * 2
                           + [list("aed")])
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    model = discovery.alpha(frame, len(acts))
    want = {(frozenset("a"), frozenset("be")),
            (frozenset("a"), frozenset("ce")),
            (frozenset("be"), frozenset("d")),
            (frozenset("ce"), frozenset("d"))}
    assert _labeled_places(model, acts) == want
    assert _labels(model.start_activities, acts) == frozenset("a")
    assert _labels(model.end_activities, acts) == frozenset("d")
    assert model.num_places == len(want) + 2
    # the discovered footprint is perfectly self-conformant
    d = discovery.discovery_state(frame, len(acts)).dfg
    assert float(conformance.footprint_conformance(d, model)) == 1.0
    assert float(conformance.alpha_fitness(d, model)) == 1.0


def test_heuristics_loops():
    """L1 loops (e,e,e) stay diagonal; L2 loops (b,c,b) add both directions
    and are suppressed when a side already has an L1 loop."""
    log = _log_from_traces([list("abcbcbd")] * 3 + [list("aeeed")] * 2)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    a = len(acts)
    state = discovery.discovery_state(frame, a)
    # L2 triple counts match the row-oriented count exactly
    ref_c2 = log.dfg_l2_iterative()
    got_c2 = np.asarray(state.l2_counts)
    assert {(acts[i], acts[j]): int(got_c2[i, j])
            for i, j in zip(*np.nonzero(got_c2))} == ref_c2
    net = discovery.discover_heuristics(state)
    _, _, ref_edges = heuristics_reference(log)
    got_edges = {(acts[i], acts[j]) for (i, j), _ in net.edges()}
    assert got_edges == ref_edges
    assert ("e", "e") in got_edges          # L1 loop on the diagonal
    assert ("b", "c") in got_edges and ("c", "b") in got_edges  # L2 pair
    fit = float(conformance.heuristics_fitness(state.dfg, net))
    assert 0.0 < fit <= 1.0


def test_heuristics_and_bindings():
    """a splits into concurrent b||c (AND) vs exclusive d|e (XOR)."""
    log = _log_from_traces([list("abcf")] * 5 + [list("acbf")] * 5
                           + [list("gdh")] * 5 + [list("geh")] * 5)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    net = discovery.heuristics(frame, len(acts))
    ab = np.asarray(net.and_bindings)
    ia, ib, ic = acts.index("a"), acts.index("b"), acts.index("c")
    ig, idd, ie = acts.index("g"), acts.index("d"), acts.index("e")
    assert ab[ia, ib, ic] and ab[ia, ic, ib]      # b and c run concurrently
    assert not ab[ig, idd, ie] and not ab[ig, ie, idd]  # d xor e


# ------------------------------------------------- oracle parity property
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 5000))
def test_alpha_matches_reference(seed):
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=14, n_acts=5, max_len=6)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    ref_places, ref_starts, ref_ends = alpha_reference(log)
    for backend in BACKENDS:
        with use_backend(backend):
            model = discovery.alpha(frame, len(acts))
        assert _labeled_places(model, acts) == ref_places, (seed, backend)
        assert _labels(model.start_activities, acts) == ref_starts
        assert _labels(model.end_activities, acts) == ref_ends


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 5000))
def test_heuristics_matches_reference(seed):
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=14, n_acts=5, max_len=6)
    frame, tables = sorted_frame(log)
    acts = tables[ACTIVITY]
    ref_dep, ref_l2, ref_edges = heuristics_reference(log)
    for backend in BACKENDS:
        with use_backend(backend):
            net = discovery.heuristics(frame, len(acts))
        np.testing.assert_allclose(np.asarray(net.dependency),
                                   _ref_matrix(ref_dep, acts),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"seed={seed} {backend}")
        np.testing.assert_allclose(np.asarray(net.l2),
                                   _ref_matrix(ref_l2, acts),
                                   rtol=1e-6, atol=1e-7)
        got_edges = {(acts[i], acts[j]) for (i, j), _ in net.edges()}
        assert got_edges == ref_edges, (seed, backend)


# ------------------------------------------------- streaming invariance
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 5000), n_chunks=st.integers(1, 12))
def test_discovery_chunk_invariance(seed, n_chunks):
    """Any chunking — including one-row chunks that split every L2 triple
    across three chunks — accumulates bitwise-identical discovery state."""
    rng = np.random.default_rng(seed)
    log = random_log(rng, n_cases=18, n_acts=5, max_len=8)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    ref = discovery.discovery_state(frame, a)
    cuts = sorted(int(c) for c in rng.integers(1, max(frame.nrows, 2),
                                               size=n_chunks))
    src = ChunkedEventFrame.from_cuts(frame, cuts)
    got = discovery.streaming_discovery_state(src, a)
    for name in ("counts", "starts", "ends"):
        np.testing.assert_array_equal(np.asarray(getattr(got.dfg, name)),
                                      np.asarray(getattr(ref.dfg, name)),
                                      err_msg=f"seed={seed}:{name}")
    np.testing.assert_array_equal(np.asarray(got.l2_counts),
                                  np.asarray(ref.l2_counts),
                                  err_msg=f"seed={seed}:l2")
    # finalized models are identical too (pure functions of the state)
    ref_m = discovery.alpha(frame, a)
    got_m = discovery.streaming_alpha(ChunkedEventFrame.from_cuts(frame, cuts), a)
    assert got_m.places == ref_m.places
    assert got_m.start_activities == ref_m.start_activities
    assert got_m.end_activities == ref_m.end_activities
    ref_n = discovery.heuristics(frame, a)
    got_n = discovery.streaming_heuristics(
        ChunkedEventFrame.from_cuts(frame, cuts), a)
    np.testing.assert_array_equal(np.asarray(got_n.dependency),
                                  np.asarray(ref_n.dependency))
    np.testing.assert_array_equal(np.asarray(got_n.graph),
                                  np.asarray(ref_n.graph))


def test_single_row_chunks():
    """The adversarial chunking: every chunk is one row, every DF pair and
    every L2 triple straddles chunk boundaries."""
    log = _log_from_traces([list("abcbcbd"), list("aeeed"), list("ad")])
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    ref = discovery.discovery_state(frame, a)
    got = discovery.streaming_discovery_state(
        ChunkedEventFrame.from_frame(frame, 1), a)
    np.testing.assert_array_equal(np.asarray(got.l2_counts),
                                  np.asarray(ref.l2_counts))
    np.testing.assert_array_equal(np.asarray(got.dfg.counts),
                                  np.asarray(ref.dfg.counts))


@pytest.mark.parametrize("backend", BACKENDS)
def test_streaming_from_edf(tmp_path, backend):
    """disk -> device: discovery over EDF row groups == whole-log, and the
    same state finalizes to the same models under either backend."""
    from repro.storage import edf

    rng = np.random.default_rng(23)
    log = random_log(rng, n_cases=40, n_acts=6, max_len=9)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    p = str(tmp_path / "disc.edf")
    edf.write(p, frame, tables, row_group_rows=37)
    with use_backend(backend):
        ref = discovery.discovery_state(frame, a)
        got = discovery.streaming_discovery_state(
            ChunkedEventFrame.from_edf(p), a)
    np.testing.assert_array_equal(np.asarray(got.dfg.counts),
                                  np.asarray(ref.dfg.counts))
    np.testing.assert_array_equal(np.asarray(got.l2_counts),
                                  np.asarray(ref.l2_counts))


def test_footprint_classes_partition():
    """causal/reverse-causal/parallel/choice partition the (A, A) cells."""
    rng = np.random.default_rng(3)
    log = random_log(rng, n_cases=20, n_acts=6, max_len=8)
    frame, tables = sorted_frame(log)
    a = len(tables[ACTIVITY])
    fp = discovery.footprint(discovery.discovery_state(frame, a).dfg)
    causal = np.asarray(fp.causal)
    parallel = np.asarray(fp.parallel)
    choice = np.asarray(fp.choice)
    total = (causal.astype(int) + causal.T.astype(int)
             + parallel.astype(int) + choice.astype(int))
    np.testing.assert_array_equal(total, np.ones((a, a), int))
