"""Graph analytics subsystem: mined state → weighted process graph → dense
semiring queries, plus PM4Py-compatible model export.

``ir`` compiles any DFG-backed state into the :class:`ProcessGraph` IR;
``queries`` answers reachability / bottleneck-path / centrality questions
over it with the ``kernels.graph_ops`` semiring matmuls; ``verbs``
registers all of it as ordinary mining verbs (importing this package is
what puts ``graph``/``reachability``/``bottleneck_paths``/
``node_centrality`` in the kernel registry); ``export`` serializes models
to PNML / DOT / process-tree / dfg.json / XES.
"""
from . import export, ir, queries, verbs  # noqa: F401 (verbs registers specs)
from .export import (alpha_to_pnml, dfg_from_json, dfg_to_json,
                     discover_process_tree, frame_from_xes, frame_to_xes,
                     graph_to_dot, heuristics_to_dot, pnml_places, read_pnml)
from .ir import END_LABEL, START_LABEL, ProcessGraph, compile_graph
from .queries import (BottleneckPaths, Centrality, Reachability,
                      bottleneck_paths, node_centrality, reachability)
from .verbs import (bottleneck_paths_kernel, graph_kernel,
                    node_centrality_kernel, reachability_kernel)

__all__ = [
    "ProcessGraph", "compile_graph", "START_LABEL", "END_LABEL",
    "Reachability", "BottleneckPaths", "Centrality",
    "reachability", "bottleneck_paths", "node_centrality",
    "graph_kernel", "reachability_kernel", "bottleneck_paths_kernel",
    "node_centrality_kernel",
    "alpha_to_pnml", "read_pnml", "pnml_places", "heuristics_to_dot",
    "graph_to_dot", "discover_process_tree", "dfg_to_json", "dfg_from_json",
    "frame_to_xes", "frame_from_xes",
]
