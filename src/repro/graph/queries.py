"""Graph-query verbs over the :class:`~repro.graph.ir.ProcessGraph` IR.

All three verbs are *finalize-over-state* computations: the heavy part of
a collect is still the one mergeable DFG fold, and the query itself is a
handful of dense (N, N) semiring products on the
``repro.kernels.graph_ops`` primitive (N = alphabet + 2 — tiny next to
the event stream, but MXU-shaped: the closures are iterated matmuls).

Exactness contract (what the engine-parity tests assert):

* ``reachability`` — 0/1 operands through the thresholded MXU product:
  exact, bitwise identical across engines *and* across the
  pallas/xla lowerings.
* ``bottleneck_paths`` — tropical (min/max) reductions over single-op
  candidates: bitwise across lowerings for any weights; with the default
  frequency weights every value is integer-valued f32, so the distances
  also match a host Floyd–Warshall bit for bit.
* ``node_centrality`` — degrees are exact integer sums; the power-method
  flow vector is a fixed op sequence over the same merged state, so it is
  engine-invariant (eager == streamed == sharded), with the usual
  float32 caveat *across* lowerings (the matvec rides ``plus_times``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.graph_ops import (bool_closure, maxmin_closure,
                                     minplus_closure, semiring_matmul)

from .ir import ProcessGraph


# ------------------------------------------------------------ reachability
@dataclasses.dataclass(frozen=True)
class Reachability:
    """``mask[i, j]`` — j reachable from i in at most ``k`` edge steps."""

    k: int
    mask: jax.Array              # (N, N) bool


def reachability(g: ProcessGraph, k: int | None = None, *,
                 impl: str | None = None) -> Reachability:
    """k-step boolean closure of the observed adjacency (``k=None`` =
    full closure).  Artificial source/sink rows answer "reachable from
    process start" / "can still reach process end"."""
    n = g.num_nodes
    k_eff = max(n - 1, 1) if k is None else max(int(k), 0)
    k_eff = min(k_eff, max(n - 1, 1))
    return Reachability(k=k_eff,
                        mask=bool_closure(g.adjacency, k_eff, impl=impl))


# ------------------------------------------------------- bottleneck paths
@dataclasses.dataclass(frozen=True)
class BottleneckPaths:
    """All-pairs path structure of the process graph.

    ``shortest[i, j]`` — min-plus distance (hop count for
    ``weights="frequency"``, summed mean waiting time for
    ``weights="performance"``; ``+inf`` = unreachable).
    ``widest[i, j]`` — max-min bottleneck capacity over the frequency
    weights (the rarest edge on the best path; ``-inf`` = unreachable,
    ``+inf`` on the diagonal).  ``path`` is the source → sink widest
    path (node ids, host-reconstructed), ``bottleneck`` its capacity —
    the process's busiest end-to-end corridor and the edge that throttles
    it.
    """

    weights: str
    shortest: jax.Array          # (N, N) float32
    widest: jax.Array            # (N, N) float32
    path: tuple[int, ...]
    bottleneck: float


def _edge_costs(g: ProcessGraph, weights: str) -> jax.Array:
    adj = g.adjacency
    if weights == "frequency":
        return jnp.where(adj, 1.0, jnp.inf)          # hop count
    if weights == "performance":
        if g.perf is None:
            raise ValueError(
                'bottleneck_paths(weights="performance") needs a '
                'performance-compiled graph (collect with timed=True / '
                'Dataset.bottlenecks(weights="performance"))')
        return jnp.where(adj, g.perf, jnp.inf)
    raise ValueError(f"unknown weights {weights!r}; "
                     f"one of ('frequency', 'performance')")


def _widest_path(freq: np.ndarray, widest: np.ndarray, src: int,
                 dst: int) -> tuple[int, ...]:
    """Reconstruct one widest src → dst path, deterministically.

    The bottleneck value ``v = widest[src, dst]`` is known; every edge on
    a widest path has capacity ≥ v, and no path beats v, so a BFS over
    the ``cap >= v`` subgraph returns a hop-shortest path whose min-edge
    is exactly v (BFS visits successors in node-id order — stable)."""
    v = widest[src, dst]
    if not np.isfinite(v) or v <= 0:
        return ()
    allowed = freq.astype(np.float64) >= v
    prev: dict[int, int | None] = {src: None}
    frontier = [src]
    while frontier and dst not in prev:
        nxt = []
        for u in frontier:
            for j in np.nonzero(allowed[u])[0]:
                j = int(j)
                if j not in prev:
                    prev[j] = u
                    nxt.append(j)
        frontier = nxt
    if dst not in prev:
        return ()
    path = [dst]
    while path[-1] != src:
        path.append(prev[path[-1]])
    return tuple(reversed(path))


def bottleneck_paths(g: ProcessGraph, weights: str = "frequency", *,
                     impl: str | None = None) -> BottleneckPaths:
    """Min-plus shortest + max-min widest all-pairs paths (module doc)."""
    costs = _edge_costs(g, weights)
    cap = jnp.where(g.adjacency, g.freq.astype(jnp.float32), -jnp.inf)
    shortest = minplus_closure(costs, impl=impl)
    widest = maxmin_closure(cap, impl=impl)
    freq = np.asarray(g.freq)
    w_host = np.asarray(widest)
    path = _widest_path(freq, w_host, g.source, g.sink)
    bott = float(w_host[g.source, g.sink]) if path else 0.0
    return BottleneckPaths(weights=weights, shortest=shortest,
                           widest=widest, path=path, bottleneck=bott)


# ----------------------------------------------------------- centrality
@dataclasses.dataclass(frozen=True)
class Centrality:
    """Per-node centrality over the frequency-weighted graph.

    ``in_degree`` / ``out_degree`` — exact traversal totals (column/row
    sums of ``freq``).  ``flow`` — power-method flow centrality: the
    L1-normalized fixed point of ``x <- x P`` (P the row-normalized
    transition matrix, sink mass recycled to the source so the chain has
    a stationary distribution), after ``iters`` matvec steps on the
    ``plus_times`` primitive.
    """

    in_degree: jax.Array         # (N,) int32
    out_degree: jax.Array        # (N,) int32
    flow: jax.Array              # (N,) float32
    iters: int


def node_centrality(g: ProcessGraph, iters: int = 16, *,
                    impl: str | None = None) -> Centrality:
    f = g.freq.astype(jnp.float32)
    n = g.num_nodes
    in_deg = jnp.sum(g.freq, axis=0).astype(jnp.int32)
    out_deg = jnp.sum(g.freq, axis=1).astype(jnp.int32)
    # row-stochastic transition matrix; dead ends (the sink, unobserved
    # activities) hand their mass back to the artificial source so the
    # walk restarts instead of leaking
    rowsum = jnp.sum(f, axis=1, keepdims=True)
    p = jnp.where(rowsum > 0, f / jnp.maximum(rowsum, 1.0), 0.0)
    restart = jnp.zeros((n,), jnp.float32).at[g.source].set(1.0)
    p = jnp.where(rowsum > 0, p, restart[None, :])
    x = jnp.full((1, n), 1.0 / n, jnp.float32)
    for _ in range(max(int(iters), 0)):
        x = semiring_matmul(x, p, "plus_times", impl=impl)
        x = x / jnp.maximum(jnp.sum(x), 1e-30)
    return Centrality(in_degree=in_deg, out_degree=out_deg,
                      flow=x[0], iters=max(int(iters), 0))
