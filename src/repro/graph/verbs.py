"""Graph verbs as registered chunk kernels.

Each verb is the alpha-miner pattern one level up: the chunk-side work is
the *existing* mergeable DFG fold (``core.dfg.dfg_kernel``), and the verb
is a new ``finalize`` that compiles the merged state into a
:class:`~repro.graph.ir.ProcessGraph` and (for the query verbs) runs the
semiring closure over it.  Because state, update, merge, and stitch are
shared verbatim with the DFG kernel, every graph verb inherits the whole
schedule family for free — eager, streaming, pruned, windowed,
state-cached, and sharded (``sharded_state="dfg"``: the distributed
driver psums DFG state, then ``from_sharded`` compiles + queries on
host).

``timed=True`` (the performance overlay) composes the DFG kernel with
``performance_dfg_kernel``; the f32 wait totals are order-sensitive, so
the timed variant deliberately has no stitch and no sharded lowering —
drivers fall back to the sequential fold, and ``from_sharded`` refuses
with a pointer at ``engine='streaming'``.
"""
from __future__ import annotations

from repro.core import engine
from repro.core.dfg import dfg_kernel
from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP

from .ir import ProcessGraph, compile_graph
from .queries import (BottleneckPaths, Centrality, Reachability,
                      bottleneck_paths, node_centrality, reachability)


def _timed_base(num_activities: int, method: str) -> engine.ChunkKernel:
    # one fused pass accumulating DFG counts + f32 wait totals; compose()
    # drops the stitch because the performance member has none
    from repro.core.performance import performance_dfg_kernel

    return engine.compose({"dfg": dfg_kernel(num_activities, method),
                           "perf": performance_dfg_kernel(num_activities)})


def _wrap(base: engine.ChunkKernel, name: str, finalize) -> engine.ChunkKernel:
    return engine.ChunkKernel(
        f"{name}[{base.name}]", base.init, base.update, base.merge, finalize,
        mask_exact=base.mask_exact, columns=base.columns, stitch=base.stitch)


def graph_kernel(num_activities: int, timed: bool = False,
                 method: str = "auto") -> engine.ChunkKernel:
    """Compile the stream into a :class:`ProcessGraph` (``timed=True`` adds
    the mean-wait performance overlay; see module docstring)."""
    if timed:
        base = _timed_base(num_activities, method)

        def finalize(state, carry):
            out = base.finalize(state, carry)
            return compile_graph(out["dfg"], perf=out["perf"][1])

        return _wrap(base, "graph+perf", finalize)
    dk = dfg_kernel(num_activities, method)
    return _wrap(dk, "graph",
                 lambda s, c: compile_graph(dk.finalize(s, c)))


def reachability_kernel(num_activities: int, k: int | None = None,
                        method: str = "auto",
                        impl: str | None = None) -> engine.ChunkKernel:
    """k-step reachability closure of the compiled graph."""
    dk = dfg_kernel(num_activities, method)
    return _wrap(dk, "reachability",
                 lambda s, c: reachability(compile_graph(dk.finalize(s, c)),
                                           k, impl=impl))


def bottleneck_paths_kernel(num_activities: int, weights: str = "frequency",
                            method: str = "auto",
                            impl: str | None = None) -> engine.ChunkKernel:
    """All-pairs shortest/widest paths + the source→sink bottleneck."""
    if weights == "performance":
        base = _timed_base(num_activities, method)

        def finalize(state, carry):
            out = base.finalize(state, carry)
            g = compile_graph(out["dfg"], perf=out["perf"][1])
            return bottleneck_paths(g, weights, impl=impl)

        return _wrap(base, "bottleneck_paths+perf", finalize)
    dk = dfg_kernel(num_activities, method)
    return _wrap(dk, "bottleneck_paths",
                 lambda s, c: bottleneck_paths(
                     compile_graph(dk.finalize(s, c)), weights, impl=impl))


def node_centrality_kernel(num_activities: int, iters: int = 16,
                           method: str = "auto",
                           impl: str | None = None) -> engine.ChunkKernel:
    """Degree + power-method flow centrality of the compiled graph."""
    dk = dfg_kernel(num_activities, method)
    return _wrap(dk, "node_centrality",
                 lambda s, c: node_centrality(compile_graph(dk.finalize(s, c)),
                                              iters, impl=impl))


# --------------------------------------------------------- registration
def _no_sharded_perf(what: str) -> ValueError:
    return ValueError(
        f"{what} has no exact distributed lowering (order-sensitive f32 "
        f"wait totals); use engine='streaming' or 'eager'")


def _graph_from_sharded(state, timed=False, **_) -> ProcessGraph:
    if timed:
        raise _no_sharded_perf("graph(timed=True)")
    return compile_graph(state)


def _reach_from_sharded(state, k=None, impl=None, **_) -> Reachability:
    return reachability(compile_graph(state), k, impl=impl)


def _bott_from_sharded(state, weights="frequency", impl=None,
                       **_) -> BottleneckPaths:
    if weights == "performance":
        raise _no_sharded_perf('bottleneck_paths(weights="performance")')
    return bottleneck_paths(compile_graph(state), weights, impl=impl)


def _cent_from_sharded(state, iters=16, impl=None, **_) -> Centrality:
    return node_centrality(compile_graph(state), iters, impl=impl)


engine.register_kernel(engine.KernelSpec(
    "graph",
    make=lambda dims, timed=False, method="auto": graph_kernel(
        dims.num_activities, timed, method),
    # TIMESTAMP serves only timed=True; plan() projects it when the schema
    # has it and the untimed kernel simply never reads it
    columns=(ACTIVITY, CASE, TIMESTAMP),
    sharded_state="dfg",
    from_sharded=_graph_from_sharded,
    doc="DFG state compiled into a weighted process graph "
        "(artificial start/end nodes; timed=True adds mean waits)"))
engine.register_kernel(engine.KernelSpec(
    "reachability",
    make=lambda dims, k=None, method="auto", impl=None: reachability_kernel(
        dims.num_activities, k, method, impl),
    columns=(ACTIVITY, CASE),
    sharded_state="dfg",
    from_sharded=_reach_from_sharded,
    doc="k-step boolean reachability closure of the process graph"))
engine.register_kernel(engine.KernelSpec(
    "bottleneck_paths",
    make=lambda dims, weights="frequency", method="auto",
    impl=None: bottleneck_paths_kernel(dims.num_activities, weights,
                                       method, impl),
    columns=(ACTIVITY, CASE, TIMESTAMP),
    sharded_state="dfg",
    from_sharded=_bott_from_sharded,
    doc="min-plus shortest / max-min widest paths + source→sink bottleneck"))
engine.register_kernel(engine.KernelSpec(
    "node_centrality",
    make=lambda dims, iters=16, method="auto",
    impl=None: node_centrality_kernel(dims.num_activities, iters,
                                      method, impl),
    columns=(ACTIVITY, CASE),
    sharded_state="dfg",
    from_sharded=_cent_from_sharded,
    doc="in/out degree + power-method flow centrality per node"))
