"""The ``ProcessGraph`` IR: mined state compiled into one dense graph.

Every mergeable DFG-backed state this repo accumulates (``core.dfg.DFG``,
``core.discovery.DiscoveryState``, a performance overlay) compiles into
the same intermediate representation: a dense weighted adjacency over the
dictionary-encoded activity alphabet **plus two artificial nodes** —

* node ``A``     — the artificial source ``▶`` (edges ``▶ -> a`` weighted
  by the start-activity histogram);
* node ``A + 1`` — the artificial sink ``■`` (edges ``a -> ■`` weighted by
  the end-activity histogram).

The artificial nodes turn per-activity start/end histograms into ordinary
edges, so "from process start" / "to process end" questions are plain
(source, sink) entries of the all-pairs query answers in
``repro.graph.queries``.  Frequencies are the exact int32 counts of the
underlying state — compiling is a pure reshaping of already-merged state,
so a graph built from eager / streamed / psum-merged / window-merged state
is bitwise identical whenever the states are.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.dfg import DFG

START_LABEL = "▶"
END_LABEL = "■"


@dataclasses.dataclass(frozen=True)
class ProcessGraph:
    """Dense process graph over ``num_activities + 2`` nodes.

    ``freq[i, j]`` is the exact directly-follows count (start/end
    histogram counts on the artificial rows/columns); ``perf`` — present
    only when compiled with a performance overlay — is the mean waiting
    time per edge (0 on artificial edges: the source/sink are
    instantaneous bookkeeping).  ``labels`` is attached by the facade
    (kernels never see dictionary tables) and excluded from parity
    comparisons by construction: engines produce ``labels=None``.
    """

    freq: jax.Array                      # (N, N) int32
    num_activities: int
    perf: jax.Array | None = None        # (N, N) float32 mean waits
    labels: tuple[str, ...] | None = None

    @property
    def num_nodes(self) -> int:
        return self.num_activities + 2

    @property
    def source(self) -> int:
        return self.num_activities

    @property
    def sink(self) -> int:
        return self.num_activities + 1

    @property
    def adjacency(self) -> jax.Array:
        """(N, N) bool — at least one observed traversal."""
        return self.freq > 0

    def node_labels(self) -> tuple[str, ...]:
        if self.labels is not None:
            return self.labels + (START_LABEL, END_LABEL)
        return tuple(f"a{i}" for i in range(self.num_activities)) + \
            (START_LABEL, END_LABEL)

    def with_labels(self, labels) -> "ProcessGraph":
        labels = tuple(str(x) for x in labels)
        if len(labels) != self.num_activities:
            raise ValueError(f"{len(labels)} labels for "
                             f"{self.num_activities} activities")
        return dataclasses.replace(self, labels=labels)

    def edges(self):
        """Host-side sparse view: ((src, dst), count [, mean_wait])."""
        import numpy as np

        f = np.asarray(self.freq)
        p = None if self.perf is None else np.asarray(self.perf)
        out = []
        for a, b in zip(*np.nonzero(f)):
            e = ((int(a), int(b)), int(f[a, b]))
            out.append(e if p is None else e + (float(p[a, b]),))
        return out


def compile_graph(state: "DFG | object", perf: jax.Array | None = None,
                  labels=None) -> ProcessGraph:
    """Compile mined state into a :class:`ProcessGraph`.

    ``state`` is a :class:`~repro.core.dfg.DFG` or anything carrying one
    (``DiscoveryState.dfg``); ``perf`` is an optional (A, A) mean-wait
    matrix (``performance_dfg``'s second output) embedded on the real
    edges.
    """
    dfg = state.dfg if hasattr(state, "dfg") else state
    if not isinstance(dfg, DFG):
        raise TypeError(f"cannot compile a {type(state).__name__} into a "
                        f"ProcessGraph (expected DFG-backed state)")
    a = dfg.num_activities
    n = a + 2
    freq = jnp.zeros((n, n), jnp.int32)
    freq = freq.at[:a, :a].set(dfg.counts.astype(jnp.int32))
    freq = freq.at[a, :a].set(dfg.starts.astype(jnp.int32))
    freq = freq.at[:a, a + 1].set(dfg.ends.astype(jnp.int32))
    pw = None
    if perf is not None:
        pw = jnp.zeros((n, n), jnp.float32)
        pw = pw.at[:a, :a].set(jnp.asarray(perf, jnp.float32))
    g = ProcessGraph(freq=freq, num_activities=a, perf=pw)
    return g.with_labels(labels) if labels is not None else g
