"""Model export: mined objects → PM4Py-compatible interchange formats.

The mining side of this repo is columnar and dictionary-encoded; the rest
of the process-mining world speaks PNML Petri nets, DOT graphs, process
trees, and DFG JSON.  This module is the bridge — every exporter is pure
host-side serialization of an already-finalized model (no JAX in the
loop), and the formats round-trip:

* :func:`alpha_to_pnml` / :func:`read_pnml` — the alpha miner's
  :class:`~repro.core.discovery.AlphaModel` as a PNML 2009 place/transition
  net; the reader parses any of our nets back structurally, and
  :func:`pnml_places` recovers the exact ``(A, B)`` place pairs for the
  round-trip test.
* :func:`heuristics_to_dot` / :func:`graph_to_dot` — Graphviz DOT of a
  :class:`~repro.core.discovery.HeuristicsNet` dependency graph or a
  :class:`~repro.graph.ir.ProcessGraph` (edge labels: dependency measure /
  frequency + mean wait).
* :func:`discover_process_tree` — a compact inductive-style cut finder
  over accumulated DFG state emitting PM4Py process-tree notation
  (``->(...)``, ``X(...)``, ``+(...)``, ``*(...)``, ``tau``): xor cut
  (weak components), sequence cut (condensation of SCCs merged by
  incomparability), parallel cut (complement components), loop cut
  (redo components re-entering the starts), flower fallthrough.
* :func:`dfg_to_json` / :func:`dfg_from_json` — the DFG + start/end
  histograms as PM4Py-style ``dfg.json`` (labelled edge triples); the
  importer reconstructs the dense :class:`~repro.core.dfg.DFG` bitwise.
* :func:`frame_to_xes` / :func:`frame_from_xes` — EventFrame ↔ XES via
  ``storage.xes`` (ISO-8601 timestamps); re-import preserves
  (case, time) order and activity spelling, so re-mining reproduces the
  DFG state bitwise (the test in ``tests/test_graph.py``).
"""
from __future__ import annotations

import json
import xml.etree.ElementTree as ET
from xml.sax.saxutils import escape

import numpy as np

from repro.core.classic_log import ClassicEventLog
from repro.core.dfg import DFG
from repro.core.discovery import AlphaModel, HeuristicsNet
from repro.core.eventframe import EventFrame

from .ir import ProcessGraph


def _labels(num_activities: int, labels=None) -> list[str]:
    if labels is None:
        return [f"a{i}" for i in range(num_activities)]
    out = [str(x) for x in labels]
    if len(out) != num_activities:
        raise ValueError(f"{len(out)} labels for {num_activities} activities")
    return out


# ------------------------------------------------------------------ PNML
def alpha_to_pnml(model: AlphaModel, labels=None, *,
                  net_id: str = "alpha") -> str:
    """Serialize an :class:`AlphaModel` as a PNML 2009 P/T net.

    One transition per activity; one place per discovered ``(A, B)`` pair
    (``id="p<i>"``) plus ``source``/``sink`` wired to the start/end
    activities — the standard alpha-net construction, in the grammar
    PM4Py's ``pnml`` importer reads.
    """
    lab = _labels(model.num_activities, labels)
    lines = ['<?xml version="1.0" encoding="UTF-8"?>',
             '<pnml xmlns="http://www.pnml.org/version-2009/grammar/pnml">',
             f'  <net id="{net_id}" '
             'type="http://www.pnml.org/version-2009/grammar/ptnet">',
             '    <page id="page1">']

    def place(pid, marking=0):
        lines.append(f'      <place id="{pid}">')
        lines.append(f'        <name><text>{escape(pid)}</text></name>')
        if marking:
            lines.append('        <initialMarking>'
                         f'<text>{marking}</text></initialMarking>')
        lines.append('      </place>')

    place("source", marking=1)
    place("sink")
    for i in range(len(model.places)):
        place(f"p{i}")
    for a in range(model.num_activities):
        lines.append(f'      <transition id="t{a}">')
        lines.append(f'        <name><text>{escape(lab[a])}</text></name>')
        lines.append('      </transition>')
    arcs = []
    for a in sorted(model.start_activities):
        arcs.append(("source", f"t{a}"))
    for a in sorted(model.end_activities):
        arcs.append((f"t{a}", "sink"))
    for i, (ins, outs) in enumerate(model.places):
        for a in sorted(ins):
            arcs.append((f"t{a}", f"p{i}"))
        for b in sorted(outs):
            arcs.append((f"p{i}", f"t{b}"))
    for j, (src, dst) in enumerate(arcs):
        lines.append(f'      <arc id="arc{j}" source="{src}" '
                     f'target="{dst}"/>')
    lines += ['    </page>', '  </net>', '</pnml>', '']
    return "\n".join(lines)


def read_pnml(source: str):
    """Structural parse of a PNML net (path or XML string).

    Returns ``(places, transitions, arcs)``: place ids with initial
    markings, transition ``id -> label``, and ``(source, target)`` id
    pairs — namespace-agnostic, enough to verify any exported net
    round-trips.
    """
    text = source if source.lstrip().startswith("<") else open(source).read()
    root = ET.fromstring(text)

    def local(tag):
        return tag.rsplit("}", 1)[-1]

    places: dict[str, int] = {}
    transitions: dict[str, str] = {}
    arcs: list[tuple[str, str]] = []
    for el in root.iter():
        kind = local(el.tag)
        if kind == "place":
            marking = 0
            for sub in el.iter():
                if local(sub.tag) == "initialMarking":
                    for t in sub.iter():
                        if local(t.tag) == "text":
                            marking = int(t.text)
            places[el.get("id")] = marking
        elif kind == "transition":
            label = el.get("id")
            for sub in el.iter():
                if local(sub.tag) == "name":
                    for t in sub.iter():
                        if local(t.tag) == "text":
                            label = t.text
            transitions[el.get("id")] = label
        elif kind == "arc":
            arcs.append((el.get("source"), el.get("target")))
    return places, transitions, arcs


def pnml_places(source: str):
    """Recover the alpha ``(A, B)`` pairs from an exported net: for each
    internal place, the frozensets of transition indices wired in/out —
    compared against ``AlphaModel.places`` by the round-trip test."""
    places, transitions, arcs = read_pnml(source)
    t_index = {tid: i for i, tid in
               enumerate(sorted(transitions, key=lambda t: int(t[1:])))}
    pairs = {}
    for src, dst in arcs:
        if dst in places and dst not in ("source", "sink"):
            pairs.setdefault(dst, (set(), set()))[0].add(t_index[src])
        elif src in places and src not in ("source", "sink"):
            pairs.setdefault(src, (set(), set()))[1].add(t_index[dst])
    starts = frozenset(t_index[d] for s, d in arcs if s == "source")
    ends = frozenset(t_index[s] for s, d in arcs if d == "sink")
    place_pairs = tuple(sorted(
        ((frozenset(i), frozenset(o)) for i, o in pairs.values()),
        key=lambda p: (sorted(p[0]), sorted(p[1]))))
    return place_pairs, starts, ends


# ------------------------------------------------------------------- DOT
def heuristics_to_dot(net: HeuristicsNet, labels=None, *,
                      name: str = "heuristics") -> str:
    """Graphviz DOT of the thresholded dependency graph (edge label =
    dependency measure, 2 decimals — PM4Py's heuristics-net visualizer
    convention)."""
    lab = _labels(net.num_activities, labels)
    lines = [f'digraph "{name}" {{', '  rankdir=LR;',
             '  node [shape=box];']
    used = sorted({n for (a, b), _ in net.edges() for n in (a, b)}
                  | net.start_activities | net.end_activities)
    for a in used:
        lines.append(f'  n{a} [label="{escape(lab[a])}"];')
    lines.append('  __start [shape=circle, label="", style=filled, '
                 'fillcolor=green];')
    lines.append('  __end [shape=doublecircle, label="", style=filled, '
                 'fillcolor=orange];')
    for a in sorted(net.start_activities):
        lines.append(f'  __start -> n{a};')
    for a in sorted(net.end_activities):
        lines.append(f'  n{a} -> __end;')
    for (a, b), dep in net.edges():
        lines.append(f'  n{a} -> n{b} [label="{dep:.2f}"];')
    lines.append('}')
    return "\n".join(lines) + "\n"


def graph_to_dot(g: ProcessGraph, *, name: str = "process") -> str:
    """Graphviz DOT of a :class:`ProcessGraph` (edge label = frequency,
    plus mean wait when the performance overlay is present)."""
    lab = g.node_labels()
    lines = [f'digraph "{name}" {{', '  rankdir=LR;',
             '  node [shape=box];',
             f'  n{g.source} [shape=circle, style=filled, '
             'fillcolor=green];',
             f'  n{g.sink} [shape=doublecircle, style=filled, '
             'fillcolor=orange];']
    for e in g.edges():
        (a, b), cnt = e[0], e[1]
        label = str(cnt) if len(e) == 2 else f"{cnt} ({e[2]:.2f}s)"
        lines.append(f'  n{a} -> n{b} [label="{label}"];')
    for n in sorted({v for e in g.edges() for v in e[0]}
                    - {g.source, g.sink}):
        lines.append(f'  n{n} [label="{escape(lab[n])}"];')
    lines.append('}')
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------- process tree
def _cc(nodes, edges):
    """Connected components over an undirected edge set."""
    parent = {n: n for n in nodes}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        parent[find(a)] = find(b)
    comps: dict = {}
    for n in nodes:
        comps.setdefault(find(n), set()).add(n)
    return list(comps.values())


def _sccs(nodes, succ):
    """Tarjan over the restricted successor map (iterative)."""
    index, low, on, stack, out = {}, {}, set(), [], []
    counter = [0]
    for root in nodes:
        if root in index:
            continue
        work = [(root, iter(succ[root]))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on.add(w)
                    work.append((w, iter(succ[w])))
                    advanced = True
                    break
                if w in on:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[v])
            if low[v] == index[v]:
                comp = set()
                while True:
                    w = stack.pop()
                    on.discard(w)
                    comp.add(w)
                    if w == v:
                        break
                out.append(comp)
    return out


def _tree(nodes, edges, starts, ends, lab, depth=0):
    nodes = set(nodes)
    e = {(a, b) for a, b in edges if a in nodes and b in nodes and a != b}
    selfloops = {a for a, b in edges if a == b and a in nodes}
    if len(nodes) == 1:
        (a,) = nodes
        leaf = f"'{lab[a]}'"
        return f"*( {leaf}, tau )" if a in selfloops else leaf
    succ = {n: sorted({b for a, b in e if a == n}) for n in nodes}

    def recurse(group, g_starts, g_ends):
        return _tree(group, edges, g_starts & group or _entry(group),
                     g_ends & group or _exit(group), lab, depth + 1)

    def _entry(group):
        ins = {b for a, b in e if a not in group and b in group}
        return ins or set(group)

    def _exit(group):
        outs = {a for a, b in e if a in group and b not in group}
        return outs or set(group)

    # xor cut: weakly connected components
    comps = _cc(nodes, {(a, b) for a, b in e})
    if len(comps) > 1 and depth < 16:
        parts = [recurse(c, starts, ends) for c in
                 sorted(comps, key=lambda c: sorted(c))]
        return "X( " + ", ".join(parts) + " )"
    # sequence cut: condensation of SCCs, incomparable classes merged
    sccs = _sccs(sorted(nodes), succ)
    if len(sccs) > 1 and depth < 16:
        reach = {i: set() for i in range(len(sccs))}
        node_scc = {n: i for i, c in enumerate(sccs) for n in c}
        for a, b in e:
            if node_scc[a] != node_scc[b]:
                reach[node_scc[a]].add(node_scc[b])
        for k in range(len(sccs)):          # transitive closure
            for i in range(len(sccs)):
                if k in reach[i]:
                    reach[i] |= reach[k]
        group_of = list(range(len(sccs)))
        for i in range(len(sccs)):
            for j in range(i + 1, len(sccs)):
                if j not in reach[i] and i not in reach[j]:
                    gj, gi = group_of[j], group_of[i]
                    group_of = [gi if g == gj else g for g in group_of]
        groups: dict[int, set] = {}
        for i, g in enumerate(group_of):
            groups.setdefault(g, set()).update(sccs[i])
        ordered = sorted(groups.values(),
                         key=lambda grp: sum(
                             1 for other in groups.values()
                             if other is not grp and any(
                                 node_scc[n] in reach[node_scc[m]]
                                 for m in other for n in grp)))
        if len(ordered) > 1:
            total = all(
                all(node_scc[n] in reach[node_scc[m]]
                    for m in ordered[i] for n in ordered[i + 1])
                for i in range(len(ordered) - 1))
            if total:
                parts = [recurse(g, starts if i == 0 else set(),
                                 ends if i == len(ordered) - 1 else set())
                         for i, g in enumerate(ordered)]
                return "->( " + ", ".join(parts) + " )"
    # parallel cut: components of the missing-double-edge graph
    missing = {(a, b) for a in nodes for b in nodes if a < b
               and not ((a, b) in e and (b, a) in e)}
    pcomps = _cc(nodes, missing)
    if len(pcomps) > 1 and depth < 16 and all(
            c & starts and c & ends for c in pcomps):
        parts = [recurse(c, starts, ends) for c in
                 sorted(pcomps, key=lambda c: sorted(c))]
        return "+( " + ", ".join(parts) + " )"
    # loop cut: redo components whose edges re-enter the starts
    body = set(starts) | set(ends)
    rest = nodes - body
    if rest and depth < 16:
        redo_comps = _cc(rest, {(a, b) for a, b in e
                                if a in rest and b in rest})
        redos = [c for c in redo_comps
                 if all(a in ends for a, b in e if b in c and a not in c)
                 and all(b in starts for a, b in e if a in c and b not in c)]
        if redos:
            do = nodes - set().union(*redos)
            parts = [recurse(do, starts, ends)]
            parts += [recurse(c, _entry(c), _exit(c)) for c in
                      sorted(redos, key=lambda c: sorted(c))]
            return "*( " + ", ".join(parts) + " )"
    # fallthrough: flower model
    leaves = ", ".join(f"'{lab[a]}'" for a in sorted(nodes))
    return f"*( tau, {leaves} )"


def discover_process_tree(source: "DFG | ProcessGraph", labels=None) -> str:
    """Inductive-style process tree over accumulated DFG state, in PM4Py
    notation (see module docstring).  A compact IMd: cuts are found on the
    directly-follows graph alone, with the flower model as fallthrough —
    guaranteed fitness, precision only as good as the cuts."""
    if isinstance(source, ProcessGraph):
        a = source.num_activities
        counts = np.asarray(source.freq[:a, :a])
        starts = np.asarray(source.freq[source.source, :a])
        ends = np.asarray(source.freq[:a, source.sink])
        lab = list(source.node_labels()[:a]) if labels is None else None
    elif isinstance(source, DFG):
        a = source.num_activities
        counts = np.asarray(source.counts)
        starts = np.asarray(source.starts)
        ends = np.asarray(source.ends)
        lab = None
    else:
        raise TypeError(f"cannot build a process tree from "
                        f"{type(source).__name__}")
    if lab is None:
        lab = _labels(a, labels)
    observed = {int(i) for i in
                np.nonzero(counts.sum(0) + counts.sum(1)
                           + starts + ends)[0]}
    if not observed:
        return "tau"
    edges = {(int(x), int(y)) for x, y in zip(*np.nonzero(counts))}
    s = {int(i) for i in np.nonzero(starts)[0]}
    t = {int(i) for i in np.nonzero(ends)[0]}
    return _tree(observed, edges, s, t, lab)


# ------------------------------------------------------------- DFG JSON
def dfg_to_json(d: DFG, labels=None) -> str:
    """PM4Py-style ``dfg.json``: labelled edge triples plus start/end
    activity histograms (the format ``pm4py.read_dfg`` round-trips)."""
    lab = _labels(d.num_activities, labels)
    counts = np.asarray(d.counts)
    starts = np.asarray(d.starts)
    ends = np.asarray(d.ends)
    return json.dumps({
        "activities": lab,
        "dfg": [[lab[a], lab[b], int(counts[a, b])]
                for a, b in zip(*np.nonzero(counts))],
        "start_activities": {lab[i]: int(starts[i])
                             for i in np.nonzero(starts)[0]},
        "end_activities": {lab[i]: int(ends[i])
                           for i in np.nonzero(ends)[0]},
    }, indent=2)


def dfg_from_json(text: str) -> tuple[DFG, list[str]]:
    """Inverse of :func:`dfg_to_json`: the dense :class:`DFG` (bitwise
    round-trip) plus the activity labels."""
    import jax.numpy as jnp

    doc = json.loads(text)
    lab = list(doc["activities"])
    index = {l: i for i, l in enumerate(lab)}
    a = len(lab)
    counts = np.zeros((a, a), np.int32)
    for src, dst, cnt in doc["dfg"]:
        counts[index[src], index[dst]] = cnt
    starts = np.zeros((a,), np.int32)
    ends = np.zeros((a,), np.int32)
    for l, cnt in doc["start_activities"].items():
        starts[index[l]] = cnt
    for l, cnt in doc["end_activities"].items():
        ends[index[l]] = cnt
    return DFG(jnp.asarray(counts), jnp.asarray(starts),
               jnp.asarray(ends)), lab


# -------------------------------------------------------------- XES I/O
def frame_to_xes(path: str, frame: EventFrame,
                 tables: dict[str, list] | None = None) -> None:
    """Write a (case, time)-sorted EventFrame as XES (dictionary columns
    decoded through ``tables``; timestamps ISO-8601 via ``storage.xes``)."""
    from repro.storage import xes

    xes.write(path, ClassicEventLog.from_eventframe(frame, tables))


def frame_from_xes(path: str) -> tuple[EventFrame, dict[str, list]]:
    """Read XES back into a dictionary-encoded EventFrame + string tables
    (first-seen encoding in (case, time) order — re-mining an exported
    frame reproduces the original DFG state bitwise)."""
    from repro.storage import xes

    return xes.read(path).to_eventframe()
