"""Version-compatibility shims for jax APIs that moved between releases.

The repo targets recent jax, but the pinned toolchain in some environments
ships 0.4.x where ``shard_map`` still lives under ``jax.experimental``,
``jax.sharding.AxisType`` / ``jax.set_mesh`` / ``get_abstract_mesh`` do not
exist yet, and ``shard_map`` spells its replication check ``check_rep``
instead of ``check_vma``. Import the names from here instead of from jax.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5
    from jax import shard_map as _shard_map
    _NEW_SHARD_MAP = True
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _NEW_SHARD_MAP = False

try:  # jax >= 0.6
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:
    AxisType = None  # type: ignore[assignment]


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename papered
    over (we only use keyword form at call sites)."""
    if not _NEW_SHARD_MAP and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def make_mesh(axis_shapes, axis_names, *, explicit: bool = False):
    """``jax.make_mesh`` with ``axis_types`` only where the API supports it."""
    if AxisType is None:
        return jax.make_mesh(axis_shapes, axis_names)
    kind = AxisType.Explicit if explicit else AxisType.Auto
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(kind,) * len(axis_names))


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    New jax: ``jax.set_mesh``. Old jax: ``Mesh`` is itself a context
    manager entering the thread-local physical mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh) if mesh is None else mesh


def get_abstract_mesh():
    """The ambient mesh, or an empty mesh when none is installed."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh
