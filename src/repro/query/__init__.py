"""Lazy columnar query subsystem: ``scan -> filter -> project -> mine``.

The paper's scalability argument rests on filtering and attribute
selection being cheap *columnar* operations; this package completes the
story by deciding — from EDFV0003 zone maps, before any data I/O — which
row groups cannot possibly contribute and never reading their bytes.
Plans compile down to the existing chunk-kernel engine, so every miner
(DFG, stats, variants, alpha, heuristics) runs over a pruned scan with
results bitwise identical to filter-then-mine on the whole log.

    from repro.query import scan, col, cases_containing, execute
    plan = scan("log.edf").filter(col("time:timestamp").between(t0, t1))
    dfg, report = execute(plan, mine=dfg_kernel(num_activities))
    print(report.groups_skipped, report.bytes_read, report.bytes_total)
"""
from .exec import (ScanReport, execute, execute_frame,  # noqa: F401
                   pruned_source)
from .expr import (CasePredicate, Col, Expr, case_size,  # noqa: F401
                   cases_containing, col)
from .optimize import PhysicalPlan, compile_plan  # noqa: F401
from .plan import Plan, scan  # noqa: F401

__all__ = [
    "CasePredicate", "Col", "Expr", "Plan", "PhysicalPlan", "ScanReport",
    "case_size", "cases_containing", "col", "compile_plan", "execute",
    "execute_frame", "pruned_source", "scan",
]
