"""Lazy columnar query subsystem: ``scan -> filter -> project -> mine``.

The paper's scalability argument rests on filtering and attribute
selection being cheap *columnar* operations; this package completes the
story by deciding — from EDFV0003 zone maps, before any data I/O — which
row groups cannot possibly contribute and never reading their bytes.
Plans compile down to the existing chunk-kernel engine, so every miner
(DFG, stats, variants, alpha, heuristics) runs over a pruned scan with
results bitwise identical to filter-then-mine on the whole log.  A
:class:`MultiPlan` widens a scan to an ordered *set* of EDF files (one
logical plan, N pruned scans, one kernel driven across all of them).

This package is the planner/executor IR; the user-facing surface is the
``repro.dataset`` facade::

    import repro
    ds = repro.open(["jan.edf", "feb.edf"]).filter(repro.col("a") == 3)
    graph = ds.dfg()                      # engine picked by cost, I/O pruned
"""
from .exec import (ScanReport, count_cases, execute,  # noqa: F401
                   execute_frame, merge_reports, multi_pruned_source,
                   pruned_source)
from .expr import (CasePredicate, Col, Expr, SketchPredicate,  # noqa: F401
                   case_size, cases_containing, col, variant_in, variant_of)
from .optimize import PhysicalPlan, compile_plan  # noqa: F401
from .plan import MultiPlan, Plan, scan, scan_many  # noqa: F401

__all__ = [
    "CasePredicate", "Col", "Expr", "MultiPlan", "Plan", "PhysicalPlan",
    "ScanReport", "SketchPredicate", "case_size", "cases_containing", "col",
    "compile_plan", "count_cases", "execute", "execute_frame",
    "merge_reports", "multi_pruned_source", "pruned_source", "scan",
    "scan_many", "variant_in", "variant_of",
]
