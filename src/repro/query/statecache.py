"""Process-wide LRU cache of folded per-group kernel states.

The group-state algebra (``repro.core.engine``) makes the fresh fold of a
row group a first-class, re-mergeable value: this module keeps those
:class:`~repro.core.engine.GroupState` values resident so a collect after
an append only decodes *fresh* groups, and a sliding window re-merges its
ring of cached states instead of rescanning.

Keys are fully content-addressed::

    (kernel-spec fingerprint, file path, group index,
     group content signature, residual-predicate fingerprint)

* the *spec fingerprint* (:func:`spec_fingerprint`) covers the verb name,
  its kwargs, both capacity dims, and the resolved segment backend — two
  different kernels can never alias;
* the *group signature* (``EDFReader.group_signature``) hashes the group's
  content metadata, never offsets, so appends that leave old groups' bytes
  alone keep old entries valid while any rewrite invalidates them;
* the *residual fingerprint* is ``""`` for groups folded unfiltered **or**
  proved entirely by zone maps — a time-window's interior groups therefore
  share cache entries with the unfiltered collect — and the predicate
  repr for groups that fold under a residual row mask.

Capacity is bounded in bytes (``REPRO_STATE_CACHE_BYTES``, default 256 MiB,
``0`` disables caching); eviction is LRU.  Cached states are the exact jax
arrays the fold produced — a hit is a pointer copy, never a recompute.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Hashable

import jax

from repro.core import backend as _backend
from repro.core.engine import Dims, GroupState

ENV_VAR = "REPRO_STATE_CACHE_BYTES"
DEFAULT_BYTES = 256 * 1024 * 1024

# per-entry bookkeeping overhead charged on top of the array payload
_ENTRY_OVERHEAD = 512


def spec_fingerprint(verb: str, dims: Dims, kwargs: dict | None = None) -> tuple:
    """Content fingerprint of one kernel build: what makes two folded
    group states interchangeable.  Includes both capacity dims (state
    shapes) and the resolved segment backend (lowering choice is part of
    the kernel cache key everywhere else too)."""
    items = tuple(sorted((k, repr(v)) for k, v in (kwargs or {}).items()))
    return (verb, int(dims.num_activities), int(dims.num_cases), items,
            _backend.resolve(None))


def state_nbytes(gs: GroupState) -> int:
    """Resident bytes of one cached group state (array payload + halo)."""
    total = _ENTRY_OVERHEAD
    for leaf in jax.tree.leaves((gs.state, gs.carry)):
        total += int(getattr(leaf, "nbytes", 8))
    return total


class StateCache:
    """Thread-safe byte-bounded LRU of :class:`GroupState` values."""

    def __init__(self, capacity_bytes: int = DEFAULT_BYTES):
        self.capacity_bytes = int(capacity_bytes)
        self._entries: OrderedDict[Hashable, tuple[GroupState, int]] = \
            OrderedDict()
        self._lock = threading.Lock()
        self.bytes = 0
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: Hashable) -> GroupState | None:
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return hit[0]

    def contains(self, key: Hashable) -> bool:
        """Probe without touching LRU order or hit/miss counters (what
        ``Dataset.explain`` uses to report would-be cache behaviour)."""
        with self._lock:
            return key in self._entries

    def put(self, key: Hashable, gs: GroupState) -> None:
        if self.capacity_bytes <= 0:
            return
        nbytes = state_nbytes(gs)
        if nbytes > self.capacity_bytes:
            return                      # larger than the whole cache
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes -= old[1]
            self._entries[key] = (gs, nbytes)
            self.bytes += nbytes
            while self.bytes > self.capacity_bytes and self._entries:
                _, (_, evicted) = self._entries.popitem(last=False)
                self.bytes -= evicted

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.bytes = 0
            self.hits = 0
            self.misses = 0


_CACHE: StateCache | None = None
_CACHE_LOCK = threading.Lock()


def state_cache() -> StateCache:
    """The process-wide cache (capacity from ``REPRO_STATE_CACHE_BYTES``)."""
    global _CACHE
    with _CACHE_LOCK:
        if _CACHE is None:
            raw = os.environ.get(ENV_VAR)
            capacity = int(raw) if raw not in (None, "") else DEFAULT_BYTES
            _CACHE = StateCache(capacity)
        return _CACHE
