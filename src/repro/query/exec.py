"""Pruned plan execution: ghost carries, residual masks, chunk kernels.

``execute(plan, mine=kernel)`` drives the surviving row groups of a
compiled plan through any ``repro.core.engine`` chunk kernel.  The
contract is **bitwise identity** with the eager pipeline the plan
replaces: ``mine(filterN(...filter1(edf.read(path))))`` — while reading
strictly fewer bytes whenever the zone maps refute any group.

Two mechanisms make the pruned stream indistinguishable from the full
one for the kernels:

* **residual masks** — each read group's chunk arrives with
  ``row_valid`` = the conjunction of every predicate the zone maps could
  not decide (plus the broadcast case-level keep masks), exactly the
  lazy ``ops.proj`` mask the eager filters would have produced.  The
  kernels already fold ``rows_valid()`` into every update, so a masked
  chunk contributes precisely what the filtered whole log would.
* **ghost chunks** — a run of skipped groups is replaced by an
  O(segments) synthetic chunk: one all-masked row per case segment, case
  ids rising from the run's first case to its recorded tail, last row
  carrying the persisted tail halo.  Driving it through the kernel's own
  ``update`` advances the carry — case id, one/two-row halo, *global
  segment numbering* — exactly as the unread rows would have (they are
  all refuted, hence all masked), at a cost independent of the run's row
  count.  Kernels that consume masked rows (``mask_exact=False``, e.g.
  variants' validity-blind hashing) opt out and are streamed unpruned.

``execute_frame`` materializes the filtered, projected frame instead
(equal to ``filterN(...).compact()``); ``pruned_source`` exposes the
pruned stream as a re-iterable ``ChunkedEventFrame`` for custom drivers
(``repro.distributed.query`` shards it across devices).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.chunked import ChunkedEventFrame
from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from repro.storage.edf import EDFReader

from .expr import CasePredicate
from .optimize import GhostItem, PhysicalPlan, ReadItem, compile_plan
from .plan import Plan


# ------------------------------------------------------------- reporting
@dataclasses.dataclass
class ScanReport:
    """I/O accounting for one executed plan (all byte counts are on-disk
    compressed extents of the scan's projected column set)."""

    path: str
    columns: tuple
    pruned: bool
    groups_total: int = 0
    groups_read: int = 0
    groups_skipped: int = 0
    groups_proved: int = 0      # read groups whose residual mask was proved
    rows_total: int = 0
    rows_read: int = 0
    bytes_total: int = 0
    bytes_read: int = 0
    phase1_groups_read: int = 0
    phase1_bytes_read: int = 0

    @property
    def skip_ratio(self) -> float:
        return self.groups_skipped / self.groups_total if self.groups_total else 0.0

    @property
    def bytes_saved_ratio(self) -> float:
        if not self.bytes_total:
            return 0.0
        return 1.0 - self.bytes_read / self.bytes_total

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["columns"] = list(self.columns)
        out["skip_ratio"] = self.skip_ratio
        out["bytes_saved_ratio"] = self.bytes_saved_ratio
        return out


def _account(report: ScanReport, physical: PhysicalPlan, schedule,
             read_columns, phase1: bool = False) -> None:
    reader = physical.reader
    for item in schedule:
        if isinstance(item, GhostItem):
            continue
        nbytes = reader.group_nbytes(item.index, read_columns)
        if phase1:
            report.phase1_groups_read += 1
            report.phase1_bytes_read += nbytes
        else:
            report.groups_read += 1
            report.bytes_read += nbytes
            report.rows_read += reader.group_nrows(item.index)
            if not item.residual and physical.steps:
                report.groups_proved += 1


# ----------------------------------------------------------- the stream
def _ghost_chunk(item: GhostItem, chunk_columns, reader: EDFReader
                 ) -> EventFrame:
    """One all-masked row per case segment of a skipped run (padded to a
    power of two so ghost shapes retrace the kernel O(log) times)."""
    d = max(int(item.segments), 1)
    m = 1 << (d - 1).bit_length()
    tail_vals = item.tail["values"]
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    for name in chunk_columns:
        meta = reader.schema[name]
        dtype = np.dtype(meta["dtype"])
        if name == CASE:
            arr = np.full(m, tail_vals[CASE], dtype)
            if d > 1:
                arr[:d - 1] = item.first_case + np.arange(d - 1)
        else:
            arr = np.zeros(m, dtype)
            arr[d - 1:] = dtype.type(tail_vals.get(name, 0))
        cols[name] = arr
        if meta.get("has_valid"):
            # every ghost row is row-masked, but the tail halo keeps its
            # persisted epsilon flag so the carry is faithful to the file
            v = np.ones(m, bool)
            v[d - 1:] = bool(item.tail.get("valid", {}).get(name, True))
            valid[name] = v
    frame = EventFrame.from_numpy(cols, valid)
    return EventFrame(frame.columns, frame.valid, jnp.zeros(m, bool))


def _iter_chunks(physical: PhysicalPlan, schedule, keeps: dict,
                 chunk_columns, read_columns):
    """Yield the pruned chunk stream: read groups with residual masks,
    ghost chunks for skipped runs.  Tracks global segment numbering
    sequentially (read groups from their case column, ghost runs from
    metadata), so case-level keep masks broadcast to the right rows."""
    reader = physical.reader
    steps = physical.steps
    # global segment ids are only materialized when a keep mask needs the
    # broadcast; ghost continuation needs just the previous case id
    track_segs = any(getattr(item, "case_steps", ()) for item in schedule)
    last_seg = -1
    prev_case = None
    for item in schedule:
        if isinstance(item, GhostItem):
            cont = prev_case is not None and item.first_case == prev_case
            yield _ghost_chunk(item, chunk_columns, reader)
            last_seg += int(item.segments) - (1 if cont else 0)
            prev_case = item.tail["values"][CASE]
            continue
        frame = reader.read_group(item.index, read_columns)
        mask = np.ones(frame.nrows, bool)
        for pos in item.residual:
            mask &= np.asarray(steps[pos].mask(frame), bool)
        if CASE in frame and frame.nrows:
            case = np.asarray(frame[CASE])
            if track_segs:
                new0 = prev_case is None or case[0] != prev_case
                seg = last_seg + int(new0) + np.concatenate(
                    [[0], np.cumsum(case[1:] != case[:-1])])
                for pos in item.case_steps:
                    keep = keeps[pos]
                    seg_c = np.minimum(seg, len(keep) - 1)
                    mask &= keep[seg_c] & (seg < len(keep))
                last_seg = int(seg[-1])
            prev_case = case[-1]
        sel = frame.select(chunk_columns)
        yield EventFrame(sel.columns, sel.valid, jnp.asarray(mask))


def _phase1_keeps(physical: PhysicalPlan, report: ScanReport) -> dict:
    """Run phase one of every case predicate, in plan order, each pass
    pruned by the steps that precede it."""
    keeps: dict = {}
    for pos, step in enumerate(physical.steps):
        if not isinstance(step, CasePredicate):
            continue
        if physical.num_cases is None:
            raise ValueError(
                f"case-level predicates need a {CASE!r} column with "
                f"per-group segment metadata in {physical.plan.path!r}")
        chunk_cols = tuple(sorted({CASE, ACTIVITY} | set(step.columns())))
        read = set(chunk_cols)
        for i in range(pos):
            s = physical.steps[i]
            if not isinstance(s, CasePredicate):
                read |= s.columns()
        schedule = physical.phase1_schedule(pos, keeps)
        _account(report, physical, schedule, tuple(sorted(read)), phase1=True)
        result = engine.run_streaming(
            step.phase1_kernel(physical.num_cases),
            _iter_chunks(physical, schedule, keeps, chunk_cols,
                         tuple(sorted(read))))
        keeps[pos] = np.asarray(step.finalize_keep(result), bool)
    return keeps


def _base_report(physical: PhysicalPlan) -> ScanReport:
    reader = physical.reader
    report = ScanReport(physical.plan.path, physical.read_columns,
                        physical.prune)
    for g in range(reader.num_groups):
        n = reader.group_nrows(g)
        if n == 0:
            continue
        report.groups_total += 1
        report.rows_total += n
        report.bytes_total += reader.group_nbytes(g, physical.read_columns)
    return report


# ------------------------------------------------------------ public API
def pruned_source(plan: Plan, *, prune: bool = True, mask_exact: bool = True
                  ) -> tuple[ChunkedEventFrame, ScanReport]:
    """Compile a plan into a re-iterable pruned chunk stream.

    ``mask_exact=False`` keeps every group in the stream (residual masks
    only) for consumers that inspect masked rows.  The returned source
    plugs into ``engine.run_streaming`` / ``repro.distributed.query``.
    """
    physical = compile_plan(plan, prune)
    report = _base_report(physical)
    keeps = _phase1_keeps(physical, report)
    schedule = physical.final_schedule(keeps, ghosts=mask_exact,
                                       skippable=mask_exact)
    _account(report, physical, schedule, physical.read_columns)
    report.groups_skipped = report.groups_total - report.groups_read
    src = ChunkedEventFrame(
        lambda: _iter_chunks(physical, schedule, keeps,
                             physical.chunk_columns, physical.read_columns),
        num_chunks=len(schedule), tables=dict(physical.reader.tables))
    return src, report


def execute(plan: Plan, mine: engine.ChunkKernel, *, prune: bool = True):
    """Fold a chunk kernel over the pruned scan of ``plan``.

    Returns ``(result, report)`` with ``result`` bitwise equal to running
    the same kernel over the eagerly filtered whole log.  ``prune=False``
    executes the identical plan without zone-map skipping (the full-scan
    baseline the benchmarks compare against).
    """
    src, report = pruned_source(
        plan, prune=prune, mask_exact=getattr(mine, "mask_exact", True))
    return engine.run_streaming(mine, src), report


def execute_frame(plan: Plan, *, prune: bool = True):
    """Materialize the filtered, projected frame (rows the predicates
    refute are dropped — equal to the eager filter chain + ``compact``).

    Returns ``(frame, tables, report)``.
    """
    physical = compile_plan(plan, prune)
    report = _base_report(physical)
    keeps = _phase1_keeps(physical, report)
    schedule = physical.final_schedule(keeps, ghosts=False, skippable=True)
    _account(report, physical, schedule, physical.read_columns)
    report.groups_skipped = report.groups_total - report.groups_read
    parts = [c.compact() for c in
             _iter_chunks(physical, schedule, keeps, physical.chunk_columns,
                          physical.read_columns)]
    parts = [p for p in parts if p.nrows] or parts[:1]
    tables = {k: v for k, v in physical.reader.tables.items()
              if k in physical.chunk_columns}
    if not parts:
        schema = physical.reader.schema
        cols = {k: np.zeros(0, np.dtype(schema[k]["dtype"]))
                for k in physical.chunk_columns}
        valid = {k: np.zeros(0, bool) for k in physical.chunk_columns
                 if schema[k].get("has_valid") or "valid_offset" in schema[k]}
        return EventFrame.from_numpy(cols, valid), tables, report
    cols = {k: np.concatenate([np.asarray(p.columns[k]) for p in parts])
            for k in parts[0].names}
    valid = {k: np.concatenate([np.asarray(p.valid[k]) for p in parts])
             for k in parts[0].valid}
    return EventFrame.from_numpy(cols, valid), tables, report
