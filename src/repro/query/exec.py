"""Pruned plan execution: ghost carries, residual masks, chunk kernels.

``execute(plan, mine=kernel)`` drives the surviving row groups of a
compiled plan through any ``repro.core.engine`` chunk kernel.  The
contract is **bitwise identity** with the eager pipeline the plan
replaces: ``mine(filterN(...filter1(edf.read(path))))`` — while reading
strictly fewer bytes whenever the zone maps refute any group.

Two mechanisms make the pruned stream indistinguishable from the full
one for the kernels:

* **residual masks** — each read group's chunk arrives with
  ``row_valid`` = the conjunction of every predicate the zone maps could
  not decide (plus the broadcast case-level keep masks), exactly the
  lazy ``ops.proj`` mask the eager filters would have produced.  The
  kernels already fold ``rows_valid()`` into every update, so a masked
  chunk contributes precisely what the filtered whole log would.
* **ghost chunks** — a run of skipped groups is replaced by an
  O(segments) synthetic chunk: one all-masked row per case segment, case
  ids rising from the run's first case to its recorded tail, last row
  carrying the persisted tail halo.  Driving it through the kernel's own
  ``update`` advances the carry — case id, one/two-row halo, *global
  segment numbering* — exactly as the unread rows would have (they are
  all refuted, hence all masked), at a cost independent of the run's row
  count.  Kernels that consume masked rows (``mask_exact=False``, e.g.
  variants' validity-blind hashing) opt out and are streamed unpruned.

``execute_frame`` materializes the filtered, projected frame instead
(equal to ``filterN(...).compact()``); ``pruned_source`` exposes the
pruned stream as a re-iterable ``ChunkedEventFrame`` for custom drivers
(``repro.distributed.query`` shards it across devices).

**Double buffering** — the scan's wall clock is ``sum(read+decode) +
sum(kernel update)`` when sequential; a bounded background prefetcher
(``REPRO_QUERY_PREFETCH``, default 1 group ahead, ``0`` = off) fetches
and decodes row group *g+1* on the host while the kernel runs on group
*g*, overlapping the two terms.  Only the ``read_group`` I/O moves off
the consumer thread: residual masks, segment tracking and ghost-chunk
synthesis are order-dependent and stay synchronous, so the chunk stream
— and therefore every kernel result — is bitwise identical with the
prefetcher on or off.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading

import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.chunked import ChunkedEventFrame
from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from repro.storage.edf import EDFReader

from .expr import CasePredicate
from .optimize import GhostItem, PhysicalPlan, ReadItem, compile_plan
from .plan import MultiPlan, Plan


# ------------------------------------------------------------- reporting
@dataclasses.dataclass
class ScanReport:
    """I/O accounting for one executed plan (all byte counts are on-disk
    compressed extents of the scan's projected column set)."""

    path: str
    columns: tuple
    pruned: bool
    prefetch: int = 0           # read-ahead depth the scan ran with
    groups_total: int = 0
    groups_read: int = 0
    groups_skipped: int = 0
    groups_proved: int = 0      # read groups whose residual mask was proved
    rows_total: int = 0
    rows_read: int = 0
    bytes_total: int = 0
    bytes_read: int = 0
    phase1_groups_read: int = 0
    phase1_bytes_read: int = 0
    per_file: tuple = ()        # multi-file plans: the per-file reports

    @property
    def skip_ratio(self) -> float:
        return self.groups_skipped / self.groups_total if self.groups_total else 0.0

    @property
    def bytes_saved_ratio(self) -> float:
        if not self.bytes_total:
            return 0.0
        return 1.0 - self.bytes_read / self.bytes_total

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["columns"] = list(self.columns)
        out["skip_ratio"] = self.skip_ratio
        out["bytes_saved_ratio"] = self.bytes_saved_ratio
        out["per_file"] = [r.to_dict() for r in self.per_file]
        return out


def merge_reports(reports) -> ScanReport:
    """Aggregate per-file reports into one dataset-level report (the
    originals remain available on ``per_file``)."""
    reports = tuple(reports)
    if len(reports) == 1:
        return reports[0]
    out = ScanReport(";".join(r.path for r in reports),
                     reports[0].columns if reports else (),
                     any(r.pruned for r in reports),
                     prefetch=max(r.prefetch for r in reports),
                     per_file=reports)
    for f in ("groups_total", "groups_read", "groups_skipped",
              "groups_proved", "rows_total", "rows_read", "bytes_total",
              "bytes_read", "phase1_groups_read", "phase1_bytes_read"):
        setattr(out, f, sum(getattr(r, f) for r in reports))
    return out


def _account(report: ScanReport, physical: PhysicalPlan, schedule,
             read_columns, phase1: bool = False) -> None:
    reader = physical.reader
    for item in schedule:
        if isinstance(item, GhostItem):
            continue
        nbytes = reader.group_nbytes(item.index, read_columns)
        if phase1:
            report.phase1_groups_read += 1
            report.phase1_bytes_read += nbytes
        else:
            report.groups_read += 1
            report.bytes_read += nbytes
            report.rows_read += reader.group_nrows(item.index)
            if not item.residual and physical.steps:
                report.groups_proved += 1


# ----------------------------------------------------------- the stream
def prefetch_depth(prefetch: int | None = None) -> int:
    """Resolve the read-ahead depth: explicit argument wins, else the
    ``REPRO_QUERY_PREFETCH`` env var (default 1 group ahead; 0 disables)."""
    if prefetch is None:
        try:
            prefetch = int(os.environ.get("REPRO_QUERY_PREFETCH", "1"))
        except ValueError:
            prefetch = 1
    return max(int(prefetch), 0)


_DONE = object()


def _read_ahead(reader: EDFReader, schedule, read_columns, depth: int):
    """Yield ``(item, frame | None)`` pairs in schedule order, fetching and
    decoding up to ``depth`` read groups ahead on a daemon thread (the
    double buffer: group *g+1* decompresses while the kernel runs on *g*).
    Ghost items pass through with ``frame=None`` — their synthesis is
    order-dependent and stays on the consumer.  Worker exceptions re-raise
    at the consumer's matching position; an abandoned consumer (generator
    closed early) stops the worker via the stop event + queue drain, so no
    thread is ever left blocked on a full queue."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def _put(payload) -> bool:
        while not stop.is_set():
            try:
                q.put(payload, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def worker():
        try:
            for item in schedule:
                if isinstance(item, GhostItem):
                    out = (item, None)
                elif stop.is_set():
                    return
                else:
                    out = (item, reader.read_group(item.index, read_columns))
                if not _put(out):
                    return
            _put(_DONE)
        except BaseException as exc:  # noqa: BLE001 — re-raised at consumer
            _put(exc)

    t = threading.Thread(target=worker, daemon=True, name="repro-prefetch")
    t.start()
    try:
        while True:
            got = q.get()
            if got is _DONE:
                return
            if isinstance(got, BaseException):
                raise got
            yield got
    finally:
        stop.set()
        while True:  # unblock a worker parked on a full queue
            try:
                q.get_nowait()
            except queue.Empty:
                break


def _ghost_chunk(item: GhostItem, chunk_columns, reader: EDFReader
                 ) -> EventFrame:
    """One all-masked row per case segment of a skipped run (padded to a
    power of two so ghost shapes retrace the kernel O(log) times)."""
    d = max(int(item.segments), 1)
    m = 1 << (d - 1).bit_length()
    tail_vals = item.tail["values"]
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    for name in chunk_columns:
        meta = reader.schema[name]
        dtype = np.dtype(meta["dtype"])
        if name == CASE:
            arr = np.full(m, tail_vals[CASE], dtype)
            if d > 1:
                arr[:d - 1] = item.first_case + np.arange(d - 1)
        else:
            arr = np.zeros(m, dtype)
            arr[d - 1:] = dtype.type(tail_vals.get(name, 0))
        cols[name] = arr
        if meta.get("has_valid"):
            # every ghost row is row-masked, but the tail halo keeps its
            # persisted epsilon flag so the carry is faithful to the file
            v = np.ones(m, bool)
            v[d - 1:] = bool(item.tail.get("valid", {}).get(name, True))
            valid[name] = v
    frame = EventFrame.from_numpy(cols, valid)
    return EventFrame(frame.columns, frame.valid, jnp.zeros(m, bool))


def _iter_chunks(physical: PhysicalPlan, schedule, keeps: dict,
                 chunk_columns, read_columns, prefetch: int | None = None):
    """Yield the pruned chunk stream: read groups with residual masks,
    ghost chunks for skipped runs.  Tracks global segment numbering
    sequentially (read groups from their case column, ghost runs from
    metadata), so case-level keep masks broadcast to the right rows.
    ``prefetch`` groups are fetched+decoded ahead on a background thread
    (:func:`prefetch_depth` resolves ``None`` from the environment); the
    masking below consumes them strictly in schedule order, so the stream
    is bitwise identical with read-ahead on or off."""
    reader = physical.reader
    steps = physical.steps
    depth = prefetch_depth(prefetch)
    if depth > 0:
        pairs = _read_ahead(reader, schedule, read_columns, depth)
    else:
        pairs = ((item, None) for item in schedule)
    # global segment ids are only materialized when a keep mask needs the
    # broadcast; ghost continuation needs just the previous case id
    track_segs = any(getattr(item, "case_steps", ()) for item in schedule)
    last_seg = -1
    prev_case = None
    try:
        yield from _masked_chunks(pairs, reader, steps, keeps, chunk_columns,
                                  read_columns, track_segs, last_seg,
                                  prev_case)
    finally:
        close = getattr(pairs, "close", None)
        if close is not None:
            close()


def _masked_chunks(pairs, reader, steps, keeps, chunk_columns, read_columns,
                   track_segs, last_seg, prev_case):
    for item, frame in pairs:
        if isinstance(item, GhostItem):
            cont = prev_case is not None and item.first_case == prev_case
            yield _ghost_chunk(item, chunk_columns, reader)
            last_seg += int(item.segments) - (1 if cont else 0)
            prev_case = item.tail["values"][CASE]
            continue
        if frame is None:
            frame = reader.read_group(item.index, read_columns)
        mask = np.ones(frame.nrows, bool)
        for pos in item.residual:
            mask &= np.asarray(steps[pos].mask(frame), bool)
        if CASE in frame and frame.nrows:
            case = np.asarray(frame[CASE])
            if track_segs:
                new0 = prev_case is None or case[0] != prev_case
                seg = last_seg + int(new0) + np.concatenate(
                    [[0], np.cumsum(case[1:] != case[:-1])])
                for pos in item.case_steps:
                    keep = keeps[pos]
                    seg_c = np.minimum(seg, len(keep) - 1)
                    mask &= keep[seg_c] & (seg < len(keep))
                last_seg = int(seg[-1])
            prev_case = case[-1]
        sel = frame.select(chunk_columns)
        yield EventFrame(sel.columns, sel.valid, jnp.asarray(mask))


def _base_report(physical: PhysicalPlan) -> ScanReport:
    reader = physical.reader
    report = ScanReport(physical.plan.path, physical.read_columns,
                        physical.prune)
    for g in range(reader.num_groups):
        n = reader.group_nrows(g)
        if n == 0:
            continue
        report.groups_total += 1
        report.rows_total += n
        report.bytes_total += reader.group_nbytes(g, physical.read_columns)
    return report


# -------------------------------------------------------- multi-file plans
def check_homogeneous(readers) -> None:
    """A multi-file dataset needs one schema: identical column names,
    dtypes, kinds, dictionary tables and validity flags across every file
    (byte layout/version may differ — v1/v2/v3 files mix freely).  Shared
    by every engine, so eager and streaming fail the same way."""

    def shape(reader):
        return {
            name: (meta["dtype"], meta.get("kind", "numeric"),
                   tuple(meta.get("table", ())),
                   bool(meta.get("has_valid") or "valid_offset" in meta))
            for name, meta in reader.schema.items()
        }

    readers = list(readers)
    first = shape(readers[0])
    for reader in readers[1:]:
        if shape(reader) != first:
            raise ValueError(
                f"multi-file plan over incompatible schemas: "
                f"{readers[0].path!r} vs {reader.path!r}")


def _case_extent(ph: PhysicalPlan):
    """(first case id, last case id) of a file, from header metadata."""
    if ph.metas is None or CASE not in ph.reader.schema:
        return None, None
    nonempty = [g for g in range(ph.reader.num_groups)
                if ph.reader.group_nrows(g) > 0]
    if not nonempty:
        return None, None
    first = ph.metas[nonempty[0]]["zones"].get(CASE, {}).get("min")
    tail = ph.metas[nonempty[-1]].get("tail", {}).get("values", {}).get(CASE)
    return first, tail


def _multi_offsets(physicals):
    """Global segment id of each file's first segment, plus the total case
    count — the multi-file extension of the per-group segment accounting.
    A case straddling a file boundary (same id on both sides) is counted
    once: the next file's offset backs up by one.  Returns ``(None, None)``
    when any file lacks segment metadata (case predicates then raise, like
    the single-file path)."""
    offsets: list[int] = []
    total = 0
    prev_tail = None
    for ph in physicals:
        if ph.num_cases is None:
            return None, None
        first, tail = _case_extent(ph)
        cont = (prev_tail is not None and first is not None
                and first == prev_tail)
        off = total - 1 if cont else total
        offsets.append(off)
        total = off + ph.num_cases
        if tail is not None:
            prev_tail = tail
    return offsets, total


def _local_keeps(keeps: dict, off: int, num_cases: int) -> dict:
    """Slice global per-case keep masks to one file's segment range."""
    return {pos: k[off:off + num_cases] for pos, k in keeps.items()}


def _multi_phase1(physicals, reports, offsets, total,
                  prefetch: int | None = None) -> dict:
    """Phase one of every case predicate, streamed across the whole file
    set with one kernel (its carry numbers segments globally, so a case
    straddling a file boundary accumulates into a single slot)."""
    steps = physicals[0].steps
    keeps: dict = {}
    for pos, step in enumerate(steps):
        if not isinstance(step, CasePredicate):
            continue
        if total is None:
            raise ValueError(
                f"case-level predicates need a {CASE!r} column with "
                f"per-group segment metadata in every file of the plan")
        chunk_cols = tuple(sorted({CASE, ACTIVITY} | set(step.columns())))
        read = set(chunk_cols)
        for i in range(pos):
            s = steps[i]
            if not isinstance(s, CasePredicate):
                read |= s.columns()
        read_cols = tuple(sorted(read))
        locals_ = [_local_keeps(keeps, off, ph.num_cases)
                   for ph, off in zip(physicals, offsets)]
        schedules = [ph.phase1_schedule(pos, lk)
                     for ph, lk in zip(physicals, locals_)]
        for ph, rep, sched in zip(physicals, reports, schedules):
            _account(rep, ph, sched, read_cols, phase1=True)

        def gen():
            for ph, sched, lk in zip(physicals, schedules, locals_):
                yield from _iter_chunks(ph, sched, lk, chunk_cols, read_cols,
                                        prefetch)

        result = engine.run_streaming(step.phase1_kernel(total), gen())
        keeps[pos] = np.asarray(step.finalize_keep(result), bool)
    return keeps


def _multi_compile(mplan: MultiPlan, prune: bool,
                   prefetch: int | None = None):
    physicals = [compile_plan(p, prune) for p in mplan.per_file()]
    check_homogeneous(ph.reader for ph in physicals)
    reports = [_base_report(ph) for ph in physicals]
    offsets, total = _multi_offsets(physicals)
    keeps = _multi_phase1(physicals, reports, offsets, total, prefetch)
    if offsets is None:
        offsets = [0] * len(physicals)
    return physicals, reports, offsets, keeps


def _multi_schedules(physicals, reports, offsets, keeps, *, ghosts,
                     skippable):
    schedules, locals_ = [], []
    for ph, rep, off in zip(physicals, reports, offsets):
        lk = _local_keeps(keeps, off, ph.num_cases or 0)
        sched = ph.final_schedule(lk, ghosts=ghosts, skippable=skippable)
        _account(rep, ph, sched, ph.read_columns)
        rep.groups_skipped = rep.groups_total - rep.groups_read
        schedules.append(sched)
        locals_.append(lk)
    return schedules, locals_


def multi_pruned_source(mplan: MultiPlan, *, prune: bool = True,
                        mask_exact: bool = True,
                        prefetch: int | None = None
                        ) -> tuple[ChunkedEventFrame, ScanReport]:
    """Compile a multi-file plan into one re-iterable pruned chunk stream.

    The stream is the concatenation of every file's pruned scan; a single
    kernel driven over it is bitwise equal to mining the concatenation of
    the files (the engine's carry crosses file boundaries exactly as it
    crosses row-group boundaries — no state merging, no float reordering).
    The returned report aggregates the per-file reports (``per_file``).
    ``prefetch`` sets the read-ahead depth of every scan the source runs
    (``None`` = the ``REPRO_QUERY_PREFETCH`` environment default).
    """
    physicals, reports, offsets, keeps = _multi_compile(mplan, prune,
                                                        prefetch)
    schedules, locals_ = _multi_schedules(physicals, reports, offsets, keeps,
                                          ghosts=mask_exact,
                                          skippable=mask_exact)
    depth = prefetch_depth(prefetch)
    for rep in reports:
        rep.prefetch = depth

    def factory():
        for ph, sched, lk in zip(physicals, schedules, locals_):
            yield from _iter_chunks(ph, sched, lk, ph.chunk_columns,
                                    ph.read_columns, depth)

    src = ChunkedEventFrame(factory,
                            num_chunks=sum(len(s) for s in schedules),
                            tables=dict(physicals[0].reader.tables))
    return src, merge_reports(reports)


# ------------------------------------------------------------ public API
def count_cases(plan: "Plan | MultiPlan") -> int | None:
    """Total case segments across the plan's file(s), from header metadata
    only (None when any file lacks segment metadata)."""
    if isinstance(plan, MultiPlan):
        physicals = [compile_plan(Plan(p), True) for p in plan.paths]
        _, total = _multi_offsets(physicals)
        return total
    return compile_plan(Plan(plan.path), True).num_cases


def pruned_source(plan: "Plan | MultiPlan", *, prune: bool = True,
                  mask_exact: bool = True, prefetch: int | None = None
                  ) -> tuple[ChunkedEventFrame, ScanReport]:
    """Compile a plan into a re-iterable pruned chunk stream.

    ``mask_exact=False`` keeps every group in the stream (residual masks
    only) for consumers that inspect masked rows.  The returned source
    plugs into ``engine.run_streaming`` / ``repro.distributed.query``.
    A single-file ``Plan`` is the one-path special case of
    :func:`multi_pruned_source` (one code path, one set of invariants).
    """
    if isinstance(plan, Plan):
        plan = MultiPlan((plan.path,), plan.steps, plan.projection)
    return multi_pruned_source(plan, prune=prune, mask_exact=mask_exact,
                               prefetch=prefetch)


def execute(plan: "Plan | MultiPlan", mine: engine.ChunkKernel, *,
            prune: bool = True, prefetch: int | None = None):
    """Fold a chunk kernel over the pruned scan of ``plan``.

    Returns ``(result, report)`` with ``result`` bitwise equal to running
    the same kernel over the eagerly filtered whole log (for multi-file
    plans: the eagerly filtered concatenation of the files).
    ``prune=False`` executes the identical plan without zone-map skipping
    (the full-scan baseline the benchmarks compare against).
    """
    src, report = pruned_source(
        plan, prune=prune, mask_exact=getattr(mine, "mask_exact", True),
        prefetch=prefetch)
    return engine.run_streaming(mine, src), report


def _materialize(parts, physical: PhysicalPlan):
    """Concatenate compacted parts into one frame (+ projected tables)."""
    from repro.core.eventframe import concat_frames

    parts = [p for p in parts if p.nrows] or parts[:1]
    tables = {k: v for k, v in physical.reader.tables.items()
              if k in physical.chunk_columns}
    if not parts:
        schema = physical.reader.schema
        cols = {k: np.zeros(0, np.dtype(schema[k]["dtype"]))
                for k in physical.chunk_columns}
        valid = {k: np.zeros(0, bool) for k in physical.chunk_columns
                 if schema[k].get("has_valid") or "valid_offset" in schema[k]}
        return EventFrame.from_numpy(cols, valid), tables
    return concat_frames(parts), tables


def execute_frame(plan: "Plan | MultiPlan", *, prune: bool = True,
                  prefetch: int | None = None):
    """Materialize the filtered, projected frame (rows the predicates
    refute are dropped — equal to the eager filter chain + ``compact``;
    multi-file plans concatenate in path order).

    Returns ``(frame, tables, report)``.
    """
    if isinstance(plan, Plan):
        plan = MultiPlan((plan.path,), plan.steps, plan.projection)
    physicals, reports, offsets, keeps = _multi_compile(plan, prune, prefetch)
    schedules, locals_ = _multi_schedules(physicals, reports, offsets,
                                          keeps, ghosts=False,
                                          skippable=True)
    depth = prefetch_depth(prefetch)
    for rep in reports:
        rep.prefetch = depth
    parts = []
    for ph, sched, lk in zip(physicals, schedules, locals_):
        parts += [c.compact() for c in
                  _iter_chunks(ph, sched, lk, ph.chunk_columns,
                               ph.read_columns, depth)]
    frame, tables = _materialize(parts, physicals[0])
    return frame, tables, merge_reports(reports)
