"""Pruned plan execution: ghost carries, residual masks, chunk kernels.

``execute(plan, mine=kernel)`` drives the surviving row groups of a
compiled plan through any ``repro.core.engine`` chunk kernel.  The
contract is **bitwise identity** with the eager pipeline the plan
replaces: ``mine(filterN(...filter1(edf.read(path))))`` — while reading
strictly fewer bytes whenever the zone maps refute any group.

Two mechanisms make the pruned stream indistinguishable from the full
one for the kernels:

* **residual masks** — each read group's chunk arrives with
  ``row_valid`` = the conjunction of every predicate the zone maps could
  not decide (plus the broadcast case-level keep masks), exactly the
  lazy ``ops.proj`` mask the eager filters would have produced.  The
  kernels already fold ``rows_valid()`` into every update, so a masked
  chunk contributes precisely what the filtered whole log would.
* **ghost chunks** — a run of skipped groups is replaced by an
  O(segments) synthetic chunk: one all-masked row per case segment, case
  ids rising from the run's first case to its recorded tail, last row
  carrying the persisted tail halo.  Driving it through the kernel's own
  ``update`` advances the carry — case id, one/two-row halo, *global
  segment numbering* — exactly as the unread rows would have (they are
  all refuted, hence all masked), at a cost independent of the run's row
  count.  Kernels whose state depends on masked rows declare
  ``ghost_sketch`` (variants' validity-blind hashing): their ghost
  chunks additionally carry the run's composed per-segment affine
  polyhash maps (``core.polyhash``, read from EDF headers), so the
  kernel replays the skipped rows' hash contribution bitwise without
  any I/O — every registered verb now runs on the pruned stream.

Case-level predicates resolve in as little as **zero** passes: variant
predicates (``variant_of`` / ``variant_in``) derive their per-case keep
masks straight from the composed header sketches when every file has
them; the remaining data-dependent case predicates
(``cases_containing`` / ``case_size``) run a fused **single-pass**
schedule (:func:`_single_pass_source`) that folds their phase-one
kernels and the mining kernel over one scan — each surviving group is
read once, buffered until its segments' keeps are resolved, and either
emitted masked or replaced by a ghost — instead of the old two-pass
plan (a phase-one scan per predicate, then the final scan).

``execute_frame`` materializes the filtered, projected frame instead
(equal to ``filterN(...).compact()``); ``pruned_source`` exposes the
pruned stream as a re-iterable ``ChunkedEventFrame`` for custom drivers
(``repro.distributed.query`` shards it across devices).

**Double buffering** — the scan's wall clock is ``sum(read+decode) +
sum(kernel update)`` when sequential; a bounded background prefetcher
(``REPRO_QUERY_PREFETCH``, default 1 group ahead, ``0`` = off) fetches
and decodes row group *g+1* on the host while the kernel runs on group
*g*, overlapping the two terms.  Only the ``read_group`` I/O moves off
the consumer thread: residual masks, segment tracking and ghost-chunk
synthesis are order-dependent and stay synchronous, so the chunk stream
— and therefore every kernel result — is bitwise identical with the
prefetcher on or off.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading

import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.chunked import ChunkedEventFrame
from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from repro.core.polyhash import sketch_columns
from repro.storage.edf import EDFReader

from .expr import ALL, NONE, CasePredicate, Expr, SketchPredicate
from .optimize import GhostItem, PhysicalPlan, ReadItem, compile_plan
from .plan import MultiPlan, Plan


# ------------------------------------------------------------- reporting
@dataclasses.dataclass
class ScanReport:
    """I/O accounting for one executed plan (all byte counts are on-disk
    compressed extents of the scan's projected column set)."""

    path: str
    columns: tuple
    pruned: bool
    prefetch: int = 0           # read-ahead depth the scan ran with
    groups_total: int = 0
    groups_read: int = 0
    groups_skipped: int = 0
    groups_proved: int = 0      # read groups whose residual mask was proved
    groups_cached: int = 0      # grouped path: states served from the cache
    groups_folded: int = 0      # grouped path: states freshly decoded+folded
    rows_total: int = 0
    rows_read: int = 0
    bytes_total: int = 0
    bytes_read: int = 0
    phase1_groups_read: int = 0
    phase1_bytes_read: int = 0
    per_file: tuple = ()        # multi-file plans: the per-file reports

    @property
    def skip_ratio(self) -> float:
        return self.groups_skipped / self.groups_total if self.groups_total else 0.0

    @property
    def bytes_saved_ratio(self) -> float:
        if not self.bytes_total:
            return 0.0
        return 1.0 - self.bytes_read / self.bytes_total

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["columns"] = list(self.columns)
        out["skip_ratio"] = self.skip_ratio
        out["bytes_saved_ratio"] = self.bytes_saved_ratio
        out["per_file"] = [r.to_dict() for r in self.per_file]
        return out


def merge_reports(reports) -> ScanReport:
    """Aggregate per-file reports into one dataset-level report (the
    originals remain available on ``per_file``)."""
    reports = tuple(reports)
    if len(reports) == 1:
        return reports[0]
    out = ScanReport(";".join(r.path for r in reports),
                     reports[0].columns if reports else (),
                     any(r.pruned for r in reports),
                     prefetch=max(r.prefetch for r in reports),
                     per_file=reports)
    for f in ("groups_total", "groups_read", "groups_skipped",
              "groups_proved", "groups_cached", "groups_folded",
              "rows_total", "rows_read", "bytes_total",
              "bytes_read", "phase1_groups_read", "phase1_bytes_read"):
        setattr(out, f, sum(getattr(r, f) for r in reports))
    return out


def _account(report: ScanReport, physical: PhysicalPlan, schedule,
             read_columns, phase1: bool = False) -> None:
    reader = physical.reader
    for item in schedule:
        if isinstance(item, GhostItem):
            continue
        nbytes = reader.group_nbytes(item.index, read_columns)
        if phase1:
            report.phase1_groups_read += 1
            report.phase1_bytes_read += nbytes
        else:
            report.groups_read += 1
            report.bytes_read += nbytes
            report.rows_read += reader.group_nrows(item.index)
            if not item.residual and physical.steps:
                report.groups_proved += 1


# ----------------------------------------------------------- the stream
def prefetch_depth(prefetch: int | None = None) -> int:
    """Resolve the read-ahead depth: explicit argument wins, else the
    ``REPRO_QUERY_PREFETCH`` env var (default 1 group ahead; 0 disables)."""
    if prefetch is None:
        try:
            prefetch = int(os.environ.get("REPRO_QUERY_PREFETCH", "1"))
        except ValueError:
            prefetch = 1
    return max(int(prefetch), 0)


_DONE = object()


def _read_ahead(reader: EDFReader, schedule, read_columns, depth: int):
    """Yield ``(item, frame | None)`` pairs in schedule order, fetching and
    decoding up to ``depth`` read groups ahead on a daemon thread (the
    double buffer: group *g+1* decompresses while the kernel runs on *g*).
    Ghost items pass through with ``frame=None`` — their synthesis is
    order-dependent and stays on the consumer.  Worker exceptions re-raise
    at the consumer's matching position; an abandoned consumer (generator
    closed early) stops the worker via the stop event + queue drain, so no
    thread is ever left blocked on a full queue."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def _put(payload) -> bool:
        while not stop.is_set():
            try:
                q.put(payload, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def worker():
        try:
            for item in schedule:
                if isinstance(item, GhostItem):
                    out = (item, None)
                elif stop.is_set():
                    return
                else:
                    out = (item, reader.read_group(item.index, read_columns))
                if not _put(out):
                    return
            _put(_DONE)
        except BaseException as exc:  # noqa: BLE001 — re-raised at consumer
            _put(exc)

    t = threading.Thread(target=worker, daemon=True, name="repro-prefetch")
    t.start()
    try:
        while True:
            got = q.get()
            if got is _DONE:
                return
            if isinstance(got, BaseException):
                raise got
            yield got
    finally:
        stop.set()
        while True:  # unblock a worker parked on a full queue
            try:
                q.get_nowait()
            except queue.Empty:
                break


def _ghost_chunk(item: GhostItem, chunk_columns, reader: EDFReader
                 ) -> EventFrame:
    """One all-masked row per case segment of a skipped run (padded to a
    power of two so ghost shapes retrace the kernel O(log) times)."""
    d = max(int(item.segments), 1)
    m = 1 << (d - 1).bit_length()
    tail_vals = item.tail["values"]
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    for name in chunk_columns:
        meta = reader.schema[name]
        dtype = np.dtype(meta["dtype"])
        if name == CASE:
            arr = np.full(m, tail_vals[CASE], dtype)
            if d > 1:
                arr[:d - 1] = item.first_case + np.arange(d - 1)
        else:
            arr = np.zeros(m, dtype)
            arr[d - 1:] = dtype.type(tail_vals.get(name, 0))
        cols[name] = arr
        if meta.get("has_valid"):
            # every ghost row is row-masked, but the tail halo keeps its
            # persisted epsilon flag so the carry is faithful to the file
            v = np.ones(m, bool)
            v[d - 1:] = bool(item.tail.get("valid", {}).get(name, True))
            valid[name] = v
    if item.sketch is not None:
        # per-segment composed affine polyhash maps on the segment rows,
        # identity maps on padding — sketch-consuming kernels (variants)
        # fold these instead of hashing the unread rows
        cols.update(sketch_columns(item.sketch, d, m))
    frame = EventFrame.from_numpy(cols, valid)
    return EventFrame(frame.columns, frame.valid, jnp.zeros(m, bool))


def _iter_chunks(physical: PhysicalPlan, schedule, keeps: dict,
                 chunk_columns, read_columns, prefetch: int | None = None):
    """Yield the pruned chunk stream: read groups with residual masks,
    ghost chunks for skipped runs.  Tracks global segment numbering
    sequentially (read groups from their case column, ghost runs from
    metadata), so case-level keep masks broadcast to the right rows.
    ``prefetch`` groups are fetched+decoded ahead on a background thread
    (:func:`prefetch_depth` resolves ``None`` from the environment); the
    masking below consumes them strictly in schedule order, so the stream
    is bitwise identical with read-ahead on or off."""
    reader = physical.reader
    steps = physical.steps
    depth = prefetch_depth(prefetch)
    if depth > 0:
        pairs = _read_ahead(reader, schedule, read_columns, depth)
    else:
        pairs = ((item, None) for item in schedule)
    # global segment ids are only materialized when a keep mask needs the
    # broadcast; ghost continuation needs just the previous case id
    track_segs = any(getattr(item, "case_steps", ()) for item in schedule)
    last_seg = -1
    prev_case = None
    try:
        yield from _masked_chunks(pairs, reader, steps, keeps, chunk_columns,
                                  read_columns, track_segs, last_seg,
                                  prev_case)
    finally:
        close = getattr(pairs, "close", None)
        if close is not None:
            close()


def _masked_chunks(pairs, reader, steps, keeps, chunk_columns, read_columns,
                   track_segs, last_seg, prev_case):
    for item, frame in pairs:
        if isinstance(item, GhostItem):
            cont = prev_case is not None and item.first_case == prev_case
            yield _ghost_chunk(item, chunk_columns, reader)
            last_seg += int(item.segments) - (1 if cont else 0)
            prev_case = item.tail["values"][CASE]
            continue
        if frame is None:
            frame = reader.read_group(item.index, read_columns)
        mask = np.ones(frame.nrows, bool)
        for pos in item.residual:
            mask &= np.asarray(steps[pos].mask(frame), bool)
        if CASE in frame and frame.nrows:
            case = np.asarray(frame[CASE])
            if track_segs:
                new0 = prev_case is None or case[0] != prev_case
                seg = last_seg + int(new0) + np.concatenate(
                    [[0], np.cumsum(case[1:] != case[:-1])])
                for pos in item.case_steps:
                    keep = keeps[pos]
                    seg_c = np.minimum(seg, len(keep) - 1)
                    mask &= keep[seg_c] & (seg < len(keep))
                last_seg = int(seg[-1])
            prev_case = case[-1]
        sel = frame.select(chunk_columns)
        yield EventFrame(sel.columns, sel.valid, jnp.asarray(mask))


def _base_report(physical: PhysicalPlan) -> ScanReport:
    reader = physical.reader
    report = ScanReport(physical.plan.path, physical.read_columns,
                        physical.prune)
    for g in range(reader.num_groups):
        n = reader.group_nrows(g)
        if n == 0:
            continue
        report.groups_total += 1
        report.rows_total += n
        report.bytes_total += reader.group_nbytes(g, physical.read_columns)
    return report


# -------------------------------------------------------- multi-file plans
def check_homogeneous(readers) -> None:
    """A multi-file dataset needs one schema: identical column names,
    dtypes, kinds, dictionary tables and validity flags across every file
    (byte layout/version may differ — v1/v2/v3 files mix freely).  Shared
    by every engine, so eager and streaming fail the same way."""

    def shape(reader):
        return {
            name: (meta["dtype"], meta.get("kind", "numeric"),
                   tuple(meta.get("table", ())),
                   bool(meta.get("has_valid") or "valid_offset" in meta))
            for name, meta in reader.schema.items()
        }

    readers = list(readers)
    first = shape(readers[0])
    for reader in readers[1:]:
        if shape(reader) != first:
            raise ValueError(
                f"multi-file plan over incompatible schemas: "
                f"{readers[0].path!r} vs {reader.path!r}")


def _case_extent(ph: PhysicalPlan):
    """(first case id, last case id) of a file, from header metadata."""
    if ph.metas is None or CASE not in ph.reader.schema:
        return None, None
    nonempty = [g for g in range(ph.reader.num_groups)
                if ph.reader.group_nrows(g) > 0]
    if not nonempty:
        return None, None
    first = ph.metas[nonempty[0]]["zones"].get(CASE, {}).get("min")
    tail = ph.metas[nonempty[-1]].get("tail", {}).get("values", {}).get(CASE)
    return first, tail


def _multi_offsets(physicals):
    """Global segment id of each file's first segment, plus the total case
    count — the multi-file extension of the per-group segment accounting.
    A case straddling a file boundary (same id on both sides) is counted
    once: the next file's offset backs up by one.  Returns ``(None, None)``
    when any file lacks segment metadata (case predicates then raise, like
    the single-file path)."""
    offsets: list[int] = []
    total = 0
    prev_tail = None
    for ph in physicals:
        if ph.num_cases is None:
            return None, None
        first, tail = _case_extent(ph)
        cont = (prev_tail is not None and first is not None
                and first == prev_tail)
        off = total - 1 if cont else total
        offsets.append(off)
        total = off + ph.num_cases
        if tail is not None:
            prev_tail = tail
    return offsets, total


def _local_keeps(keeps: dict, off: int, num_cases: int) -> dict:
    """Slice global per-case keep masks to one file's segment range."""
    return {pos: k[off:off + num_cases] for pos, k in keeps.items()}


def _sketch_fingerprints(physicals, total):
    """Whole-dataset per-case variant fingerprints from header sketches
    alone (no data I/O): walk the nonempty groups in stream order, folding
    each segment's composed affine map — a case that straddles group/file
    boundaries composes across them exactly like the streamed hash.
    Returns ``(fp1, fp2)`` uint32 arrays of length ``total``, or ``None``
    when any group lacks a sketch."""
    if total is None:
        return None
    fp1 = np.zeros(total, np.uint32)
    fp2 = np.zeros(total, np.uint32)
    seg = -1                    # id of the open (possibly straddling) case
    h1 = h2 = 0                 # its running hash pair (python ints, mod 2^32)
    prev_tail = None
    for ph in physicals:
        for g in ph._nonempty():
            sk = ph.reader.group_sketch(g)
            if sk is None:
                return None
            meta = ph.metas[g]
            first = meta["zones"][CASE]["min"]
            mul1, add1 = sk["mul1"], sk["add1"]
            mul2, add2 = sk["mul2"], sk["add2"]
            nsegs = len(mul1)
            j0 = 0
            if prev_tail is not None and first == prev_tail:
                h1 = (h1 * int(mul1[0]) + int(add1[0])) & 0xFFFFFFFF
                h2 = (h2 * int(mul2[0]) + int(add2[0])) & 0xFFFFFFFF
                j0 = 1
            if nsegs > j0:
                if seg >= 0:
                    fp1[seg], fp2[seg] = h1, h2     # close the open case
                # fresh segments closed inside the group start from h=0:
                # their fingerprint is their additive coefficient directly
                fresh = nsegs - j0
                fp1[seg + 1:seg + fresh] = add1[j0:nsegs - 1]
                fp2[seg + 1:seg + fresh] = add2[j0:nsegs - 1]
                seg += fresh
                h1, h2 = int(add1[nsegs - 1]), int(add2[nsegs - 1])
            prev_tail = meta["tail"]["values"][CASE]
    if seg >= 0:
        fp1[seg], fp2[seg] = h1, h2
    return fp1, fp2


def _sketch_keeps(physicals, total, steps) -> dict:
    """Keep masks of every :class:`SketchPredicate` step, resolved entirely
    from header sketches (empty when fingerprints aren't derivable — those
    predicates then fall back to the streamed phase-one kernel)."""
    pos_list = [i for i, s in enumerate(steps)
                if isinstance(s, SketchPredicate)]
    if not pos_list or total is None or \
            not all(ph.can_ghost for ph in physicals):
        return {}
    fps = _sketch_fingerprints(physicals, total)
    if fps is None:
        return {}
    return {pos: np.asarray(steps[pos].keep_from_fps(*fps), bool)
            for pos in pos_list}


def _multi_phase1(physicals, reports, offsets, total,
                  prefetch: int | None = None,
                  seeded: dict | None = None) -> dict:
    """Phase one of every case predicate, streamed across the whole file
    set with one kernel (its carry numbers segments globally, so a case
    straddling a file boundary accumulates into a single slot).  Variant
    predicates resolve header-only via :func:`_sketch_keeps` first and
    skip the streamed pass entirely."""
    steps = physicals[0].steps
    keeps: dict = dict(seeded) if seeded is not None else \
        _sketch_keeps(physicals, total, steps)
    for pos, step in enumerate(steps):
        if not isinstance(step, CasePredicate) or pos in keeps:
            continue
        if total is None:
            raise ValueError(
                f"case-level predicates need a {CASE!r} column with "
                f"per-group segment metadata in every file of the plan")
        chunk_cols = tuple(sorted({CASE, ACTIVITY} | set(step.columns())))
        read = set(chunk_cols)
        for i in range(pos):
            s = steps[i]
            if not isinstance(s, CasePredicate):
                read |= s.columns()
        read_cols = tuple(sorted(read))
        kern = step.phase1_kernel(total)
        sketch = getattr(kern, "ghost_sketch", False)
        locals_ = [_local_keeps(keeps, off, ph.num_cases)
                   for ph, off in zip(physicals, offsets)]
        schedules = [ph.phase1_schedule(pos, lk, sketch=sketch)
                     for ph, lk in zip(physicals, locals_)]
        for ph, rep, sched in zip(physicals, reports, schedules):
            _account(rep, ph, sched, read_cols, phase1=True)

        def gen():
            for ph, sched, lk in zip(physicals, schedules, locals_):
                yield from _iter_chunks(ph, sched, lk, chunk_cols, read_cols,
                                        prefetch)

        result = engine.run_streaming(kern, gen())
        keeps[pos] = np.asarray(step.finalize_keep(result), bool)
    return keeps


def _multi_compile(mplan: MultiPlan, prune: bool,
                   prefetch: int | None = None):
    physicals = [compile_plan(p, prune) for p in mplan.per_file()]
    check_homogeneous(ph.reader for ph in physicals)
    reports = [_base_report(ph) for ph in physicals]
    offsets, total = _multi_offsets(physicals)
    keeps = _multi_phase1(physicals, reports, offsets, total, prefetch)
    if offsets is None:
        offsets = [0] * len(physicals)
    return physicals, reports, offsets, keeps


def _multi_schedules(physicals, reports, offsets, keeps, *, ghosts,
                     skippable, sketch=False):
    schedules, locals_ = [], []
    for ph, rep, off in zip(physicals, reports, offsets):
        lk = _local_keeps(keeps, off, ph.num_cases or 0)
        sched = ph.final_schedule(lk, ghosts=ghosts, skippable=skippable,
                                  sketch=sketch)
        _account(rep, ph, sched, ph.read_columns)
        rep.groups_skipped = rep.groups_total - rep.groups_read
        schedules.append(sched)
        locals_.append(lk)
    return schedules, locals_


def _sp_buffer_cap() -> int:
    """Single-pass frame buffer: decoded groups held while their segments'
    keeps resolve (``REPRO_QUERY_SP_BUFFER``, default 16).  Overflowed
    frames are dropped (their read charged to phase one) and re-read at
    emission, bounding residency on adversarial straddles."""
    try:
        cap = int(os.environ.get("REPRO_QUERY_SP_BUFFER", "16"))
    except ValueError:
        cap = 16
    return max(cap, 1)


def _group_ghost(ph: PhysicalPlan, g: int, sketch: bool) -> GhostItem:
    meta = ph.metas[g]
    sk = None
    if sketch:
        sk = ph.reader.group_sketch(g)
        if sk is None:
            raise ValueError(
                f"group {g} of {ph.reader.path!r} has no variant sketch "
                f"(case/activity columns missing?) — cannot ghost-skip it "
                f"for a sketch-consuming kernel")
    return GhostItem((g,), int(ph.seg_count[g]), meta["zones"][CASE]["min"],
                     meta["tail"], sk)


def _single_pass_source(physicals, reports, offsets, total, sk_keeps,
                        data_pos, sketch):
    """Fused phase-one + mine scan (the ``cases_containing`` fast path).

    One walk over the nonempty groups: each group is either refuted
    header-only, read once (feeding every data-dependent case predicate's
    phase-one kernel the frame masked by its *preceding* expression
    residuals), or ghosted through the phase-one kernels.  Groups buffer
    until the scan has passed their segment range — phase-one states are
    segment-local with pure finalize, so a closed segment's keep is final
    the moment the scan moves past it — then emit to the consumer: masked
    chunk if any segment survives, ghost otherwise.  Bitwise equal to the
    two-pass plan (same final keeps, same skip set, kernel
    chunk-invariance covers the differing ghost granularity) while
    reading each surviving group once instead of once per pass plus once.

    Accounting lands at emission: a surviving group's read counts as scan
    I/O, a read that only served phase one counts as phase-one I/O, and a
    header-refuted group costs nothing.  Re-iterating the source replays
    a conventional schedule from the resolved keeps (no re-accounting).
    """
    from collections import deque

    steps = physicals[0].steps
    exprs = [i for i, s in enumerate(steps) if isinstance(s, Expr)]
    case_pos = [i for i, s in enumerate(steps)
                if isinstance(s, CasePredicate)]
    before = {pos: [i for i in exprs if i < pos] for pos in data_pos}
    merged = merge_reports(reports)
    targets = [[rep] if merged is rep else [rep, merged] for rep in reports]
    all_targets = [t for tg in targets for t in tg]
    cell: dict = {"finals": None, "replay": None}

    def first_pass():
        for rep in all_targets:     # idempotent restart of an abandoned pass
            rep.groups_read = rep.bytes_read = rep.rows_read = 0
            rep.groups_proved = rep.groups_skipped = 0
            rep.phase1_groups_read = rep.phase1_bytes_read = 0
        kernels = {pos: steps[pos].phase1_kernel(total) for pos in data_pos}
        p1_sketch = any(getattr(k, "ghost_sketch", False)
                        for k in kernels.values())
        states = {pos: k.init() for pos, k in kernels.items()}
        finals: dict = {}
        dirty = True
        pending: deque = deque()
        held = 0
        cap = _sp_buffer_cap()

        def keep_masks():
            nonlocal dirty
            if dirty:
                for pos in data_pos:
                    st, ca = states[pos]
                    finals[pos] = np.asarray(steps[pos].finalize_keep(
                        kernels[pos].finalize(st, ca)), bool)
                dirty = False
            return {**sk_keeps, **finals}

        def emit(entry):
            fi, g, glo, ghi, frame, was_read = entry
            ph, tg = physicals[fi], targets[fi]
            keeps = keep_masks()
            refuted = (any(ph.proves[i][g] == NONE for i in exprs) or
                       any(not keeps[p][glo:ghi].any() for p in case_pos))
            if refuted:
                if was_read:        # the read only served phase one
                    nb = ph.reader.group_nbytes(g, ph.read_columns)
                    for rep in tg:
                        rep.phase1_groups_read += 1
                        rep.phase1_bytes_read += nb
                yield _ghost_chunk(_group_ghost(ph, g, sketch),
                                   ph.read_columns, ph.reader)
                return
            if frame is None:       # never read, or dropped at the cap
                frame = ph.reader.read_group(g, ph.read_columns)
            nb = ph.reader.group_nbytes(g, ph.read_columns)
            for rep in tg:
                rep.groups_read += 1
                rep.bytes_read += nb
                rep.rows_read += frame.nrows
            residual = [i for i in exprs if ph.proves[i][g] != ALL]
            if not residual and ph.steps:
                for rep in tg:
                    rep.groups_proved += 1
            mask = np.ones(frame.nrows, bool)
            for i in residual:
                mask &= np.asarray(steps[i].mask(frame), bool)
            case = np.asarray(frame[CASE])
            seg = glo + np.concatenate(
                [[0], np.cumsum(case[1:] != case[:-1])])
            for p in case_pos:
                mask &= keeps[p][seg]
            sel = frame.select(ph.chunk_columns)
            yield EventFrame(sel.columns, sel.valid, jnp.asarray(mask))

        def masked_for(frame, residual, cache):
            key = tuple(residual)
            if key not in cache:
                if not key:
                    cache[key] = frame
                else:
                    mask = np.ones(frame.nrows, bool)
                    for i in key:
                        mask &= np.asarray(steps[i].mask(frame), bool)
                    cache[key] = EventFrame(frame.columns, frame.valid,
                                            jnp.asarray(mask))
            return cache[key]

        for fi, ph in enumerate(physicals):
            off = offsets[fi]
            for g in ph._nonempty():
                glo = off + int(ph.seg_start[g])
                ghi = glo + int(ph.seg_count[g])
                meta = ph.metas[g]
                # phase one wants the rows iff some predicate's preceding
                # conjuncts don't refute the group and its own header
                # proof can't (presence bitsets / zone maps)
                want = any(
                    not any(ph.proves[i][g] == NONE for i in before[pos])
                    and steps[pos].phase1_prove(meta) != NONE
                    for pos in data_pos)
                frame = None
                if want:
                    frame = ph.reader.read_group(g, ph.read_columns)
                    cache: dict = {}
                    for pos in data_pos:
                        resid = [i for i in before[pos]
                                 if ph.proves[i][g] != ALL]
                        st, ca = states[pos]
                        states[pos] = kernels[pos].update(
                            st, ca, masked_for(frame, resid, cache))
                    dirty = True
                    held += 1
                else:
                    ghost = _ghost_chunk(_group_ghost(ph, g, p1_sketch),
                                         ph.read_columns, ph.reader)
                    for pos in data_pos:
                        st, ca = states[pos]
                        states[pos] = kernels[pos].update(st, ca, ghost)
                pending.append([fi, g, glo, ghi, frame, want])
                while held > cap:
                    for entry in pending:
                        if entry[4] is not None:
                            nb = physicals[entry[0]].reader.group_nbytes(
                                entry[1], physicals[entry[0]].read_columns)
                            for rep in targets[entry[0]]:
                                rep.phase1_groups_read += 1
                                rep.phase1_bytes_read += nb
                            entry[4], entry[5] = None, False
                            held -= 1
                            break
                # segments below the open one (ghi - 1) are closed: their
                # phase-one state slots are final, so those groups resolve
                while pending and pending[0][3] <= ghi - 1:
                    entry = pending.popleft()
                    if entry[4] is not None:
                        held -= 1
                    yield from emit(entry)
        while pending:
            entry = pending.popleft()
            yield from emit(entry)
        for rep in all_targets:
            rep.groups_skipped = rep.groups_total - rep.groups_read
        cell["finals"] = keep_masks()

    def factory():
        if cell["finals"] is None:
            yield from first_pass()
            return
        if cell["replay"] is None:      # resolved keeps -> plain schedules
            schedules, locals_ = [], []
            for ph, off in zip(physicals, offsets):
                lk = _local_keeps(cell["finals"], off, ph.num_cases or 0)
                schedules.append(ph.final_schedule(
                    lk, ghosts=True, skippable=True, sketch=sketch))
                locals_.append(lk)
            cell["replay"] = (schedules, locals_)
        for ph, sched, lk in zip(physicals, *cell["replay"]):
            yield from _iter_chunks(ph, sched, lk, ph.chunk_columns,
                                    ph.read_columns)

    src = ChunkedEventFrame(factory, num_chunks=None,
                            tables=dict(physicals[0].reader.tables))
    return src, merged


def multi_pruned_source(mplan: MultiPlan, *, prune: bool = True,
                        mask_exact: bool = True, sketch: bool = False,
                        prefetch: int | None = None
                        ) -> tuple[ChunkedEventFrame, ScanReport]:
    """Compile a multi-file plan into one re-iterable pruned chunk stream.

    The stream is the concatenation of every file's pruned scan; a single
    kernel driven over it is bitwise equal to mining the concatenation of
    the files (the engine's carry crosses file boundaries exactly as it
    crosses row-group boundaries — no state merging, no float reordering).
    The returned report aggregates the per-file reports (``per_file``).
    ``sketch`` attaches composed header sketch maps to every ghost chunk
    (what ``ghost_sketch`` kernels need); ``prefetch`` sets the read-ahead
    depth of every scan the source runs (``None`` = the
    ``REPRO_QUERY_PREFETCH`` environment default).

    Plans whose case predicates are all sketch-resolvable compile with
    zero phase-one passes; remaining data-dependent case predicates fuse
    into the scan itself (:func:`_single_pass_source`) when the plan is
    pruned with complete segment metadata — the classic two-pass schedule
    is the fallback.
    """
    physicals = [compile_plan(p, prune) for p in mplan.per_file()]
    check_homogeneous(ph.reader for ph in physicals)
    reports = [_base_report(ph) for ph in physicals]
    offsets, total = _multi_offsets(physicals)
    steps = physicals[0].steps
    sk_keeps = _sketch_keeps(physicals, total, steps)
    data_pos = [i for i, s in enumerate(steps)
                if isinstance(s, CasePredicate) and i not in sk_keeps]
    depth = prefetch_depth(prefetch)
    if (prune and mask_exact and data_pos and total is not None
            and all(ph.can_ghost for ph in physicals)):
        for rep in reports:
            rep.prefetch = depth
        return _single_pass_source(physicals, reports, offsets, total,
                                   sk_keeps, data_pos, sketch)
    keeps = _multi_phase1(physicals, reports, offsets, total, prefetch,
                          seeded=sk_keeps)
    if offsets is None:
        offsets = [0] * len(physicals)
    schedules, locals_ = _multi_schedules(physicals, reports, offsets, keeps,
                                          ghosts=mask_exact,
                                          skippable=mask_exact,
                                          sketch=sketch)
    for rep in reports:
        rep.prefetch = depth

    def factory():
        for ph, sched, lk in zip(physicals, schedules, locals_):
            yield from _iter_chunks(ph, sched, lk, ph.chunk_columns,
                                    ph.read_columns, depth)

    src = ChunkedEventFrame(factory,
                            num_chunks=sum(len(s) for s in schedules),
                            tables=dict(physicals[0].reader.tables))
    return src, merge_reports(reports)


# ------------------------------------------------------------ public API
def count_cases(plan: "Plan | MultiPlan") -> int | None:
    """Total case segments across the plan's file(s), from header metadata
    only (None when any file lacks segment metadata)."""
    if isinstance(plan, MultiPlan):
        physicals = [compile_plan(Plan(p), True) for p in plan.paths]
        _, total = _multi_offsets(physicals)
        return total
    return compile_plan(Plan(plan.path), True).num_cases


def pruned_source(plan: "Plan | MultiPlan", *, prune: bool = True,
                  mask_exact: bool = True, sketch: bool = False,
                  prefetch: int | None = None
                  ) -> tuple[ChunkedEventFrame, ScanReport]:
    """Compile a plan into a re-iterable pruned chunk stream.

    ``mask_exact=False`` keeps every group in the stream (residual masks
    only) for consumers that inspect masked rows; ``sketch=True`` attaches
    the composed header sketch maps to ghost chunks (what ``ghost_sketch``
    kernels — variants — need to replay skipped runs).  The returned
    source plugs into ``engine.run_streaming`` /
    ``repro.distributed.query``.  A single-file ``Plan`` is the one-path
    special case of :func:`multi_pruned_source` (one code path, one set
    of invariants).
    """
    if isinstance(plan, Plan):
        plan = MultiPlan((plan.path,), plan.steps, plan.projection)
    return multi_pruned_source(plan, prune=prune, mask_exact=mask_exact,
                               sketch=sketch, prefetch=prefetch)


def execute(plan: "Plan | MultiPlan", mine: engine.ChunkKernel, *,
            prune: bool = True, prefetch: int | None = None):
    """Fold a chunk kernel over the pruned scan of ``plan``.

    Returns ``(result, report)`` with ``result`` bitwise equal to running
    the same kernel over the eagerly filtered whole log (for multi-file
    plans: the eagerly filtered concatenation of the files).
    ``prune=False`` executes the identical plan without zone-map skipping
    (the full-scan baseline the benchmarks compare against).
    """
    src, report = pruned_source(
        plan, prune=prune, mask_exact=getattr(mine, "mask_exact", True),
        sketch=getattr(mine, "ghost_sketch", False), prefetch=prefetch)
    return engine.run_streaming(mine, src), report


# -------------------------------------------------- group-state execution
def grouped_eligible(kernel: engine.ChunkKernel, steps) -> bool:
    """True when ``plan`` can run on the group-state algebra: the kernel
    defines a ``stitch`` (bitwise-mergeable states) and every plan step is
    a row-level expression (case-level keep masks are global, so those
    plans stay on the sequential schedules)."""
    return engine.mergeable(kernel) and not any(
        isinstance(s, CasePredicate) for s in steps)


def _unit_key(ph: PhysicalPlan, item: ReadItem, spec_fp) -> tuple:
    """State-cache key of one read unit: kernel build fingerprint, file
    path + group index, the group's content signature, and the residual
    predicate set the fold masked with ("" when none — zone-proved and
    unfiltered folds share entries)."""
    residual_fp = "&".join(repr(ph.steps[i]) for i in item.residual)
    return (spec_fp, ph.reader.path, item.index,
            ph.reader.group_signature(item.index), residual_fp)


def group_states(plan: "Plan | MultiPlan", kernel: engine.ChunkKernel,
                 spec_fp, *, prune: bool = True):
    """One :class:`~repro.core.engine.GroupState` per nonempty row group.

    Each unit of :meth:`PhysicalPlan.unit_schedule` is resolved to a
    group state three ways:

    * **cached** — the state cache (``query.statecache``) holds a fold of
      this exact group content (group signature), under this exact kernel
      build (``spec_fp``) and residual predicate set: reuse it with zero
      I/O (``groups_cached``);
    * **folded** — read the group, apply the residual masks (the same
      masking the sequential scan applies), fold it fresh, and cache the
      result (``groups_read`` / ``groups_folded``);
    * **ghosted** — a refuted group folds its O(segments) ghost chunk
      fresh each time (no I/O; too cheap to be worth cache residency),
      counted in ``groups_skipped``.

    Residual-free groups key with an empty residual fingerprint, so the
    interior groups of a time-window query share cache entries with the
    unfiltered collect.  ``finalize_group(merge_tree(states))`` is
    bitwise equal to ``execute(plan, kernel)`` — the merge reconstructs
    the sequential fold exactly (``core.engine`` invariant).

    Returns ``(states, report)`` in stream order.
    """
    from repro.core.engine import fold_group

    from .statecache import state_cache

    if isinstance(plan, Plan):
        plan = MultiPlan((plan.path,), plan.steps, plan.projection)
    if not engine.mergeable(kernel):
        raise ValueError(f"kernel {kernel.name!r} defines no stitch — it "
                         f"cannot run on the group-state algebra")
    physicals = [compile_plan(p, prune) for p in plan.per_file()]
    check_homogeneous(ph.reader for ph in physicals)
    if not grouped_eligible(kernel, physicals[0].steps):
        raise ValueError("group_states: case-level predicates are not "
                         "group-local — use execute()")
    reports = [_base_report(ph) for ph in physicals]
    cache = state_cache()
    sketch = getattr(kernel, "ghost_sketch", False)
    mask_exact = getattr(kernel, "mask_exact", True)
    states: list[engine.GroupState] = []
    for ph, rep in zip(physicals, reports):
        steps = ph.steps
        for item in ph.unit_schedule(sketch=sketch, mask_exact=mask_exact):
            if isinstance(item, GhostItem):
                ghost = _ghost_chunk(item, ph.chunk_columns, ph.reader)
                states.append(fold_group(kernel, [ghost]))
                continue
            g = item.index
            key = _unit_key(ph, item, spec_fp)
            hit = cache.get(key)
            if hit is not None:
                rep.groups_cached += 1
                states.append(hit)
                continue
            frame = ph.reader.read_group(g, ph.read_columns)
            mask = np.ones(frame.nrows, bool)
            for i in item.residual:
                mask &= np.asarray(steps[i].mask(frame), bool)
            sel = frame.select(ph.chunk_columns)
            gs = fold_group(kernel, [EventFrame(sel.columns, sel.valid,
                                                jnp.asarray(mask))])
            cache.put(key, gs)
            states.append(gs)
            rep.groups_folded += 1
            rep.groups_read += 1
            rep.bytes_read += ph.reader.group_nbytes(g, ph.read_columns)
            rep.rows_read += frame.nrows
            if not item.residual and ph.steps:
                rep.groups_proved += 1
        rep.groups_skipped = (rep.groups_total - rep.groups_read
                              - rep.groups_cached)
    return states, merge_reports(reports)


def execute_grouped(plan: "Plan | MultiPlan", kernel: engine.ChunkKernel,
                    spec_fp, *, prune: bool = True):
    """Mine ``plan`` as a merge tree over per-group states.

    ``finalize(merge_tree(group_states(plan)))`` — bitwise equal to
    :func:`execute` with the same kernel, but incremental: a re-collect
    after appending a file (or new groups) only decodes what the state
    cache has not seen.  Returns ``(result, report)``.
    """
    states, report = group_states(plan, kernel, spec_fp, prune=prune)
    merged = engine.merge_tree(kernel, states)
    return engine.finalize_group(kernel, merged), report


def grouped_cache_probe(plan: "Plan | MultiPlan", kernel: engine.ChunkKernel,
                        spec_fp, *, prune: bool = True) -> dict | None:
    """How :func:`group_states` would resolve the plan *right now*, from
    headers alone — no data I/O, no cache mutation (probes with
    ``contains``, which skips the hit/miss counters).  Returns ``{"units",
    "cached", "fresh", "ghosted"}``, or ``None`` when the plan/kernel is
    not grouped-eligible (what ``Dataset.explain`` prints)."""
    from .statecache import state_cache

    if isinstance(plan, Plan):
        plan = MultiPlan((plan.path,), plan.steps, plan.projection)
    if not engine.mergeable(kernel):
        return None
    physicals = [compile_plan(p, prune) for p in plan.per_file()]
    if not grouped_eligible(kernel, physicals[0].steps):
        return None
    cache = state_cache()
    out = {"units": 0, "cached": 0, "fresh": 0, "ghosted": 0}
    for ph in physicals:
        for item in ph.unit_schedule(
                sketch=getattr(kernel, "ghost_sketch", False),
                mask_exact=getattr(kernel, "mask_exact", True)):
            out["units"] += 1
            if isinstance(item, GhostItem):
                out["ghosted"] += 1
            elif cache.contains(_unit_key(ph, item, spec_fp)):
                out["cached"] += 1
            else:
                out["fresh"] += 1
    return out


def _materialize(parts, physical: PhysicalPlan):
    """Concatenate compacted parts into one frame (+ projected tables)."""
    from repro.core.eventframe import concat_frames

    parts = [p for p in parts if p.nrows] or parts[:1]
    tables = {k: v for k, v in physical.reader.tables.items()
              if k in physical.chunk_columns}
    if not parts:
        schema = physical.reader.schema
        cols = {k: np.zeros(0, np.dtype(schema[k]["dtype"]))
                for k in physical.chunk_columns}
        valid = {k: np.zeros(0, bool) for k in physical.chunk_columns
                 if schema[k].get("has_valid") or "valid_offset" in schema[k]}
        return EventFrame.from_numpy(cols, valid), tables
    return concat_frames(parts), tables


def execute_frame(plan: "Plan | MultiPlan", *, prune: bool = True,
                  prefetch: int | None = None):
    """Materialize the filtered, projected frame (rows the predicates
    refute are dropped — equal to the eager filter chain + ``compact``;
    multi-file plans concatenate in path order).

    Returns ``(frame, tables, report)``.
    """
    if isinstance(plan, Plan):
        plan = MultiPlan((plan.path,), plan.steps, plan.projection)
    physicals, reports, offsets, keeps = _multi_compile(plan, prune, prefetch)
    schedules, locals_ = _multi_schedules(physicals, reports, offsets,
                                          keeps, ghosts=False,
                                          skippable=True)
    depth = prefetch_depth(prefetch)
    for rep in reports:
        rep.prefetch = depth
    parts = []
    for ph, sched, lk in zip(physicals, schedules, locals_):
        parts += [c.compact() for c in
                  _iter_chunks(ph, sched, lk, ph.chunk_columns,
                               ph.read_columns, depth)]
    frame, tables = _materialize(parts, physicals[0])
    return frame, tables, merge_reports(reports)
