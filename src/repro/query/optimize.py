"""Plan compilation: predicate + projection pushdown over zone maps.

``compile_plan`` turns a logical :class:`repro.query.plan.Plan` into a
:class:`PhysicalPlan`:

* **predicate pushdown** — every row-level conjunct is ``prove()``-d
  against each row group's zone maps; a group any conjunct refutes is
  never read (its byte extents are never touched), and a group a conjunct
  *proves* skips that conjunct's residual mask;
* **projection pushdown** — the scan reads only the union of the
  consumer's columns and the columns of the predicates that still need
  residual evaluation (plus the case column when segment bookkeeping is
  required);
* **segment accounting** — from the per-group ``segments`` / ``tail``
  metadata the planner derives, without any data I/O, the global segment
  id of every group's first row and the total case count.  This is what
  keeps case-indexed kernels (case sizes, durations, variants, case-level
  filters) bitwise identical under pruning: a skipped run of groups is
  replaced by an O(segments) *ghost chunk* that advances the engine's
  carry exactly as the unread rows would have (all of them masked).
  When the consumer declares ``ghost_sketch`` (variants), the ghost also
  carries the run's composed per-segment affine polyhash maps
  (``core.polyhash``), so even validity-blind hashing replays skipped
  runs exactly;
* **two-pass planning** — each :class:`CasePredicate` gets its own
  phase-one schedule (pruned by the conjuncts that precede it in the
  plan), whose streamed kernel result becomes a per-case keep mask; the
  final scan then also skips groups whose entire segment range is
  refuted by the keep masks.

The executor (``repro.query.exec``) asks the physical plan for a
*schedule* — an ordered list of ``read`` / ``ghost`` items — once the
phase-one keep masks are known.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.eventframe import ACTIVITY, CASE
from repro.storage.edf import EDFReader, pooled_reader

from .expr import ALL, NONE, CasePredicate, Expr, bind_schema
from .plan import Plan


@dataclasses.dataclass(frozen=True)
class ReadItem:
    """Read group ``index`` and mask it with the listed residual steps."""

    index: int
    residual: tuple       # step positions (Expr) needing per-row evaluation
    case_steps: tuple     # step positions (CasePredicate) to broadcast


@dataclasses.dataclass(frozen=True)
class GhostItem:
    """A run of consecutive skipped groups, collapsed to segment metadata."""

    indices: tuple        # skipped group indices (ascending, all nonempty)
    segments: int         # distinct case segments across the run
    first_case: int       # case id of the run's first row
    tail: dict            # last row's {"values", "valid"} halo
    sketch: dict | None = None  # per-segment composed affine polyhash maps
    #   ({"mul1","add1","mul2","add2"} uint32 arrays of length ``segments``,
    #   header sketches composed across the run's group boundaries) — only
    #   materialized when the consumer asked for it (kernel.ghost_sketch)


@dataclasses.dataclass
class PhysicalPlan:
    reader: EDFReader
    plan: Plan
    steps: tuple                    # resolved steps, plan order
    chunk_columns: tuple            # what the consumer (kernel) sees
    read_columns: tuple             # what the scan materializes
    prune: bool
    metas: list | None              # per-group metadata (None: prune=False)
    proves: dict                    # expr step position -> list[str] per group
    seg_start: np.ndarray | None    # global segment id of each group's row 0
    seg_count: np.ndarray | None    # segments per group
    num_cases: int | None           # total case segments in the file
    can_ghost: bool                 # segment metadata complete enough to skip

    # ------------------------------------------------------------ helpers
    def _nonempty(self):
        return [g for g in range(self.reader.num_groups)
                if self.reader.group_nrows(g) > 0]

    def _keep_refutes(self, g: int, pos: int, keeps: dict) -> bool:
        """True when keep mask of the case predicate at ``pos`` rules out
        every segment that intersects group ``g``."""
        if self.seg_start is None:
            return False            # no segment metadata — never skip by keep
        lo = int(self.seg_start[g])
        hi = lo + int(self.seg_count[g])
        return not keeps[pos][lo:hi].any()

    def _run_sketch(self, run) -> dict:
        """Compose the run's per-group header sketches into one per-segment
        map list, merging the maps of a case that straddles a group boundary
        (apply the earlier group's partial map first, then the later's)."""
        acc: dict | None = None
        prev_tail = None
        for g in run:
            sk = self.reader.group_sketch(g)
            if sk is None:
                raise ValueError(
                    f"group {g} of {self.reader.path!r} has no variant "
                    f"sketch (case/activity columns missing?) — cannot "
                    f"ghost-skip it for a sketch-consuming kernel")
            first = self.metas[g]["zones"][CASE]["min"]
            if acc is None:
                acc = {k: sk[k].copy() for k in sk}
            elif prev_tail is not None and first == prev_tail:
                for mk, ak in (("mul1", "add1"), ("mul2", "add2")):
                    # python-int compose: uint32 scalar ops would warn on wrap
                    m0, a0 = int(sk[mk][0]), int(sk[ak][0])
                    acc[ak][-1] = (int(acc[ak][-1]) * m0 + a0) & 0xFFFFFFFF
                    acc[mk][-1] = (int(acc[mk][-1]) * m0) & 0xFFFFFFFF
                acc = {k: np.concatenate([acc[k], sk[k][1:]]) for k in sk}
            else:
                acc = {k: np.concatenate([acc[k], sk[k]]) for k in sk}
            prev_tail = self.metas[g]["tail"]["values"][CASE]
        return acc

    def _schedule(self, skip, residual, case_steps, ghosts: bool,
                  sketch: bool = False):
        """Fold per-group decisions into read items and ghost runs."""
        items: list = []
        run: list[int] = []

        def flush():
            if not run:
                return
            segs = 0
            prev_tail = None
            for g in run:
                first = self.metas[g]["zones"][CASE]["min"]
                segs += int(self.metas[g]["segments"])
                if prev_tail is not None and first == prev_tail:
                    segs -= 1
                prev_tail = self.metas[g]["tail"]["values"][CASE]
            items.append(GhostItem(
                tuple(run), segs,
                self.metas[run[0]]["zones"][CASE]["min"],
                self.metas[run[-1]]["tail"],
                self._run_sketch(run) if sketch else None))
            run.clear()

        for g in self._nonempty():
            if skip(g):
                if ghosts:
                    run.append(g)
                continue
            flush()
            items.append(ReadItem(g, tuple(residual(g)), tuple(case_steps)))
        flush()
        return items

    # ----------------------------------------------------------- schedules
    def phase1_schedule(self, pos: int, keeps: dict, sketch: bool = False):
        """Schedule for phase one of the case predicate at step ``pos``;
        pruned by the plan steps that precede it."""
        pred = self.steps[pos]
        before_exprs = [i for i in range(pos) if isinstance(self.steps[i], Expr)]
        before_cases = [i for i in range(pos)
                        if isinstance(self.steps[i], CasePredicate)]

        def skip(g):
            # phase-one kernels are segment-indexed: skipping is only safe
            # when a ghost chunk can advance the numbering
            if not (self.prune and self.can_ghost):
                return False
            if any(self.proves[i][g] == NONE for i in before_exprs):
                return True
            if pred.phase1_prove(self.metas[g]) == NONE:
                return True
            return any(self._keep_refutes(g, i, keeps) for i in before_cases)

        def residual(g):
            # keep every conjunct the zone maps did not PROVE: a group that
            # is read despite a NONE proof (no ghost available) still needs
            # its refuting predicate applied to mask the rows
            if not self.prune:
                return before_exprs
            return [i for i in before_exprs if self.proves[i][g] != ALL]

        return self._schedule(skip, residual, tuple(before_cases),
                              ghosts=self.can_ghost and self.prune,
                              sketch=sketch)

    def final_schedule(self, keeps: dict, ghosts: bool = True,
                       skippable: bool = True, sketch: bool = False):
        """Schedule for the final (mine / materialize) pass.

        ``skippable=False`` reads every group (consumers that inspect
        masked rows — ``mask_exact=False`` kernels) while still skipping
        residual evaluation on groups the zone maps prove.
        """
        exprs = [i for i, s in enumerate(self.steps) if isinstance(s, Expr)]
        cases = [i for i, s in enumerate(self.steps)
                 if isinstance(s, CasePredicate)]
        # with ghosts requested (mine path), a skip is only safe when the
        # segment metadata can stand in for the unread rows; without ghosts
        # (materialize path) skipped rows are simply dropped
        can_skip = self.prune and skippable and (self.can_ghost or not ghosts)

        def skip(g):
            if not can_skip:
                return False
            if any(self.proves[i][g] == NONE for i in exprs):
                return True
            return any(self._keep_refutes(g, i, keeps) for i in cases)

        def residual(g):
            # non-ALL (not just SOME): a NONE-proved group can still be
            # read — mask_exact=False consumers, or no ghost metadata —
            # and must then arrive with its rows masked
            if not self.prune:
                return exprs
            return [i for i in exprs if self.proves[i][g] != ALL]

        return self._schedule(skip, residual, tuple(cases),
                              ghosts=ghosts and self.can_ghost and self.prune,
                              sketch=sketch)

    def unit_schedule(self, sketch: bool = False, mask_exact: bool = True):
        """Group-granular schedule: exactly one item per nonempty group.

        The group-state algebra (``core.engine``) folds each item into its
        own :class:`~repro.core.engine.GroupState`, so units must map 1:1
        to row groups — no run coalescing, or the per-group states could
        not be cached and re-merged independently.  Refuted groups become
        *single-group* ghost items (segment metadata permitting); their
        fold is O(segments) with zero I/O.  Only row-level (``Expr``)
        plans qualify — case-level predicates need global keep masks and
        stay on the sequential schedules.
        """
        exprs = [i for i, s in enumerate(self.steps) if isinstance(s, Expr)]
        if any(isinstance(s, CasePredicate) for s in self.steps):
            raise ValueError("unit_schedule: case-level predicates are not "
                             "group-local — use final_schedule")
        items: list = []
        for g in self._nonempty():
            refuted = self.prune and any(
                self.proves[i][g] == NONE for i in exprs)
            if refuted and self.can_ghost and mask_exact:
                meta = self.metas[g]
                items.append(GhostItem(
                    (g,), int(self.seg_count[g]),
                    meta["zones"][CASE]["min"], meta["tail"],
                    self._run_sketch([g]) if sketch else None))
                continue
            residual = [i for i in exprs if self.proves[i][g] != ALL] \
                if self.prune else exprs
            items.append(ReadItem(g, tuple(residual), ()))
        return items


def compile_plan(plan: Plan, prune: bool = True) -> PhysicalPlan:
    # readers are pooled: every plan over the same file shares one cached
    # header (and one open handle) — a multi-file Dataset compiles N plans
    # without re-parsing or re-synthesizing anything
    reader = pooled_reader(plan.path)
    steps = tuple(s.resolve(reader.tables) if isinstance(s, CasePredicate)
                  else bind_schema(s, reader.schema) for s in plan.steps)
    exprs = [(i, s) for i, s in enumerate(steps) if isinstance(s, Expr)]
    case_steps = [s for s in steps if isinstance(s, CasePredicate)]

    chunk_columns = tuple(plan.projection) if plan.projection is not None \
        else reader.column_names
    unknown = set(chunk_columns) - set(reader.column_names)
    for _, e in exprs:
        unknown |= e.columns() - set(reader.column_names)
    for s in case_steps:
        unknown |= s.columns() - set(reader.column_names)
    if unknown:
        raise KeyError(f"plan references columns not in {plan.path!r}: "
                       f"{sorted(unknown)}")
    read = set(chunk_columns)
    for _, e in exprs:
        read |= e.columns()
    for s in case_steps:
        # phase-one kernels + segment broadcast + the predicate's column
        read |= {CASE, ACTIVITY} | s.columns()
    read_columns = tuple(sorted(read))

    metas = None
    proves: dict = {}
    seg_start = seg_count = None
    num_cases = None
    can_ghost = False
    if prune or case_steps:
        # case predicates need the segment accounting (kernel capacity +
        # keep-mask broadcast) even on an unpruned scan
        metas = [reader.group_meta(g) for g in range(reader.num_groups)]
        if prune:
            proves = {i: [e.prove(metas[g]) for g in range(reader.num_groups)]
                      for i, e in exprs}
        nonempty = [g for g in range(reader.num_groups)
                    if reader.group_nrows(g) > 0]
        can_ghost = (CASE in reader.schema and
                     all("segments" in metas[g] for g in nonempty))
        if can_ghost:
            seg_start = np.zeros(reader.num_groups, np.int64)
            seg_count = np.zeros(reader.num_groups, np.int64)
            last_seg, prev_tail = -1, None
            for g in nonempty:
                first = metas[g]["zones"][CASE]["min"]
                cont = prev_tail is not None and first == prev_tail
                seg_start[g] = last_seg if cont else last_seg + 1
                seg_count[g] = int(metas[g]["segments"])
                last_seg = seg_start[g] + seg_count[g] - 1
                prev_tail = metas[g]["tail"]["values"][CASE]
            num_cases = int(last_seg) + 1
    return PhysicalPlan(reader, plan, steps, chunk_columns, read_columns,
                        prune, metas, proves, seg_start, seg_count,
                        num_cases, can_ghost)
