"""Predicate expression trees over event attributes (the query language).

An :class:`Expr` is a small, closed algebra of row-level predicates —
comparisons, set membership, time ranges, and ``& | ~`` combinations —
built with the :func:`col` factory::

    from repro.query import col
    e = col("concept:name").isin([3, 7]) & col("time:timestamp").between(0, 9)

Every node supports three operations, and the split between them is the
whole point of the subsystem:

* ``columns()`` — the attributes the predicate reads (projection pushdown:
  the scan loads only these plus what the downstream kernel needs);
* ``mask(frame)`` — the per-row boolean valuation, *bitwise identical* to
  the corresponding eager filter in ``repro.core.filtering`` (comparisons
  and ``isin`` follow ``filter_attr_values`` and compare stored values;
  ``between`` follows ``filter_time_range`` and additionally requires the
  cell's epsilon flag — a missing timestamp never matches a range);
* ``prove(meta)`` — the tri-state zone-map valuation over a whole row
  group: ``NONE`` (no row can match → the scan skips the group's bytes),
  ``ALL`` (every row matches → the scan skips evaluating the residual
  mask), or ``SOME``.  Proofs are conservative: zone min/max cover every
  *stored* value (sentinels of invalid cells included), so refutation is
  always sound.

Case-level predicates (:func:`cases_containing`, :func:`case_size`) are
*not* row-local — they need a first pass over the log ("does this case
contain activity a anywhere?") before any row can be kept.  They implement
the :class:`CasePredicate` interface instead: a phase-one chunk kernel
(from ``core.filtering`` / ``core.stats``) whose result is a per-case keep
mask, which the planner then broadcasts through global segment ids in the
second, pruned pass.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eventframe import ACTIVITY, EventFrame

# tri-state zone-map valuations
NONE = "none"   # zone maps refute the predicate for every row of the group
SOME = "some"   # undecided — read the group and evaluate the residual mask
ALL = "all"     # zone maps prove the predicate for every row of the group

_NEG = {NONE: ALL, SOME: SOME, ALL: NONE}


def _zone(meta: dict, name: str) -> dict | None:
    return (meta.get("zones") or {}).get(name)


def _bitset(zone: dict) -> np.ndarray | None:
    """Decode a dictionary-presence bitset (or None when not recorded)."""
    bits = zone.get("bits")
    if bits is None:
        return None
    raw = np.frombuffer(bytes.fromhex(bits), np.uint8)
    return np.unpackbits(raw).astype(bool)


class Expr:
    """Base class of row-level predicate nodes (see module docstring)."""

    def columns(self) -> frozenset[str]:
        raise NotImplementedError

    def mask(self, frame: EventFrame) -> jax.Array:
        raise NotImplementedError

    def prove(self, meta: dict) -> str:
        """Tri-state valuation over a row group's zone maps (NONE/SOME/ALL)."""
        raise NotImplementedError

    def __and__(self, other: "Expr") -> "Expr":
        return And(_parts(self, other, And))

    def __or__(self, other: "Expr") -> "Expr":
        return Or(_parts(self, other, Or))

    def __invert__(self) -> "Expr":
        return Not(self)


def _parts(a: Expr, b: Expr, kind) -> tuple:
    """Flatten nested And/And (Or/Or) chains into one n-ary node."""
    if not isinstance(b, Expr):
        raise TypeError(f"cannot combine Expr with {type(b).__name__}")
    pa = a.parts if isinstance(a, kind) else (a,)
    pb = b.parts if isinstance(b, kind) else (b,)
    return pa + pb


# ------------------------------------------------------------ leaf nodes
_CMP = {
    "eq": lambda c, v: c == v, "ne": lambda c, v: c != v,
    "lt": lambda c, v: c < v, "le": lambda c, v: c <= v,
    "gt": lambda c, v: c > v, "ge": lambda c, v: c >= v,
}


@dataclasses.dataclass(frozen=True, eq=False)
class Cmp(Expr):
    """``frame[name] <op> value`` over stored values (validity-agnostic,
    matching ``filter_attr_values``'s treatment of the raw column)."""

    name: str
    op: str
    value: Any

    def columns(self):
        return frozenset((self.name,))

    def mask(self, frame):
        return _CMP[self.op](frame[self.name], self.value)

    def prove(self, meta):
        z = _zone(meta, self.name)
        if meta.get("nrows", 1) == 0:
            return NONE
        if z is None or "min" not in z:
            return SOME
        lo, hi, v, op = z["min"], z["max"], self.value, self.op
        if op == "eq":
            if v < lo or v > hi:
                return NONE
            bits = _bitset(z)
            if bits is not None and not (0 <= int(v) < bits.size and bits[int(v)]):
                return NONE
            return ALL if lo == hi == v else SOME
        if op == "ne":
            return _NEG[Cmp(self.name, "eq", v).prove(meta)]
        if op == "lt":
            return NONE if lo >= v else (ALL if hi < v else SOME)
        if op == "le":
            return NONE if lo > v else (ALL if hi <= v else SOME)
        if op == "gt":
            return NONE if hi <= v else (ALL if lo > v else SOME)
        if op == "ge":
            return NONE if hi < v else (ALL if lo >= v else SOME)
        raise ValueError(f"unknown comparison {op!r}")


@dataclasses.dataclass(frozen=True, eq=False)
class IsIn(Expr):
    """Membership in a value set — the pushdown form of
    ``filtering.filter_attr_values`` (same sorted-binary-search mask)."""

    name: str
    values: tuple

    def columns(self):
        return frozenset((self.name,))

    def mask(self, frame):
        from repro.core.filtering import isin_mask

        return isin_mask(frame[self.name], np.asarray(self.values))

    def prove(self, meta):
        if meta.get("nrows", 1) == 0 or not self.values:
            return NONE
        z = _zone(meta, self.name)
        if z is None or "min" not in z:
            return SOME
        vals = np.asarray(self.values).ravel()
        in_range = vals[(vals >= z["min"]) & (vals <= z["max"])]
        if in_range.size == 0:
            return NONE
        bits = _bitset(z)
        if bits is not None:
            chosen = np.zeros(bits.size, bool)
            ids = in_range[(in_range >= 0) & (in_range < bits.size)].astype(np.int64)
            chosen[ids] = True
            if not (bits & chosen).any():
                return NONE
            if not (bits & ~chosen).any():
                return ALL          # every id present in the group is chosen
            return SOME
        if z["min"] == z["max"]:
            return ALL
        return SOME


@dataclasses.dataclass(frozen=True, eq=False)
class Between(Expr):
    """``lo <= frame[name] <= hi`` on *valid* cells — the pushdown form of
    ``filtering.filter_time_range`` (epsilon cells never match)."""

    name: str
    lo: Any
    hi: Any

    def columns(self):
        return frozenset((self.name,))

    def mask(self, frame):
        from repro.core.filtering import time_range_mask

        return time_range_mask(frame, self.name, self.lo, self.hi)

    def prove(self, meta):
        n = meta.get("nrows", 1)
        if n == 0:
            return NONE
        z = _zone(meta, self.name)
        if z is None or "min" not in z:
            return SOME
        if z.get("nulls", 0) >= n:
            return NONE             # every cell is epsilon — nothing matches
        if self.hi < z["min"] or self.lo > z["max"]:
            return NONE
        if z.get("nulls", 0) == 0 and z["min"] >= self.lo and z["max"] <= self.hi:
            return ALL
        return SOME


# ------------------------------------------------------------ combinators
@dataclasses.dataclass(frozen=True, eq=False)
class And(Expr):
    parts: tuple

    def columns(self):
        return frozenset().union(*(p.columns() for p in self.parts))

    def mask(self, frame):
        m = self.parts[0].mask(frame)
        for p in self.parts[1:]:
            m = m & p.mask(frame)
        return m

    def prove(self, meta):
        got = [p.prove(meta) for p in self.parts]
        if NONE in got:
            return NONE
        return ALL if all(g == ALL for g in got) else SOME


@dataclasses.dataclass(frozen=True, eq=False)
class Or(Expr):
    parts: tuple

    def columns(self):
        return frozenset().union(*(p.columns() for p in self.parts))

    def mask(self, frame):
        m = self.parts[0].mask(frame)
        for p in self.parts[1:]:
            m = m | p.mask(frame)
        return m

    def prove(self, meta):
        got = [p.prove(meta) for p in self.parts]
        if ALL in got:
            return ALL
        return NONE if all(g == NONE for g in got) else SOME


@dataclasses.dataclass(frozen=True, eq=False)
class Not(Expr):
    part: Expr

    def columns(self):
        return self.part.columns()

    def mask(self, frame):
        return ~self.part.mask(frame)

    def prove(self, meta):
        return _NEG[self.part.prove(meta)]


# ------------------------------------------------------- schema binding
def _cast_const(schema: dict, name: str, v):
    """Snap a predicate constant to the column's storage dtype.

    Zone-map proofs compare in binary64 while ``mask`` compares in the
    column's dtype (a Python ``0.1`` weak-casts to ``float32(0.1) =
    0.10000000149``); snapping the constant once makes both sides see the
    same number, so a proof can never refute a row the mask would keep.
    Non-integral constants on integer columns are left untouched (the
    mask's promote-to-float comparison has no integer counterpart).
    """
    meta = schema.get(name)
    if meta is None:
        return v
    dt = np.dtype(meta["dtype"])
    try:
        if np.issubdtype(dt, np.integer):
            return int(dt.type(v)) if float(v).is_integer() else v
        return float(dt.type(v))
    except (OverflowError, ValueError):
        return v                    # out-of-range constant: leave untouched


def bind_schema(e: Expr, schema: dict) -> Expr:
    """Rebuild an expression with every leaf constant cast to its
    column's dtype (see :func:`_cast_const`); called by the planner."""
    if isinstance(e, Cmp):
        return Cmp(e.name, e.op, _cast_const(schema, e.name, e.value))
    if isinstance(e, IsIn):
        return IsIn(e.name, tuple(_cast_const(schema, e.name, v)
                                  for v in e.values))
    if isinstance(e, Between):
        return Between(e.name, _cast_const(schema, e.name, e.lo),
                       _cast_const(schema, e.name, e.hi))
    if isinstance(e, Not):
        return Not(bind_schema(e.part, schema))
    if isinstance(e, (And, Or)):
        return type(e)(tuple(bind_schema(p, schema) for p in e.parts))
    return e


# ---------------------------------------------------------------- column
class Col:
    """Column reference; comparison operators build the leaf nodes."""

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"col({self.name!r})"

    def isin(self, values: Iterable) -> Expr:
        return IsIn(self.name, tuple(np.asarray(list(values)).ravel().tolist()))

    def between(self, lo, hi) -> Expr:
        return Between(self.name, lo, hi)

    def __eq__(self, v):            # noqa: A003 — predicate DSL, not identity
        return Cmp(self.name, "eq", v)

    def __ne__(self, v):
        return Cmp(self.name, "ne", v)

    def __lt__(self, v):
        return Cmp(self.name, "lt", v)

    def __le__(self, v):
        return Cmp(self.name, "le", v)

    def __gt__(self, v):
        return Cmp(self.name, "gt", v)

    def __ge__(self, v):
        return Cmp(self.name, "ge", v)

    __hash__ = None                 # == builds an Expr; keys would be wrong


def col(name: str) -> Col:
    """Entry point of the predicate DSL: ``col("concept:name") == 3``."""
    return Col(name)


# ------------------------------------------------- case-level predicates
class CasePredicate:
    """A two-pass predicate: phase one folds a chunk kernel into a per-case
    keep mask; phase two broadcasts ``keep[segment_id]`` onto rows.  The
    planner prunes *both* passes with zone maps (phase one additionally via
    :meth:`phase1_prove`)."""

    def phase1_kernel(self, num_cases: int):
        """Chunk kernel whose streamed result yields the keep mask."""
        raise NotImplementedError

    def finalize_keep(self, result) -> np.ndarray:
        """Map the kernel's streamed result to a boolean (num_cases,) mask."""
        raise NotImplementedError

    def columns(self) -> frozenset[str]:
        """Extra columns phase one reads (beyond case + activity)."""
        return frozenset()

    def phase1_prove(self, meta: dict) -> str:
        """NONE when the group provably contributes nothing to phase one."""
        return SOME

    def resolve(self, tables: dict) -> "CasePredicate":
        """Resolve string attribute values against dictionary tables."""
        return self


@dataclasses.dataclass(frozen=True, eq=False)
class CaseContains(CasePredicate):
    """Keep every event of any case containing ``activity`` — the paper's
    case-level filter; phase one is ``filtering.cases_containing_kernel``."""

    activity: Any
    column: str = ACTIVITY

    def phase1_kernel(self, num_cases: int):
        from repro.core.filtering import cases_with_value_kernel

        return cases_with_value_kernel(self.column, int(self.activity),
                                       num_cases)

    def finalize_keep(self, result):
        return np.asarray(result, bool)

    def columns(self):
        return frozenset((self.column,))

    def phase1_prove(self, meta):
        # a group that cannot contain the activity contributes no hits
        return NONE if Cmp(self.column, "eq", int(self.activity)).prove(
            meta) == NONE else SOME

    def resolve(self, tables):
        if isinstance(self.activity, str):
            table = tables.get(self.column)
            if table is None or self.activity not in table:
                raise KeyError(f"activity {self.activity!r} not in the "
                               f"dictionary table of {self.column!r}")
            return CaseContains(table.index(self.activity), self.column)
        return self


@dataclasses.dataclass(frozen=True, eq=False)
class CaseSizeBetween(CasePredicate):
    """Keep cases whose valid-event count lies in ``[min_events,
    max_events]``; phase one is ``stats.case_sizes_kernel``."""

    min_events: int
    max_events: int

    def phase1_kernel(self, num_cases: int):
        from repro.core.stats import case_sizes_kernel

        return case_sizes_kernel(num_cases)

    def finalize_keep(self, result):
        sizes = np.asarray(result)
        return (sizes >= self.min_events) & (sizes <= self.max_events)


class SketchPredicate(CasePredicate):
    """A case predicate decidable from variant fingerprints alone.

    The planner resolves these **without any phase-one I/O** when every
    file carries (or can synthesize) per-group variant sketches: composing
    the header sketch maps in stream order reproduces each case's exact
    fingerprint pair, and :meth:`keep_from_fps` turns those into the keep
    mask.  Files without sketch metadata fall back to the generic
    phase-one kernel path (``phase1_kernel`` — the variants kernel itself,
    which is still pruned and ghost-exact)."""

    def keep_from_fps(self, fp1: np.ndarray, fp2: np.ndarray) -> np.ndarray:
        """Boolean keep mask from the per-case fingerprint pair arrays."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True, eq=False)
class VariantIn(SketchPredicate):
    """Keep every event of any case whose variant fingerprint is one of
    ``pairs`` — the variant-band filter.  ``pairs`` holds ``(fp1, fp2)``
    tuples as produced by ``variant_counts`` / ``collect("variants")``."""

    pairs: tuple

    def phase1_kernel(self, num_cases: int):
        from repro.core.variants import variants_kernel

        return variants_kernel(num_cases)

    def finalize_keep(self, result):
        fp1, fp2, _ncases = result
        return self.keep_from_fps(np.asarray(fp1), np.asarray(fp2))

    def keep_from_fps(self, fp1, fp2):
        keep = np.zeros(fp1.shape, bool)
        for a, b in self.pairs:
            keep |= (fp1 == np.uint32(a)) & (fp2 == np.uint32(b))
        return keep


@dataclasses.dataclass(frozen=True, eq=False)
class VariantOf(SketchPredicate):
    """Keep cases whose activity sequence equals ``sequence`` exactly.

    Resolves (at plan time, against the file's dictionary tables when the
    sequence is given as strings) to a single-pair :class:`VariantIn` via
    :func:`repro.core.polyhash.sequence_fingerprint`."""

    sequence: tuple

    def resolve(self, tables):
        from repro.core.polyhash import sequence_fingerprint

        seq = self.sequence
        if any(isinstance(a, str) for a in seq):
            table = tables.get(ACTIVITY)
            if table is None:
                raise KeyError(f"no dictionary table for {ACTIVITY!r}; "
                               f"pass integer activity ids")
            seq = tuple(table.index(a) if isinstance(a, str) else int(a)
                        for a in seq)
        return VariantIn((sequence_fingerprint(seq),))

    def phase1_kernel(self, num_cases: int):
        raise RuntimeError("VariantOf must be resolve()-d to VariantIn "
                           "before execution")

    def finalize_keep(self, result):
        raise RuntimeError("VariantOf must be resolve()-d to VariantIn "
                           "before execution")

    def keep_from_fps(self, fp1, fp2):
        raise RuntimeError("VariantOf must be resolve()-d to VariantIn "
                           "before execution")


def cases_containing(activity, column: str = ACTIVITY) -> CaseContains:
    """Case-level ``contains(activity)``; accepts a dictionary id or the
    decoded string (resolved against the file's tables at plan time)."""
    return CaseContains(activity, column)


def case_size(min_events: int, max_events: int) -> CaseSizeBetween:
    """Case-level size filter (``filtering.filter_case_size`` pushed down)."""
    return CaseSizeBetween(int(min_events), int(max_events))


def variant_in(pairs) -> VariantIn:
    """Case-level variant membership filter.  ``pairs`` is an iterable of
    ``(fp1, fp2)`` fingerprint tuples (see ``collect("variants")``); the
    planner decides it from header sketches alone — zero phase-one I/O —
    whenever the files carry variant sketch metadata."""
    return VariantIn(tuple((int(a) & 0xFFFFFFFF, int(b) & 0xFFFFFFFF)
                           for a, b in pairs))


def variant_of(sequence) -> VariantOf:
    """Keep cases following exactly this activity sequence (ids or decoded
    strings — strings resolve against the file's tables at plan time)."""
    return VariantOf(tuple(sequence))
