"""Logical query plans: ``scan -> filter* -> project -> mine``.

A :class:`Plan` is an immutable description of *what* to compute over an
EDF file — which predicates restrict the rows, which columns the consumer
needs — with no commitment to *how*.  The how (which row groups are read
at all, which predicates still need a residual mask, how global segment
numbering survives the skips) is decided by ``repro.query.optimize`` from
the file's zone maps, and executed by ``repro.query.exec``::

    from repro.query import scan, col, execute
    plan = (scan("log.edf")
            .filter(col(CASE).between(1_000, 2_000))
            .filter(col(ACTIVITY).isin([2, 5]))
            .project([CASE, ACTIVITY]))
    graph, report = execute(plan, mine=dfg_kernel(num_activities))

Filters are applied in order; each step is either a row-level
:class:`~repro.query.expr.Expr` or a two-pass
:class:`~repro.query.expr.CasePredicate`.  The composed semantics are
exactly the eager chain of ``repro.core.filtering`` calls the plan
replaces — the executor's contract is bitwise identity with
``mine(filterN(...filter1(edf.read(path))))``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Iterable, Sequence

from .expr import CasePredicate, Expr


def check_predicate(predicate) -> None:
    """Shared ``filter()`` argument validation (Plan / MultiPlan / Dataset)."""
    if not isinstance(predicate, (Expr, CasePredicate)):
        raise TypeError(
            f"filter() takes an Expr or CasePredicate, got "
            f"{type(predicate).__name__} (build one with col()/"
            f"cases_containing()/case_size())")


@dataclasses.dataclass(frozen=True)
class Plan:
    """Immutable logical plan over one EDF file (see module docstring)."""

    path: str
    steps: tuple = ()               # Expr | CasePredicate, in application order
    projection: tuple | None = None  # None = every column in the schema

    def filter(self, predicate) -> "Plan":
        """Append a filter step (row-level ``Expr`` or ``CasePredicate``)."""
        check_predicate(predicate)
        return dataclasses.replace(self, steps=self.steps + (predicate,))

    def project(self, columns: Iterable[str]) -> "Plan":
        """Restrict the columns the scan materializes (the downstream
        kernel must find every column it reads in this set)."""
        return dataclasses.replace(self, projection=tuple(columns))

    # ------------------------------------------------------------- views
    @property
    def exprs(self) -> tuple:
        return tuple(s for s in self.steps if isinstance(s, Expr))

    @property
    def case_predicates(self) -> tuple:
        return tuple(s for s in self.steps if isinstance(s, CasePredicate))

    def describe(self) -> str:
        """One line per plan node (scan -> filters -> project)."""
        lines = [f"scan({self.path!r})"]
        lines += [f"  filter {s!r}" for s in self.steps]
        if self.projection is not None:
            lines.append(f"  project {list(self.projection)}")
        return "\n".join(lines)

    def union(self, other: "Plan | MultiPlan") -> "MultiPlan":
        """Widen this plan to also scan ``other``'s file(s) — see
        :meth:`MultiPlan.union` for the compatibility rules."""
        return MultiPlan((self.path,), self.steps, self.projection).union(other)


@dataclasses.dataclass(frozen=True)
class MultiPlan:
    """One logical plan over a *set* of EDF files.

    The files are the ordered partitions of one (case,time)-sorted log
    (cases may even straddle a file boundary — the executor's carry flows
    across files exactly as it flows across row groups).  Filters and
    projection apply to every file; each file keeps its own zone-map
    pruning, and the executor drives a single kernel over the concatenated
    pruned streams, so the result is bitwise equal to mining the
    concatenation of the files.  Build with :func:`scan_many` or by
    ``union``-ing plans.
    """

    paths: tuple
    steps: tuple = ()
    projection: tuple | None = None

    def filter(self, predicate) -> "MultiPlan":
        """Append a filter step (applies to every file)."""
        check_predicate(predicate)
        return dataclasses.replace(self, steps=self.steps + (predicate,))

    def project(self, columns: Iterable[str]) -> "MultiPlan":
        """Restrict the columns every scan materializes."""
        return dataclasses.replace(self, projection=tuple(columns))

    def union(self, other: "Plan | MultiPlan") -> "MultiPlan":
        """Concatenate another plan's file set onto this one.

        Both sides must carry the *same* filter steps and projection
        (practically: union the scans first, then filter the union) — a
        union of differently-filtered plans has no single logical plan to
        compile to.
        """
        if isinstance(other, Plan):
            other = MultiPlan((other.path,), other.steps, other.projection)
        if not isinstance(other, MultiPlan):
            raise TypeError(f"union() takes a Plan or MultiPlan, got "
                            f"{type(other).__name__}")
        if self.steps != other.steps or self.projection != other.projection:
            raise ValueError(
                "union() requires identical filter/projection state on both "
                "sides; build the union first, then filter it")
        return dataclasses.replace(self, paths=self.paths + other.paths)

    def per_file(self) -> tuple[Plan, ...]:
        """The single-file plan each scan compiles from."""
        return tuple(Plan(p, self.steps, self.projection) for p in self.paths)

    # ------------------------------------------------------------- views
    @property
    def exprs(self) -> tuple:
        return tuple(s for s in self.steps if isinstance(s, Expr))

    @property
    def case_predicates(self) -> tuple:
        return tuple(s for s in self.steps if isinstance(s, CasePredicate))

    def describe(self) -> str:
        lines = [f"scan_many({list(self.paths)!r})"]
        lines += [f"  filter {s!r}" for s in self.steps]
        if self.projection is not None:
            lines.append(f"  project {list(self.projection)}")
        return "\n".join(lines)


def scan_many(paths: Iterable[str]) -> MultiPlan:
    """Start a lazy plan over an ordered set of EDF files (the partitions
    of one sorted log)."""
    paths = tuple(paths)
    if not paths:
        raise ValueError("scan_many() needs at least one path")
    return MultiPlan(paths)


def scan(path: str) -> Plan:
    """Start a lazy plan over an EDF file (any version; zone maps are
    synthesized on open for v1/v2 files).

    .. deprecated:: use ``repro.open(path).filter(...)`` — the ``Dataset``
       facade plans over file *sets* and picks the execution engine; the
       ``Plan`` IR stays public for custom drivers via ``Plan(path)``.
    """
    warnings.warn(
        "repro.query.scan() is deprecated; use repro.open(path) and the "
        "Dataset verbs (.filter/.dfg/.stats/...) — or Plan(path) directly "
        "for a raw logical plan", DeprecationWarning, stacklevel=2)
    return Plan(path)
