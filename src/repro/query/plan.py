"""Logical query plans: ``scan -> filter* -> project -> mine``.

A :class:`Plan` is an immutable description of *what* to compute over an
EDF file — which predicates restrict the rows, which columns the consumer
needs — with no commitment to *how*.  The how (which row groups are read
at all, which predicates still need a residual mask, how global segment
numbering survives the skips) is decided by ``repro.query.optimize`` from
the file's zone maps, and executed by ``repro.query.exec``::

    from repro.query import scan, col, execute
    plan = (scan("log.edf")
            .filter(col(CASE).between(1_000, 2_000))
            .filter(col(ACTIVITY).isin([2, 5]))
            .project([CASE, ACTIVITY]))
    graph, report = execute(plan, mine=dfg_kernel(num_activities))

Filters are applied in order; each step is either a row-level
:class:`~repro.query.expr.Expr` or a two-pass
:class:`~repro.query.expr.CasePredicate`.  The composed semantics are
exactly the eager chain of ``repro.core.filtering`` calls the plan
replaces — the executor's contract is bitwise identity with
``mine(filterN(...filter1(edf.read(path))))``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .expr import CasePredicate, Expr


@dataclasses.dataclass(frozen=True)
class Plan:
    """Immutable logical plan over one EDF file (see module docstring)."""

    path: str
    steps: tuple = ()               # Expr | CasePredicate, in application order
    projection: tuple | None = None  # None = every column in the schema

    def filter(self, predicate) -> "Plan":
        """Append a filter step (row-level ``Expr`` or ``CasePredicate``)."""
        if not isinstance(predicate, (Expr, CasePredicate)):
            raise TypeError(
                f"filter() takes an Expr or CasePredicate, got "
                f"{type(predicate).__name__} (build one with col()/"
                f"cases_containing()/case_size())")
        return dataclasses.replace(self, steps=self.steps + (predicate,))

    def project(self, columns: Iterable[str]) -> "Plan":
        """Restrict the columns the scan materializes (the downstream
        kernel must find every column it reads in this set)."""
        return dataclasses.replace(self, projection=tuple(columns))

    # ------------------------------------------------------------- views
    @property
    def exprs(self) -> tuple:
        return tuple(s for s in self.steps if isinstance(s, Expr))

    @property
    def case_predicates(self) -> tuple:
        return tuple(s for s in self.steps if isinstance(s, CasePredicate))

    def describe(self) -> str:
        """One line per plan node (scan -> filters -> project)."""
        lines = [f"scan({self.path!r})"]
        lines += [f"  filter {s!r}" for s in self.steps]
        if self.projection is not None:
            lines.append(f"  project {list(self.projection)}")
        return "\n".join(lines)


def scan(path: str) -> Plan:
    """Start a lazy plan over an EDF file (any version; zone maps are
    synthesized on open for v1/v2 files)."""
    return Plan(path)
