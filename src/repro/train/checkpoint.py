"""Checkpointing: shard-aware save/restore, async writes, keep-K, auto-resume.

Fault-tolerance contract (the multi-pod story):
* saves are atomic (write to ``step_N.tmp`` dir, fsync, rename) so a node
  failure mid-save never corrupts the latest checkpoint;
* ``latest_step`` scans for the newest *complete* checkpoint, so restart
  after failure resumes from the last good step — no coordinator needed;
* async mode overlaps serialization with the next train steps (the device->
  host copy is synchronous, the file I/O runs on a worker thread);
* restore reshards automatically: arrays are saved unsharded (host gather)
  and re-placed with ``jax.device_put`` under the *current* mesh, so a
  restart on a different device count (elastic re-mesh) just works.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(k): np.asarray(v) for k, v in flat}


def _unflatten_like(tree, data: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for k, v in flat:
        key = jax.tree_util.keystr(k)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(v.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {v.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [v for _, v in zip(flat, leaves)])


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: dict | None = None):
        host = _flatten(state)          # device->host (synchronous, cheap copy)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write(self, step: int, host: dict, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **extra}, f)
        os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings=None) -> Any:
        path = os.path.join(self.dir, f"step_{step:09d}", "arrays.npz")
        with np.load(path) as z:
            data = {k: z[k] for k in z.files}
        tree = _unflatten_like(like, data)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    def restore_latest(self, like: Any, shardings=None):
        s = self.latest_step()
        if s is None:
            return None, None
        return s, self.restore(s, like, shardings)
