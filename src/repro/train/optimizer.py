"""AdamW + cosine schedule + global-norm clipping (pure pytree ops).

Optimizer state shards exactly like the parameters (same specs), giving
ZeRO/FSDP behaviour for free under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    prog = (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(oc: OptConfig, params, grads, opt_state):
    step = opt_state["step"] + 1
    lr = schedule(oc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = oc.beta1, oc.beta2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt_state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + oc.eps)
        u = u + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"lr": lr, "grad_norm": gnorm}
