from . import checkpoint, compression, ft, optimizer, trainstep
