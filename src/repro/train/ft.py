"""Fault tolerance: failure injection, straggler mitigation, elastic re-mesh.

On a real multi-pod deployment these hooks sit around the train loop:

* **Failure detection**: each step runs under a deadline; a step that throws
  (XLA halt, ICI timeout) or exceeds ``deadline_s`` marks the step failed.
* **Restart policy**: reload the latest complete checkpoint (see
  ``checkpoint.py``) and continue — the data pipeline is a pure function of
  (epoch, step) so it re-seeks deterministically.
* **Straggler mitigation**: per-step wall times feed an EWMA; a step slower
  than ``straggler_factor`` x EWMA is logged and counted. On TPU pods the
  mitigation is re-sharding around the slow pod (elastic re-mesh below) —
  within-step work stealing is not possible under SPMD.
* **Elastic re-mesh**: on permanent device loss, rebuild the mesh from the
  surviving device count (largest (data, model) factorization that keeps
  the model axis intact), re-derive shardings, and restore the checkpoint
  into the new topology (checkpoints are topology-free).

The CPU container cannot kill real TPU nodes, so tests drive these with a
``FailureInjector`` that raises on chosen steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax


class FailureInjector:
    """Deterministically raise at chosen steps (simulated node failure)."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.failed: list[int] = []

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failed.append(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 2.0
    alpha: float = 0.2
    ewma: float | None = None
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        is_straggler = self.ewma is not None and dt > self.factor * self.ewma
        self.ewma = dt if self.ewma is None else (1 - self.alpha) * self.ewma + self.alpha * dt
        if is_straggler:
            self.stragglers += 1
        return is_straggler


def elastic_mesh(num_devices: int, model_parallel: int, devices=None):
    """Largest (data, model) mesh from surviving devices; drops remainders.

    Keeps the model axis intact (a model shard cannot run degraded); shrinks
    the data axis —训 throughput degrades, correctness doesn't.
    """
    devices = devices if devices is not None else jax.devices()
    devices = devices[:num_devices]
    data = max(1, len(devices) // model_parallel)
    usable = devices[: data * model_parallel]
    import numpy as np

    arr = np.array(usable).reshape(data, model_parallel)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


def run_with_restarts(train_loop: Callable[[int], int], *, max_restarts: int = 5,
                      on_restart: Callable[[int], None] | None = None) -> int:
    """Drive ``train_loop(start_step) -> last_step`` through failures.

    ``train_loop`` must checkpoint internally and raise on failure; we resume
    it from the step after the latest checkpoint.
    """
    restarts = 0
    start = 0
    while True:
        try:
            return train_loop(start)
        except RuntimeError as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts)
            start = -1  # sentinel: loop re-reads latest checkpoint
