"""Gradient compression for the slow (cross-pod / DCN) all-reduce.

int8 quantization with per-tensor scale and **error feedback**: the
quantization residual is carried to the next step, so compression error
accumulates to zero over time (convergence-preserving). Intended placement:
within-pod gradients reduce at full precision over ICI (cheap); the
pod-level reduction — 8x fewer bytes over the slow link — uses this path
(``psum_compressed`` inside shard_map over the "pod" axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def quantize(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, errors):
    """Quantize (grads + carried errors); return (q_tree, scales, new_errors)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize(x)
        new_e = x - dequantize(q, s)
        return q, s, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(errors)
    qs, ss, es = zip(*[one(g, e) for g, e in zip(flat, eflat)])
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, ss),
            jax.tree.unflatten(treedef, es))


def psum_compressed(grads, errors, axis_name: str):
    """Error-feedback int8 psum over ``axis_name`` (use inside shard_map).

    Shards must agree on the quantization scale or the int sum is
    meaningless, so the scale is the ``pmax`` of local abs-maxima (one scalar
    per tensor — negligible traffic). The payload is int8 on the wire; the
    reduction accumulates in int32 to avoid fan-in overflow. The local
    quantization residual is carried to the next step (error feedback), so
    the compression bias vanishes over time.
    """
    # axis size as a traced psum of ones: works on every jax we support
    # (jax.lax.axis_size only exists on newer releases).
    n = jax.lax.psum(jnp.int32(1), axis_name)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * scale
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return s.astype(jnp.float32) * scale / n, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(errors)
    ms, es = zip(*[one(g, e) for g, e in zip(flat, eflat)])
    return jax.tree.unflatten(treedef, ms), jax.tree.unflatten(treedef, es)


def init_errors(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
