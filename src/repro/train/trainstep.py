"""Train step builder: loss, microbatched grad accumulation, AdamW update.

Microbatching is a ``lax.scan`` over microbatches — the natural structure for
activation-memory control AND compute/comm overlap (XLA pipelines the psum of
microbatch k with the compute of k+1 when latency hiding is on).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as Mdl
from repro.models.config import ModelConfig
from repro.models.module import ShardingRules
from .optimizer import OptConfig, adamw_update, init_opt_state


def loss_fn(cfg: ModelConfig, params, batch, rules: ShardingRules):
    logits = Mdl.forward(cfg, params, batch["tokens"], rules=rules,
                         frontend=batch.get("frontend"))
    if cfg.family == "vlm":                 # drop vision-prefix positions
        logits = logits[:, cfg.num_patches:]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
    mask = batch["loss_mask"].astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_train_step(cfg: ModelConfig, rules: ShardingRules, oc: OptConfig,
                    num_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": {m, v, step}}; batch leaves have leading
    dim = global_batch, reshaped to (num_microbatches, -1, ...) inside.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, rules))(params)

    def train_step(state, batch):
        params = state["params"]
        if num_microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape(num_microbatches, -1, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def mb_step(acc, mb):
                loss_acc, g_acc = acc
                loss, g = grads_of(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, g_acc, g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(mb_step, zero, mbs)
            inv = 1.0 / num_microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)

        new_params, new_opt, om = adamw_update(oc, params, grads, state["opt"])
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(cfg: ModelConfig, params):
    return {"params": params, "opt": init_opt_state(params)}


def state_specs(cfg: ModelConfig, rules: ShardingRules):
    from jax.sharding import PartitionSpec as P
    pspecs = Mdl.param_specs(cfg, rules)
    return {"params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": P()}}


def abstract_state(cfg: ModelConfig):
    params = Mdl.abstract_params(cfg)
    like = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    return {"params": params,
            "opt": {"m": like(params), "v": like(params),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}
