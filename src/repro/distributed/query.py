"""Distributed pruned scans: surviving row groups sharded over devices.

The query layer's pruned stream (``repro.query.exec.pruned_source``)
collapses zone-map-refuted row groups to O(segments) ghost rows; this
module concatenates that stream, splits it into equal contiguous shards
over the data axis, and reuses the ``distributed.dfg`` drivers verbatim —
one kernel update per shard, the boundary row recovered with a
``ppermute`` halo, the mergeable state combined with one ``psum``.  Ghost
rows ride along as ordinary all-masked rows, so the halo a shard hands to
its successor is exactly the carry the streaming path would have built,
and sharded == streamed == filter-then-mine, bitwise.

The one boundary the shards cannot resolve is the *stream's* final end
activity: the last physical row is padding (all-masked), so the trailing
end is re-applied host-side from the true tail row after the psum.

**Fused collection** (:func:`query_sharded_multi`) mines several
*distinct* mergeable states — ``"dfg"``, ``"discovery"`` — from ONE
gathered stream and ONE ``shard_map``: the member state kernels are
``core.engine.compose``-d, each member gets its own ppermute halo at its
own depth, and the psum carries every state in one leafwise all-reduce.
``query_sharded_dfg`` / ``query_sharded_discovery`` are its single-state
special cases, so fused and separate runs share one code path and are
bitwise equal state-for-state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import engine
from repro.core.dfg import DFG, dfg_kernel
from repro.core.discovery import DiscoveryState, discovery_kernel
from repro.core.eventframe import ACTIVITY, CASE
from repro.query.exec import pruned_source
from repro.query.plan import MultiPlan, Plan

from .dfg import fix_trailing_end, run_sharded_composed
from .discovery import _fix_end as fix_discovery_end

# every distributed lowering a KernelSpec.sharded_state can name:
# state name -> (kernel factory(num_activities, method), shard-end fix)
STATE_DRIVERS = {
    "dfg": (dfg_kernel, fix_trailing_end),
    "discovery": (discovery_kernel, fix_discovery_end),
}


def _gather(plan: "Plan | MultiPlan", prune: bool):
    """Concatenate the pruned stream's (case, activity, rows_valid).

    Multi-file plans concatenate every file's pruned scan in path order
    (``repro.query.multi_pruned_source``), so the shards of a dataset-wide
    mine see one contiguous sorted log with ghost rows standing in for
    every skipped row group of every file.
    """
    src, report = pruned_source(plan.project((ACTIVITY, CASE)), prune=prune,
                                mask_exact=True)
    case_parts, act_parts, rv_parts = [], [], []
    for chunk in src:
        if chunk.nrows == 0:
            continue
        case_parts.append(np.asarray(chunk[CASE]))
        act_parts.append(np.asarray(chunk[ACTIVITY]))
        rv_parts.append(np.asarray(chunk.rows_valid(), bool))
    if not case_parts:
        z = np.zeros(0, np.int64)
        return z, z.astype(np.int32), np.zeros(0, bool), report
    return (np.concatenate(case_parts), np.concatenate(act_parts),
            np.concatenate(rv_parts), report)


def _pad_to_shards(case, act, rv, n_dev: int):
    """Pad with >= 1 all-masked copies of the last row so every shard is
    equally sized and the trailing end is *never* resolved on-device."""
    n = case.shape[0]
    if n == 0:
        case = np.zeros(1, np.int64)
        act = np.zeros(1, np.int32)
        rv = np.zeros(1, bool)
        n = 1
    pad = (-(n + 1)) % n_dev + 1
    case = np.concatenate([case, np.full(pad, case[-1], case.dtype)])
    act = np.concatenate([act, np.full(pad, act[-1], act.dtype)])
    rv = np.concatenate([rv, np.zeros(pad, bool)])
    return case, act, rv


def _apply_tail_end(dfg: DFG, tail) -> DFG:
    if tail is None or not tail[2]:
        return dfg
    return DFG(dfg.counts, dfg.starts,
               dfg.ends.at[tail[1]].add(jnp.int32(1), mode="drop"))


def _finish_state(name: str, state, tail):
    """Host-side tail fix per distributed state (the stream's true last
    row is padding on-device; see module docstring)."""
    if name == "dfg":
        return _apply_tail_end(state, tail)
    if name == "discovery":
        return DiscoveryState(_apply_tail_end(state["dfg"], tail),
                              state["l2"])
    raise KeyError(f"no distributed lowering named {name!r}; "
                   f"known: {sorted(STATE_DRIVERS)}")


def query_sharded_multi(plan: "Plan | MultiPlan", states, num_activities: int,
                        mesh, axis_name: str = "data", *, prune: bool = True,
                        method: str = "auto"):
    """Mine every distributed state in ``states`` (distinct names from
    :data:`STATE_DRIVERS`) from ONE gathered pruned stream and ONE
    ``shard_map``.  Returns ``({state_name: state}, ScanReport)`` — each
    state bitwise equal to its separate ``query_sharded_*`` run, with the
    event columns gathered and sharded exactly once however many verbs
    share the pass."""
    states = tuple(dict.fromkeys(states))       # dedupe, keep order
    unknown = set(states) - set(STATE_DRIVERS)
    if not states or unknown:
        raise KeyError(f"distributed states must be a non-empty subset of "
                       f"{sorted(STATE_DRIVERS)}; got {list(states)}")
    case, act, rv, report = _gather(plan, prune)
    tail = (int(case[-1]), int(act[-1]), bool(rv[-1])) if case.size else None
    n_dev = mesh.shape[axis_name]
    case, act, rv = _pad_to_shards(case, act, rv, n_dev)
    kernel = engine.compose({s: STATE_DRIVERS[s][0](num_activities, method)
                             for s in states})
    fix_ends = {s: STATE_DRIVERS[s][1] for s in states}

    def local(case, act, valid):
        return run_sharded_composed(kernel, fix_ends, case, act, valid,
                                    axis_name=axis_name, n_dev=n_dev)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis_name), P(axis_name), P(axis_name)),
                   out_specs=P())
    out = jax.jit(fn)(jnp.asarray(case), jnp.asarray(act), jnp.asarray(rv))
    return {s: _finish_state(s, out[s], tail) for s in states}, report


def query_sharded_dfg(plan: "Plan | MultiPlan", num_activities: int, mesh,
                      axis_name: str = "data", *, prune: bool = True,
                      method: str = "auto"):
    """Full DFG of a filtered log, mined from the pruned scan sharded over
    ``axis_name``.  Returns ``(DFG, ScanReport)``; counts/starts/ends are
    bitwise equal to ``dfg(filter(read(path)))``."""
    out, report = query_sharded_multi(plan, ("dfg",), num_activities, mesh,
                                      axis_name, prune=prune, method=method)
    return out["dfg"], report


def query_sharded_discovery(plan: "Plan | MultiPlan", num_activities: int, mesh,
                            axis_name: str = "data", *, prune: bool = True,
                            method: str = "auto"):
    """DFG + L2-loop discovery state over the pruned, sharded scan
    (feeds ``discover_alpha`` / ``discover_heuristics`` host-side)."""
    out, report = query_sharded_multi(plan, ("discovery",), num_activities,
                                      mesh, axis_name, prune=prune,
                                      method=method)
    return out["discovery"], report


def query_sharded_dfg_host(plan: "Plan | MultiPlan", num_activities: int, num_shards: int,
                           **kw):
    """CPU-host validation path (virtual device mesh), as in
    ``distributed.dfg.dfg_sharded_host``."""
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return query_sharded_dfg(plan, num_activities, mesh, **kw)


def query_sharded_discovery_host(plan: "Plan | MultiPlan", num_activities: int,
                                 num_shards: int, **kw):
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return query_sharded_discovery(plan, num_activities, mesh, **kw)
