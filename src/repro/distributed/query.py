"""Distributed pruned scans: surviving row groups sharded over devices.

The query layer's pruned stream (``repro.query.exec.pruned_source``)
collapses zone-map-refuted row groups to O(segments) ghost rows; this
module concatenates that stream, splits it into equal contiguous shards
over the data axis, and reuses the ``distributed.dfg`` drivers verbatim —
one kernel update per shard, the boundary row recovered with a
``ppermute`` halo, the mergeable state combined with one ``psum``.  Ghost
rows ride along as ordinary all-masked rows, so the halo a shard hands to
its successor is exactly the carry the streaming path would have built,
and sharded == streamed == filter-then-mine, bitwise.

The one boundary the shards cannot resolve is the *stream's* final end
activity: the last physical row is padding (all-masked), so the trailing
end is re-applied host-side from the true tail row after the psum.

**Fused collection** (:func:`query_sharded_multi`) mines several
*distinct* mergeable states — ``"dfg"``, ``"discovery"``, ``"variants"``
— from ONE gathered stream and ONE ``shard_map``: the halo-carry state
kernels are ``core.engine.compose``-d, each member gets its own ppermute
halo at its own depth, and the psum carries every state in one leafwise
all-reduce.  Variants rides the same shard_map with its own lowering
(``distributed.variants`` — per-row affine hash maps, an ``all_gather``
boundary fold instead of a halo, so ghost rows and shards smaller than a
case both work).  ``query_sharded_dfg`` / ``query_sharded_discovery``
are its single-state special cases, so fused and separate runs share one
code path and are bitwise equal state-for-state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import engine
from repro.core.dfg import DFG, dfg_kernel
from repro.core.discovery import DiscoveryState, discovery_kernel
from repro.core.eventframe import ACTIVITY, CASE
from repro.core.polyhash import BASE1, BASE2, SK_ADD1, SK_ADD2, SK_MUL1, \
    SK_MUL2
from repro.query.exec import pruned_source
from repro.query.plan import MultiPlan, Plan

from .dfg import fix_trailing_end, run_sharded_composed
from .discovery import _fix_end as fix_discovery_end
from .variants import run_sharded_variants

# every halo-carry distributed lowering a KernelSpec.sharded_state can name:
# state name -> (kernel factory(num_activities, method), shard-end fix)
STATE_DRIVERS = {
    "dfg": (dfg_kernel, fix_trailing_end),
    "discovery": (discovery_kernel, fix_discovery_end),
}

# every sharded state, halo-carry or bespoke ("variants" gathers affine
# hash maps and folds shard boundaries with an all_gather — see
# distributed.variants)
SHARDED_STATES = frozenset(STATE_DRIVERS) | {"variants"}


def _gather(plan: "Plan | MultiPlan", prune: bool, sketch: bool = False):
    """Concatenate the pruned stream's (case, activity, rows_valid).

    Multi-file plans concatenate every file's pruned scan in path order
    (``repro.query.multi_pruned_source``), so the shards of a dataset-wide
    mine see one contiguous sorted log with ghost rows standing in for
    every skipped row group of every file.  With ``sketch`` the stream's
    ghost chunks carry composed header sketch maps, and the gather also
    returns per-row affine hash maps ``(m1, b1, m2, b2)`` — real rows
    hash as ``(BASE, act+1)``, ghost segment rows as their composed
    sketch map, ghost padding rows as the identity — the sharded variants
    input.
    """
    src, report = pruned_source(plan.project((ACTIVITY, CASE)), prune=prune,
                                mask_exact=True, sketch=sketch)
    case_parts, act_parts, rv_parts, map_parts = [], [], [], []
    for chunk in src:
        if chunk.nrows == 0:
            continue
        case_parts.append(np.asarray(chunk[CASE]))
        act = np.asarray(chunk[ACTIVITY])
        act_parts.append(act)
        rv_parts.append(np.asarray(chunk.rows_valid(), bool))
        if sketch:
            if SK_MUL1 in chunk:
                map_parts.append(tuple(np.asarray(chunk[c]) for c in
                                       (SK_MUL1, SK_ADD1, SK_MUL2, SK_ADD2)))
            else:
                v = act.astype(np.uint32) + 1
                map_parts.append((np.full(v.shape, BASE1, np.uint32), v,
                                  np.full(v.shape, BASE2, np.uint32), v))
    if not case_parts:
        z = np.zeros(0, np.int64)
        maps = tuple(np.zeros(0, np.uint32) for _ in range(4)) \
            if sketch else None
        return z, z.astype(np.int32), np.zeros(0, bool), maps, report
    maps = tuple(np.concatenate([p[i] for p in map_parts])
                 for i in range(4)) if sketch else None
    return (np.concatenate(case_parts), np.concatenate(act_parts),
            np.concatenate(rv_parts), maps, report)


def _pad_to_shards(case, act, rv, n_dev: int, maps=None):
    """Pad with >= 1 all-masked copies of the last row so every shard is
    equally sized and the trailing end is *never* resolved on-device.
    Hash map padding is the *identity* map (1, 0): the padded rows extend
    the final case without touching its hash."""
    n = case.shape[0]
    if n == 0:
        case = np.zeros(1, np.int64)
        act = np.zeros(1, np.int32)
        rv = np.zeros(1, bool)
        if maps is not None:
            maps = tuple(np.zeros(1, np.uint32) for _ in range(4))
        n = 1
    pad = (-(n + 1)) % n_dev + 1
    case = np.concatenate([case, np.full(pad, case[-1], case.dtype)])
    act = np.concatenate([act, np.full(pad, act[-1], act.dtype)])
    rv = np.concatenate([rv, np.zeros(pad, bool)])
    if maps is not None:
        one = np.ones(pad, np.uint32)
        zero = np.zeros(pad, np.uint32)
        maps = tuple(np.concatenate([m, one if i % 2 == 0 else zero])
                     for i, m in enumerate(maps))
    return case, act, rv, maps


def _segment_markers(case):
    """Global ``(starts, seg, ends)`` of the padded case column — the
    variants lowering's segment geometry (host-derived once, sliced per
    shard by the shard_map)."""
    n = case.shape[0]
    starts = np.zeros(n, bool)
    starts[0] = True
    starts[1:] = case[1:] != case[:-1]
    seg = np.cumsum(starts, dtype=np.int64).astype(np.int32) - 1
    ends = np.zeros(n, bool)
    ends[:-1] = starts[1:]
    ends[-1] = True
    return starts, seg, ends


def _apply_tail_end(dfg: DFG, tail) -> DFG:
    if tail is None or not tail[2]:
        return dfg
    return DFG(dfg.counts, dfg.starts,
               dfg.ends.at[tail[1]].add(jnp.int32(1), mode="drop"))


def _finish_state(name: str, state, tail):
    """Host-side tail fix per distributed state (the stream's true last
    row is padding on-device; see module docstring)."""
    if name == "dfg":
        return _apply_tail_end(state, tail)
    if name == "discovery":
        return DiscoveryState(_apply_tail_end(state["dfg"], tail),
                              state["l2"])
    if name == "variants":
        return state            # no end-activity concept, nothing to fix
    raise KeyError(f"no distributed lowering named {name!r}; "
                   f"known: {sorted(SHARDED_STATES)}")


def query_sharded_multi(plan: "Plan | MultiPlan", states, num_activities: int,
                        mesh, axis_name: str = "data", *, prune: bool = True,
                        method: str = "auto", num_cases: int | None = None):
    """Mine every distributed state in ``states`` (distinct names from
    :data:`SHARDED_STATES`) from ONE gathered pruned stream and ONE
    ``shard_map``.  Returns ``({state_name: state}, ScanReport)`` — each
    state bitwise equal to its separate ``query_sharded_*`` run, with the
    event columns gathered and sharded exactly once however many verbs
    share the pass.  ``"variants"`` needs ``num_cases`` (its fingerprint
    table capacity) and yields ``(fp1, fp2, ncases)`` exactly like the
    streaming kernel's finalize."""
    states = tuple(dict.fromkeys(states))       # dedupe, keep order
    unknown = set(states) - SHARDED_STATES
    if not states or unknown:
        raise KeyError(f"distributed states must be a non-empty subset of "
                       f"{sorted(SHARDED_STATES)}; got {list(states)}")
    want_var = "variants" in states
    if want_var and num_cases is None:
        raise ValueError("states including 'variants' need num_cases= "
                         "(the fingerprint table capacity)")
    halo_states = tuple(s for s in states if s in STATE_DRIVERS)
    case, act, rv, maps, report = _gather(plan, prune, sketch=want_var)
    tail = (int(case[-1]), int(act[-1]), bool(rv[-1])) if case.size else None
    empty = case.size == 0
    n_dev = mesh.shape[axis_name]
    case, act, rv, maps = _pad_to_shards(case, act, rv, n_dev, maps)
    var_dev = want_var and num_cases > 0
    if want_var:
        starts, seg, ends = _segment_markers(case)
        ncases_seen = 0 if empty else int(seg[-1]) + 1
    kernel = engine.compose({s: STATE_DRIVERS[s][0](num_activities, method)
                             for s in halo_states}) if halo_states else None
    fix_ends = {s: STATE_DRIVERS[s][1] for s in halo_states}

    def local(case, act, valid, *var_args):
        out = {}
        if kernel is not None:
            out.update(run_sharded_composed(kernel, fix_ends, case, act,
                                            valid, axis_name=axis_name,
                                            n_dev=n_dev))
        if var_args:
            m1, b1, m2, b2, starts, seg, ends = var_args
            out["variants"] = run_sharded_variants(
                m1, b1, m2, b2, starts, seg, ends, num_cases,
                axis_name=axis_name, n_dev=n_dev)
        return out

    args = [jnp.asarray(case), jnp.asarray(act), jnp.asarray(rv)]
    if var_dev:
        args += [jnp.asarray(x) for x in (*maps, starts, seg, ends)]
    out = {}
    if kernel is not None or var_dev:
        fn = shard_map(local, mesh=mesh,
                       in_specs=(P(axis_name),) * len(args), out_specs=P())
        out = jax.jit(fn)(*args)
    result = {}
    for s in states:
        if s == "variants":
            fp1, fp2 = out.get("variants",
                               (jnp.zeros(0, jnp.uint32),) * 2)
            result[s] = (fp1, fp2,
                         jnp.int32(min(ncases_seen, num_cases)))
        else:
            result[s] = _finish_state(s, out[s], tail)
    return result, report


def merge_tree_sharded(plan: "Plan | MultiPlan", kernel, num_shards: int,
                       *, prune: bool = True, prefetch: int | None = None):
    """Shard a pruned scan as a merge tree over the group-state algebra.

    The classic drivers above shard with a ppermute halo + one ``psum`` —
    a lowering only states with hand-written distributed kernels have.
    With mergeable group states (``core.engine.GroupState``) the psum *is*
    a merge-tree instance: split the pruned chunk stream into
    ``num_shards`` contiguous spans, fold each span fresh (exactly what a
    shard's local pass computes), then ``merge_tree`` the span states and
    finalize once.  Every kernel with a ``stitch`` gains a sharded
    schedule this way — case sizes, durations, activity counts,
    eventually-follows — with no bespoke halo code, and the result stays
    bitwise equal to the streamed fold (the merge reconstructs it).

    Returns ``(result, ScanReport)``.
    """
    if not engine.mergeable(kernel):
        raise ValueError(f"kernel {kernel.name!r} defines no stitch — no "
                         f"merge-tree sharding (and no distributed state)")
    src, report = pruned_source(
        plan, prune=prune, mask_exact=getattr(kernel, "mask_exact", True),
        sketch=getattr(kernel, "ghost_sketch", False), prefetch=prefetch)
    chunks = [c for c in src if c.nrows]
    n = max(int(num_shards), 1)
    bounds = np.linspace(0, len(chunks), n + 1).round().astype(int)
    states = [engine.fold_group(kernel, chunks[lo:hi])
              for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]
    merged = engine.merge_tree(kernel, states)
    return engine.finalize_group(kernel, merged), report


def query_sharded_dfg(plan: "Plan | MultiPlan", num_activities: int, mesh,
                      axis_name: str = "data", *, prune: bool = True,
                      method: str = "auto"):
    """Full DFG of a filtered log, mined from the pruned scan sharded over
    ``axis_name``.  Returns ``(DFG, ScanReport)``; counts/starts/ends are
    bitwise equal to ``dfg(filter(read(path)))``."""
    out, report = query_sharded_multi(plan, ("dfg",), num_activities, mesh,
                                      axis_name, prune=prune, method=method)
    return out["dfg"], report


def query_sharded_discovery(plan: "Plan | MultiPlan", num_activities: int, mesh,
                            axis_name: str = "data", *, prune: bool = True,
                            method: str = "auto"):
    """DFG + L2-loop discovery state over the pruned, sharded scan
    (feeds ``discover_alpha`` / ``discover_heuristics`` host-side)."""
    out, report = query_sharded_multi(plan, ("discovery",), num_activities,
                                      mesh, axis_name, prune=prune,
                                      method=method)
    return out["discovery"], report


def query_sharded_dfg_host(plan: "Plan | MultiPlan", num_activities: int, num_shards: int,
                           **kw):
    """CPU-host validation path (virtual device mesh), as in
    ``distributed.dfg.dfg_sharded_host``."""
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return query_sharded_dfg(plan, num_activities, mesh, **kw)


def query_sharded_discovery_host(plan: "Plan | MultiPlan", num_activities: int,
                                 num_shards: int, **kw):
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return query_sharded_discovery(plan, num_activities, mesh, **kw)
