"""Distributed pruned scans: surviving row groups sharded over devices.

The query layer's pruned stream (``repro.query.exec.pruned_source``)
collapses zone-map-refuted row groups to O(segments) ghost rows; this
module concatenates that stream, splits it into equal contiguous shards
over the data axis, and reuses the ``distributed.dfg`` drivers verbatim —
one kernel update per shard, the boundary row recovered with a
``ppermute`` halo, the mergeable state combined with one ``psum``.  Ghost
rows ride along as ordinary all-masked rows, so the halo a shard hands to
its successor is exactly the carry the streaming path would have built,
and sharded == streamed == filter-then-mine, bitwise.

The one boundary the shards cannot resolve is the *stream's* final end
activity: the last physical row is padding (all-masked), so the trailing
end is re-applied host-side from the true tail row after the psum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.dfg import DFG, dfg_kernel
from repro.core.discovery import DiscoveryState, discovery_kernel
from repro.core.eventframe import ACTIVITY, CASE
from repro.query.exec import pruned_source
from repro.query.plan import MultiPlan, Plan

from .dfg import fix_trailing_end, run_sharded_kernel
from .discovery import _fix_end as fix_discovery_end


def _gather(plan: "Plan | MultiPlan", prune: bool):
    """Concatenate the pruned stream's (case, activity, rows_valid).

    Multi-file plans concatenate every file's pruned scan in path order
    (``repro.query.multi_pruned_source``), so the shards of a dataset-wide
    mine see one contiguous sorted log with ghost rows standing in for
    every skipped row group of every file.
    """
    src, report = pruned_source(plan.project((ACTIVITY, CASE)), prune=prune,
                                mask_exact=True)
    case_parts, act_parts, rv_parts = [], [], []
    for chunk in src:
        if chunk.nrows == 0:
            continue
        case_parts.append(np.asarray(chunk[CASE]))
        act_parts.append(np.asarray(chunk[ACTIVITY]))
        rv_parts.append(np.asarray(chunk.rows_valid(), bool))
    if not case_parts:
        z = np.zeros(0, np.int64)
        return z, z.astype(np.int32), np.zeros(0, bool), report
    return (np.concatenate(case_parts), np.concatenate(act_parts),
            np.concatenate(rv_parts), report)


def _pad_to_shards(case, act, rv, n_dev: int):
    """Pad with >= 1 all-masked copies of the last row so every shard is
    equally sized and the trailing end is *never* resolved on-device."""
    n = case.shape[0]
    if n == 0:
        case = np.zeros(1, np.int64)
        act = np.zeros(1, np.int32)
        rv = np.zeros(1, bool)
        n = 1
    pad = (-(n + 1)) % n_dev + 1
    case = np.concatenate([case, np.full(pad, case[-1], case.dtype)])
    act = np.concatenate([act, np.full(pad, act[-1], act.dtype)])
    rv = np.concatenate([rv, np.zeros(pad, bool)])
    return case, act, rv


def _run(kernel_factory, fix_end, plan, num_activities, mesh, axis_name,
         prune, method):
    case, act, rv, report = _gather(plan, prune)
    tail = (int(case[-1]), int(act[-1]), bool(rv[-1])) if case.size else None
    n_dev = mesh.shape[axis_name]
    case, act, rv = _pad_to_shards(case, act, rv, n_dev)
    kernel = kernel_factory(num_activities, method)

    def local(case, act, valid):
        return run_sharded_kernel(
            kernel, fix_end, case, act, valid, axis_name=axis_name,
            n_dev=n_dev, halo_depth=2 if "case2" in kernel.init()[1] else 1)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis_name), P(axis_name), P(axis_name)),
                   out_specs=P())
    state = jax.jit(fn)(jnp.asarray(case), jnp.asarray(act), jnp.asarray(rv))
    return state, tail, report


def _apply_tail_end(dfg: DFG, tail) -> DFG:
    if tail is None or not tail[2]:
        return dfg
    return DFG(dfg.counts, dfg.starts,
               dfg.ends.at[tail[1]].add(jnp.int32(1), mode="drop"))


def query_sharded_dfg(plan: "Plan | MultiPlan", num_activities: int, mesh,
                      axis_name: str = "data", *, prune: bool = True,
                      method: str = "auto"):
    """Full DFG of a filtered log, mined from the pruned scan sharded over
    ``axis_name``.  Returns ``(DFG, ScanReport)``; counts/starts/ends are
    bitwise equal to ``dfg(filter(read(path)))``."""
    state, tail, report = _run(dfg_kernel, fix_trailing_end, plan,
                               num_activities, mesh, axis_name, prune, method)
    return _apply_tail_end(state, tail), report


def query_sharded_discovery(plan: "Plan | MultiPlan", num_activities: int, mesh,
                            axis_name: str = "data", *, prune: bool = True,
                            method: str = "auto"):
    """DFG + L2-loop discovery state over the pruned, sharded scan
    (feeds ``discover_alpha`` / ``discover_heuristics`` host-side)."""
    state, tail, report = _run(discovery_kernel, fix_discovery_end, plan,
                               num_activities, mesh, axis_name, prune, method)
    return DiscoveryState(_apply_tail_end(state["dfg"], tail),
                          state["l2"]), report


def query_sharded_dfg_host(plan: "Plan | MultiPlan", num_activities: int, num_shards: int,
                           **kw):
    """CPU-host validation path (virtual device mesh), as in
    ``distributed.dfg.dfg_sharded_host``."""
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return query_sharded_dfg(plan, num_activities, mesh, **kw)


def query_sharded_discovery_host(plan: "Plan | MultiPlan", num_activities: int,
                                 num_shards: int, **kw):
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return query_sharded_discovery(plan, num_activities, mesh, **kw)
