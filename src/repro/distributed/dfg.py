"""Distributed DFG: the paper's map-reduce strategy as shard_map + psum.

Events are sharded over the data axes (columnar arrays cut into contiguous
ranges). Each shard runs the *local* shifting-and-counting (the §5.4 matmul
form), plus a one-row halo exchange: the pair that straddles a shard
boundary (last event of shard i, first event of shard i+1) is recovered with
a ``ppermute`` — the "shift" crossing the shard edge. The reduce phase is a
single psum of the (A, A) count matrix: the paper's Spark shuffle collapses
into one all-reduce whose payload is independent of N.

Complexity per device: O(N / devices) work, O(A^2) communication — compare
Table 4's O(N) single-node bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.eventframe import ACTIVITY, CASE, EventFrame


def _local_counts(case, act, valid, num_activities, axis_name):
    a = num_activities
    # halo: receive the (case, act, valid) of the *previous* shard's last row
    n_dev = jax.lax.axis_size(axis_name)
    perm = [(i, i + 1) for i in range(n_dev - 1)]
    prev_case = jax.lax.ppermute(case[-1:], axis_name, perm)
    prev_act = jax.lax.ppermute(act[-1:], axis_name, perm)
    prev_valid = jax.lax.ppermute(valid[-1:], axis_name, perm)
    idx = jax.lax.axis_index(axis_name)
    prev_valid = jnp.where(idx == 0, False, prev_valid[0])

    src = jnp.concatenate([prev_act, act[:-1]])
    src_case = jnp.concatenate([prev_case, case[:-1]])
    src_valid = jnp.concatenate([prev_valid[None], valid[:-1]])
    mask = (src_case == case) & src_valid & valid
    key = jnp.where(mask, src * a + act, a * a)
    flat = jnp.zeros((a * a + 1,), jnp.int32).at[key].add(1)
    counts = flat[:-1].reshape(a, a)
    return jax.lax.psum(counts, axis_name)


def dfg_sharded(frame: EventFrame, num_activities: int, mesh,
                axis_name: str = "data"):
    """Compute the DFG of a (case,time)-sorted frame sharded over ``axis_name``."""
    fn = shard_map(
        functools.partial(_local_counts, num_activities=num_activities,
                          axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(),
    )
    return jax.jit(fn)(frame[CASE], frame[ACTIVITY], frame.rows_valid())


def dfg_sharded_host(frame: EventFrame, num_activities: int, num_shards: int):
    """CPU-host validation path: shard on a host mesh of virtual devices."""
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return dfg_sharded(frame, num_activities, mesh)
