"""Distributed DFG: the streaming chunk-kernel with ``psum`` as its merge.

Events are sharded over the data axis (columnar arrays cut into contiguous
ranges). Each shard runs the *same* ``core.dfg.dfg_kernel`` update that the
single-shot and out-of-core paths use; the one-row halo that stitches the
pair straddling a shard boundary is exactly the kernel's carry, recovered
with a single ``ppermute`` (last row of shard i becomes shard i+1's carry).
The reduce phase merges the per-shard states with one psum of the (A, A)
count matrix (+ two (A,) histograms): the paper's Spark shuffle collapses
into one all-reduce whose payload is independent of N.

There is no bespoke halo code here any more — carry construction and
boundary semantics live in ``core.engine`` and are shared verbatim with the
streaming engine, so sharded == streamed == single-shot, bitwise.

Complexity per device: O(N / devices) work, O(A^2) communication — compare
Table 4's O(N) single-node bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.dfg import DFG, dfg_kernel
from repro.core.eventframe import ACTIVITY, CASE, EventFrame


def shard_halo_carry(carry: dict, case, act, valid, *, axis_name, n_dev,
                     depth: int = 1) -> dict:
    """Recover the previous shard's last ``depth`` rows as this shard's
    carry, one ppermute per column; shard 0 keeps the kernel's init carry
    (its exists flags are False and mask everything).  ``depth=2`` also
    fills the two-back halo keys of ``discovery_kernel`` carries."""
    perm = [(i, i + 1) for i in range(n_dev - 1)]
    tail_case = jax.lax.ppermute(case[-depth:], axis_name, perm)
    tail_act = jax.lax.ppermute(act[-depth:], axis_name, perm)
    tail_valid = jax.lax.ppermute(valid[-depth:], axis_name, perm)
    exists = jax.lax.axis_index(axis_name) > 0
    carry = dict(carry,
                 case=tail_case[-1].astype(jnp.int32),
                 act=tail_act[-1].astype(jnp.int32),
                 rv=tail_valid[-1],
                 exists=exists)
    if depth >= 2:
        carry.update(case2=tail_case[-2].astype(jnp.int32),
                     act2=tail_act[-2].astype(jnp.int32),
                     rv2=tail_valid[-2],
                     exists2=exists)
    return carry


def fix_trailing_end(state: DFG, carry: dict, last_end) -> DFG:
    """Resolve the stream's final end activity on the shard that owns it
    (every other shard's trailing end is resolved by its successor)."""
    return DFG(state.counts, state.starts,
               state.ends.at[carry["act"]].add(last_end, mode="drop"))


def run_sharded_kernel(kernel, fix_end, case, act, valid, *, axis_name,
                       n_dev, halo_depth: int = 1):
    """Shard-local driver shared by the DFG and discovery lowerings:
    init, ppermute halo carry, one kernel update, last-shard end fix,
    psum merge.  Every shard must hold >= ``halo_depth`` rows — shard
    sizes are static at trace time, so violating it (a tiny frame on a
    wide mesh) raises here instead of silently clamping the halo index."""
    if case.shape[0] < halo_depth:
        raise ValueError(
            f"{kernel.name}: {case.shape[0]} row(s) per shard < halo depth "
            f"{halo_depth}; use fewer shards or a larger frame")
    state, carry = kernel.init()
    carry = shard_halo_carry(carry, case, act, valid, axis_name=axis_name,
                             n_dev=n_dev, depth=halo_depth)
    chunk = EventFrame({CASE: case, ACTIVITY: act}, {}, valid)
    state, carry = kernel.update(state, carry, chunk)
    is_last = jax.lax.axis_index(axis_name) == n_dev - 1
    last_end = (is_last & carry["rv"]).astype(jnp.int32)
    state = fix_end(state, carry, last_end)
    # merge == psum of the mergeable state, leaf by leaf
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), state)


def run_sharded_composed(kernel, fix_ends: dict, case, act, valid, *,
                         axis_name, n_dev):
    """Fused multi-state twin of :func:`run_sharded_kernel` for a
    ``core.engine.compose`` kernel: per-member ppermute halo (each member
    at *its* depth — the composed carry is a dict of member carries, so
    the top-level driver cannot use one depth for all), ONE composed
    update over the shard, per-member end fix, one leafwise psum.  Every
    distinct mergeable state crosses the wire once; the event columns
    cross zero extra times."""
    state, carry = kernel.init()
    depths = {m: (2 if "case2" in c else 1) for m, c in carry.items()}
    deepest = max(depths.values())
    if case.shape[0] < deepest:
        raise ValueError(
            f"{kernel.name}: {case.shape[0]} row(s) per shard < halo depth "
            f"{deepest}; use fewer shards or a larger frame")
    halo = {m: shard_halo_carry(c, case, act, valid, axis_name=axis_name,
                                n_dev=n_dev, depth=depths[m])
            for m, c in carry.items()}
    chunk = EventFrame({CASE: case, ACTIVITY: act}, {}, valid)
    state, carry = kernel.update(state, halo, chunk)
    is_last = jax.lax.axis_index(axis_name) == n_dev - 1
    state = {m: fix_ends[m](state[m], carry[m],
                            (is_last & carry[m]["rv"]).astype(jnp.int32))
             for m in state}
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), state)


def _local_state(case, act, valid, *, num_activities, axis_name, n_dev):
    return run_sharded_kernel(dfg_kernel(num_activities), fix_trailing_end,
                              case, act, valid, axis_name=axis_name,
                              n_dev=n_dev)


def dfg_sharded(frame: EventFrame, num_activities: int, mesh,
                axis_name: str = "data") -> DFG:
    """Full DFG (counts + start/end histograms) of a (case,time)-sorted
    frame sharded over ``axis_name``; replicated on every shard."""
    fn = shard_map(
        functools.partial(_local_state, num_activities=num_activities,
                          axis_name=axis_name, n_dev=mesh.shape[axis_name]),
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(),
    )
    return jax.jit(fn)(frame[CASE], frame[ACTIVITY], frame.rows_valid())


def dfg_sharded_host(frame: EventFrame, num_activities: int, num_shards: int) -> DFG:
    """CPU-host validation path: shard on a host mesh of virtual devices."""
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return dfg_sharded(frame, num_activities, mesh)
