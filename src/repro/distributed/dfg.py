"""Distributed DFG: the streaming chunk-kernel with ``psum`` as its merge.

Events are sharded over the data axis (columnar arrays cut into contiguous
ranges). Each shard runs the *same* ``core.dfg.dfg_kernel`` update that the
single-shot and out-of-core paths use; the one-row halo that stitches the
pair straddling a shard boundary is exactly the kernel's carry, recovered
with a single ``ppermute`` (last row of shard i becomes shard i+1's carry).
The reduce phase merges the per-shard states with one psum of the (A, A)
count matrix (+ two (A,) histograms): the paper's Spark shuffle collapses
into one all-reduce whose payload is independent of N.

There is no bespoke halo code here any more — carry construction and
boundary semantics live in ``core.engine`` and are shared verbatim with the
streaming engine, so sharded == streamed == single-shot, bitwise.

Complexity per device: O(N / devices) work, O(A^2) communication — compare
Table 4's O(N) single-node bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.dfg import DFG, dfg_kernel
from repro.core.eventframe import ACTIVITY, CASE, EventFrame


def _local_state(case, act, valid, *, num_activities, axis_name, n_dev):
    kernel = dfg_kernel(num_activities)
    state, carry = kernel.init()

    # carry = the previous shard's last row, via one ppermute; shard 0 keeps
    # the kernel's init carry (exists=False masks everything).
    perm = [(i, i + 1) for i in range(n_dev - 1)]
    prev_case = jax.lax.ppermute(case[-1:], axis_name, perm)[0]
    prev_act = jax.lax.ppermute(act[-1:], axis_name, perm)[0]
    prev_valid = jax.lax.ppermute(valid[-1:], axis_name, perm)[0]
    idx = jax.lax.axis_index(axis_name)
    carry = dict(carry,
                 case=prev_case.astype(jnp.int32),
                 act=prev_act.astype(jnp.int32),
                 rv=prev_valid,
                 exists=idx > 0)

    chunk = EventFrame({CASE: case, ACTIVITY: act}, {}, valid)
    state, carry = kernel.update(state, carry, chunk)

    # every shard's trailing end is resolved by its successor's update; the
    # global last row has no successor, so the last shard finalizes it.
    is_last = idx == n_dev - 1
    last_end = (is_last & carry["rv"]).astype(jnp.int32)
    state = DFG(state.counts, state.starts,
                state.ends.at[carry["act"]].add(last_end, mode="drop"))

    # merge == psum of the mergeable state, leaf by leaf
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), state)


def dfg_sharded(frame: EventFrame, num_activities: int, mesh,
                axis_name: str = "data") -> DFG:
    """Full DFG (counts + start/end histograms) of a (case,time)-sorted
    frame sharded over ``axis_name``; replicated on every shard."""
    fn = shard_map(
        functools.partial(_local_state, num_activities=num_activities,
                          axis_name=axis_name, n_dev=mesh.shape[axis_name]),
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(),
    )
    return jax.jit(fn)(frame[CASE], frame[ACTIVITY], frame.rows_valid())


def dfg_sharded_host(frame: EventFrame, num_activities: int, num_shards: int) -> DFG:
    """CPU-host validation path: shard on a host mesh of virtual devices."""
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return dfg_sharded(frame, num_activities, mesh)
