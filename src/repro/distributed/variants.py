"""Distributed variants: affine hash maps sharded over the data axis.

The rolling variant hash is a left fold, which looks sequential — but
every row of the stream is an *affine map* ``h -> h*m + b`` over uint32
(real rows: ``(BASE, act+1)``; ghost rows from pruned scans: the
composed per-segment sketch maps of ``core.polyhash``; padding rows:
the identity).  Affine maps compose associatively, so the fold shards:

1. each shard runs the segmented affine scan twice, seeded with ``h=0``
   and ``h=1`` — the two evaluations of an affine function recover its
   coefficients, ``ys(h) = mr*h + ys0`` with ``mr = ys1 - ys0`` (``mr``
   self-zeroes at the first segment restart inside the shard, because
   the restart severs the dependence on the incoming carry);
2. one ``all_gather`` of each shard's whole-shard map
   ``(mr[-1], ys0[-1])`` (payload: 2 uint32 per shard per base) and an
   O(shards) fold give every shard its true incoming carry — no halo
   depth constraint, any shard may hold less than a case;
3. per-row hashes ``mr*h_in + ys0``; each case's hash at its end row is
   scattered by global segment id (``segment_reduce``) and one ``psum``
   assembles the replicated fingerprint table (every end row lives on
   exactly one shard, so the sum has one nonzero contribution per case).

Bitwise equal to the streaming ``variants_kernel`` and the whole-log
``variant_fingerprints``: uint32 arithmetic is exact mod 2^32 under both
backends, and the composition order is the stream order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops import segment_reduce, segmented_affine


def _base_fingerprints(m, b, starts, seg, ends, num_cases, *, axis_name,
                       n_dev):
    ys0, _ = segmented_affine(m, b, starts, jnp.uint32(0))
    ys1, _ = segmented_affine(m, b, starts, jnp.uint32(1))
    mr = ys1 - ys0              # shard-prefix map slope (0 after a restart)
    gather = jax.lax.all_gather(jnp.stack([mr[-1], ys0[-1]]), axis_name)
    idx = jax.lax.axis_index(axis_name)

    def fold(h, i):             # compose the preceding shards' maps, in order
        return jnp.where(i < idx, h * gather[i, 0] + gather[i, 1], h), None

    h_in, _ = jax.lax.scan(fold, jnp.uint32(0), jnp.arange(n_dev))
    hs = mr * h_in + ys0        # exact per-row hashes given the true carry
    fp = segment_reduce(jnp.where(ends, hs, jnp.uint32(0)), seg, num_cases,
                        "max")
    return jax.lax.psum(fp, axis_name)


def run_sharded_variants(m1, b1, m2, b2, starts, seg, ends, num_cases: int,
                         *, axis_name, n_dev):
    """Shard-local driver: per-case ``(fp1, fp2)`` fingerprint tables,
    replicated.  ``starts``/``seg``/``ends`` are the *global* segment
    markers (host-derived from the padded case column) sliced per shard."""
    fp1 = _base_fingerprints(m1, b1, starts, seg, ends, num_cases,
                             axis_name=axis_name, n_dev=n_dev)
    fp2 = _base_fingerprints(m2, b2, starts, seg, ends, num_cases,
                             axis_name=axis_name, n_dev=n_dev)
    return fp1, fp2
