"""Distributed sort-by-case: all-to-all bucket exchange.

The paper's shifting-and-counting *assumes the dataframe is sorted by case
id*. At cluster scale the log arrives time-ordered and distributed, so the
sort itself must be distributed: each shard buckets its events by
``hash(case) % n_shards``, an all_to_all exchanges buckets (each case lands
wholly on one shard), and a local lexsort finishes. This is the classic
"exchange + local sort" — one collective pass, O(N/p log N/p) local work.

Static-shape constraint (TPU): bucket capacity is ``cap = ceil(N/p * slack)``
per (src, dst) pair; overflow is detected and reported (slack=2 default).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame


def _exchange(case, act, ts, *, n_shards, cap, axis_name):
    tgt = case % n_shards                                   # destination shard
    # position of each row within its destination bucket
    onehot = jax.nn.one_hot(tgt, n_shards, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)
    slot = jnp.take_along_axis(pos, tgt[:, None], axis=1)[:, 0]
    overflow = jax.lax.pmax((slot >= cap).any().astype(jnp.int32), axis_name)
    slot = jnp.minimum(slot, cap - 1)

    def bucketize(x, fill):
        buf = jnp.full((n_shards, cap), fill, x.dtype)
        return buf.at[tgt, slot].set(x, mode="drop")

    bc = bucketize(case, -1)
    ba = bucketize(act, -1)
    bt = bucketize(ts, jnp.inf)
    # exchange: row i of my buffer goes to shard i
    bc = jax.lax.all_to_all(bc, axis_name, 0, 0, tiled=False)
    ba = jax.lax.all_to_all(ba, axis_name, 0, 0, tiled=False)
    bt = jax.lax.all_to_all(bt, axis_name, 0, 0, tiled=False)
    cc = bc.reshape(-1)
    aa = ba.reshape(-1)
    tt = bt.reshape(-1)
    order = jnp.lexsort((tt, cc))                           # case major, ts minor
    return cc[order], aa[order], tt[order], overflow


def sort_by_case_sharded(frame: EventFrame, mesh, axis_name: str = "data",
                         slack: float = 2.0):
    """Returns per-shard (case, act, ts) case-sorted arrays + overflow flag.

    Invalid slots carry case == -1 and sort to the front; downstream DFG
    treats them as non-matching (distinct sentinel per position not needed —
    they never equal a real case id and the -1 run only pairs within itself,
    contributing to bucket (a*A+a) only if act==-1 which is filtered)."""
    n = frame.nrows
    n_shards = mesh.shape[axis_name]
    local = n // n_shards
    cap = int(local * slack / n_shards + 1)

    fn = shard_map(
        functools.partial(_exchange, n_shards=n_shards, cap=cap,
                          axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name), P(axis_name), P()),
    )
    case = frame[CASE].astype(jnp.int32)
    act = frame[ACTIVITY].astype(jnp.int32)
    ts = frame[TIMESTAMP].astype(jnp.float32)
    return jax.jit(fn)(case, act, ts)
