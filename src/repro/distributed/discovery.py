"""Distributed discovery: the discovery chunk-kernel with ``psum`` merge.

Same shape as ``distributed.dfg`` — literally: both lowerings run through
``distributed.dfg.run_sharded_kernel`` (init, ppermute halo carry, one
kernel update per shard, last-shard end fix, psum merge).  The only
variation here is the halo depth: L2-loop triples (``a, b, a``) can
straddle a shard boundary by *two* rows, so the carry is recovered from
each shard's last two rows instead of one.  The miners themselves
(``discover_alpha`` / ``discover_heuristics``) run on the merged state —
they are pure finalize and never see events.

Precondition: every shard holds at least two rows (pad the frame, as the
data-sharding helpers already do for alignment).
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.discovery import (AlphaModel, DiscoveryState, HeuristicsNet,
                                  discover_alpha, discover_heuristics,
                                  discovery_kernel)
from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from .dfg import fix_trailing_end, run_sharded_kernel


def _fix_end(state, carry, last_end):
    return {"dfg": fix_trailing_end(state["dfg"], carry, last_end),
            "l2": state["l2"]}


def _local_state(case, act, valid, *, num_activities, axis_name, n_dev):
    return run_sharded_kernel(discovery_kernel(num_activities), _fix_end,
                              case, act, valid, axis_name=axis_name,
                              n_dev=n_dev, halo_depth=2)


def discovery_state_sharded(frame: EventFrame, num_activities: int, mesh,
                            axis_name: str = "data") -> DiscoveryState:
    """DFG + L2 counts of a (case,time)-sorted frame sharded over
    ``axis_name``; replicated on every shard."""
    fn = shard_map(
        functools.partial(_local_state, num_activities=num_activities,
                          axis_name=axis_name, n_dev=mesh.shape[axis_name]),
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(),
    )
    out = jax.jit(fn)(frame[CASE], frame[ACTIVITY], frame.rows_valid())
    return DiscoveryState(out["dfg"], out["l2"])


def alpha_sharded(frame: EventFrame, num_activities: int, mesh,
                  axis_name: str = "data", min_count: int = 1) -> AlphaModel:
    """Distributed alpha miner: psum-merged DFG state + host finalize."""
    state = discovery_state_sharded(frame, num_activities, mesh, axis_name)
    return discover_alpha(state.dfg, min_count)


def heuristics_sharded(frame: EventFrame, num_activities: int, mesh,
                       axis_name: str = "data", **thresholds) -> HeuristicsNet:
    """Distributed heuristics miner: psum-merged state + dense finalize."""
    state = discovery_state_sharded(frame, num_activities, mesh, axis_name)
    return discover_heuristics(state, **thresholds)


def discovery_state_sharded_host(frame: EventFrame, num_activities: int,
                                 num_shards: int) -> DiscoveryState:
    """CPU-host validation path: shard on a host mesh of virtual devices."""
    devs = jax.devices()[:num_shards]
    mesh = jax.sharding.Mesh(devs, ("data",))
    return discovery_state_sharded(frame, num_activities, mesh)
