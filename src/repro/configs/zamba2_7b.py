"""Zamba2-7B: Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14_336, vocab_size=32_000,
    ssm_state=64, ssm_expand=2, ssm_chunk=128, shared_attn_every=6,
)
