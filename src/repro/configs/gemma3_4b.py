"""Gemma3-4B: 5:1 local(1024):global interleave, 262k vocab, tied embeddings
[hf:google/gemma-3-4b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10_240, vocab_size=262_144,
    local_window=1024, global_every=6,
    rope_theta=10_000.0, global_rope_theta=1_000_000.0,
    tie_embeddings=True,
)
