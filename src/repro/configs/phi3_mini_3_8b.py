"""Phi3-mini-3.8B: RoPE SwiGLU MHA [arXiv:2404.14219]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8_192, vocab_size=32_064,
)
