"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig
from .shapes import SHAPES, LONG_CONTEXT_OK, Shape, cells

_ARCHS = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "zamba2-7b": "zamba2_7b",
    "gemma3-4b": "gemma3_4b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "deepseek-67b": "deepseek_67b",
    "yi-6b": "yi_6b",
    "whisper-medium": "whisper_medium",
    "internvl2-2b": "internvl2_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "eventlm-100m": "eventlm_100m",
}

ARCH_IDS = tuple(k for k in _ARCHS if k != "eventlm-100m")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
    return mod.CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving shrink for CPU smoke tests."""
    kw = dict(
        num_layers=max(4, (cfg.global_every or cfg.shared_attn_every or
                           cfg.slstm_every or 2) * 2),
        d_model=64, num_heads=4, num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16, d_ff=128, vocab_size=128,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_chunk=16)
    if cfg.family == "ssm":
        kw.update(num_heads=4, num_kv_heads=4, head_dim=16, d_ff=0, ssm_chunk=16)
        kw["num_layers"] = 2 * cfg.slstm_every
    if cfg.enc_layers:
        kw.update(enc_layers=2, enc_seq=16)
    if cfg.num_patches:
        kw.update(num_patches=4)
    if cfg.local_window:
        kw.update(local_window=8)
    if cfg.window:
        kw.update(window=8)
    kw.update(compute_dtype="float32", param_dtype="float32", attn_chunk=32)
    return cfg.with_overrides(**kw)
