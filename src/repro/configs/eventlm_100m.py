"""EventLM-100M: the paper-side model — a ~100M dense LM trained on
next-activity prediction over EventFrame token streams (examples/train)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="eventlm-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3_072, vocab_size=4_096,
)
