"""InternVL2-2B: InternViT frontend is a STUB (precomputed patch embeddings);
backbone = InternLM2-2B [arXiv:2404.16821]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8_192, vocab_size=92_553,
    num_patches=256,
)
