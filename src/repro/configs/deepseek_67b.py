"""DeepSeek-67B: llama-arch GQA, 95 layers (deepest) [arXiv:2401.02954]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22_016, vocab_size=102_400,
)
