"""Whisper-medium: enc-dec; conv frontend is a STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4_096, vocab_size=51_865,
    enc_layers=24, enc_seq=1500,
)
