"""xLSTM-1.3B: 48 blocks in 6 groups of (7 mLSTM + 1 sLSTM) [arXiv:2405.04517]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4, head_dim=512,
    d_ff=0, vocab_size=50_304,
    slstm_every=8, ssm_chunk=128,
)
