"""Mixtral-8x7B: 8-expert top-2 MoE with SWA-4096 [arXiv:2401.04088].

8 experts < 16-way model axis => expert weights are TP-sharded on d_ff
(experts replicated), see DESIGN.md §4."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14_336, moe_d_ff=14_336, vocab_size=32_000,
    num_experts=8, num_experts_per_tok=2,
    window=4_096, rope_theta=1_000_000.0,
)
