"""Assigned input shapes and per-arch applicability (see DESIGN.md §4)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# long_500k needs sub-quadratic attention: SSM / hybrid / windowed archs only.
LONG_CONTEXT_OK = {"mixtral-8x7b", "zamba2-7b", "gemma3-4b", "xlstm-1.3b"}


def cells(arch: str):
    """Runnable (arch, shape) cells; documented skips excluded."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_OK:
        out.append("long_500k")
    return out
