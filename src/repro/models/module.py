"""Param-pytree module plumbing: one structural definition, three readings.

Model code builds parameters through a ``Creator``. Interpreting the same
structure with different creators yields:

* ``Initializer``    — real arrays (truncated-normal fan-in init),
* ``SpecCreator``    — a matching pytree of ``PartitionSpec`` (sharding rules),
* ``AbstractCreator``— ``ShapeDtypeStruct`` stand-ins (dry-run, no allocation).

Logical axes name *what* a dimension is; ``ShardingRules`` maps logical axes
to mesh axes. This is the MaxText "logical axis rules" pattern distilled.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Logical axis vocabulary.
#   "embed"  — the residual/d_model dim (FSDP-sharded)
#   "vocab"  — vocabulary dim (TP-sharded: big softmaxes)
#   "heads"  — flattened attention heads*head_dim dim (TP)
#   "mlp"    — feed-forward hidden dim (TP)
#   "expert" — MoE expert dim (EP)
#   "layers" — scan-stacked layer dim (never sharded)
#   None     — replicated


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    embed: Any = "data"
    vocab: Any = "model"
    heads: Any = "model"
    mlp: Any = "model"
    expert: Any = "model"
    layers: Any = None
    seq: Any = None          # activation seq dim (SP when = "model")
    batch: Any = ("pod", "data")

    def spec(self, axes: tuple[str | None, ...]) -> P:
        return P(*[getattr(self, a) if a else None for a in axes])


# Baseline rule sets used by the configs.
RULES_2D = ShardingRules()                                # (data, model) pod-less
RULES_EP = ShardingRules()                                # expert -> model (qwen3)
RULES_TP_FF = ShardingRules(expert=None)                  # mixtral: experts replicated, mlp TP


class Creator:
    def __call__(self, name, shape, axes, dtype, scale): ...


class Initializer(Creator):
    """Materializes truncated-normal params (fan-in scaled)."""

    def __init__(self, rng: jax.Array, dtype: str = "float32"):
        self.rng = rng
        self.dtype = dtype
        self._i = 0

    def __call__(self, name, shape, axes, dtype=None, scale=None):
        self._i += 1
        key = jax.random.fold_in(self.rng, self._i)
        dtype = dtype or self.dtype
        if scale == "zeros":
            return jnp.zeros(shape, dtype)
        if scale == "ones":
            return jnp.ones(shape, dtype)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = (1.0 / max(fan_in, 1)) ** 0.5 if scale is None else scale
        return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(dtype)


class SpecCreator(Creator):
    """Yields PartitionSpec leaves from the logical axes."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __call__(self, name, shape, axes, dtype=None, scale=None):
        assert len(axes) == len(shape), (name, shape, axes)
        return self.rules.spec(axes)


class AbstractCreator(Creator):
    """Yields ShapeDtypeStructs (no device allocation — dry-run params)."""

    def __init__(self, dtype: str = "float32"):
        self.dtype = dtype

    def __call__(self, name, shape, axes, dtype=None, scale=None):
        return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype or self.dtype))


def stack_init(creator: Creator, n: int, init_fn):
    """Build scan-stacked params: leading 'layers' dim on every leaf.

    ``init_fn(sub_creator) -> params`` defines ONE layer; we re-interpret it
    with a creator that prepends the layer axis. For the Initializer we still
    materialize layers independently (vmapped fold-in) to decorrelate.
    """
    class _Stacked(Creator):
        def __call__(self, name, shape, axes, dtype=None, scale=None):
            return creator(name, (n, *shape), ("layers", *axes), dtype, scale)

    return init_fn(_Stacked())


def cast_leaves(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)
