"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, GQA attention block, MoE."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import attention, attention_decode
from .config import ModelConfig
from .module import Creator


# ----------------------------------------------------------------- basics
def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: (..., S, H, D). Rotates pairs (d, d + D/2)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- MLP / MoE
def mlp_init(c: Creator, cfg: ModelConfig, d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "gate": c("mlp.gate", (D, F), ("embed", "mlp")),
        "up": c("mlp.up", (D, F), ("embed", "mlp")),
        "down": c("mlp.down", (F, D), ("mlp", "embed")),
    }


def mlp_apply(p, x, compute_dtype):
    x = x.astype(compute_dtype)
    g = jnp.einsum("bsd,df->bsf", x, p["gate"].astype(compute_dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["down"].astype(compute_dtype))


def moe_init(c: Creator, cfg: ModelConfig):
    D, E = cfg.d_model, cfg.num_experts
    F = cfg.moe_d_ff or cfg.d_ff
    return {
        "router": c("moe.router", (D, E), ("embed", None)),
        "gate": c("moe.gate", (E, D, F), ("expert", "embed", "mlp")),
        "up": c("moe.up", (E, D, F), ("expert", "embed", "mlp")),
        "down": c("moe.down", (E, F, D), ("expert", "mlp", "embed")),
    }


def moe_apply(p, x, cfg: ModelConfig, rules):
    """MoE front door: dense GSPMD dispatch or explicit shard_map EP."""
    if cfg.moe_impl == "shard_map":
        from .moe_ep import moe_apply_ep
        return moe_apply_ep(p, x, cfg, rules)
    return moe_apply_dense(p, x, cfg, rules)


def moe_apply_dense(p, x, cfg: ModelConfig, rules):
    """Capacity-bounded scatter dispatch (GSPMD-friendly, static shapes).

    tokens are flattened to (T, D), routed top-k, scattered into an
    (E, C, D) buffer (C = capacity), expert-matmul'd as one batched einsum
    over the expert dim (EP-sharded), and combined back with the router
    weights. Overflowing tokens are dropped (standard capacity-factor MoE).
    """
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    F = cfg.moe_d_ff or cfg.d_ff
    T = b * s
    C = max(8, int(cfg.capacity_factor * T * K / E))
    xt = x.reshape(T, D).astype(dt)

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(dt)).astype(jnp.float32)
    gates, idx = jax.lax.top_k(logits, K)                  # (T, K)
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = idx.reshape(-1)                               # (T*K,)
    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (T*K, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)            # exclusive count
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C
    slot = jnp.where(keep, slot, C - 1)

    src = jnp.repeat(jnp.arange(T), K)
    disp = jnp.zeros((E, C, D), dt)
    disp = disp.at[flat_e, slot].add(
        jnp.where(keep[:, None], xt[src], 0).astype(dt), mode="drop")
    from .transformer import maybe_constrain
    # capacity dim shards over the batch axes: keeps the (E, C, D) dispatch
    # buffer O(tokens/device) even when experts are replicated (mixtral)
    disp = maybe_constrain(disp, P(rules.expert, rules.batch, None))

    g = jnp.einsum("ecd,edf->ecf", disp, p["gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", disp, p["up"].astype(dt))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(dt))
    out = maybe_constrain(out, P(rules.expert, rules.batch, None))

    gathered = out[flat_e, slot]                           # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gates.reshape(-1)[:, None].astype(dt)
    combined = jnp.zeros((T, D), dt).at[src].add(gathered * w)
    return combined.reshape(b, s, D)


# ------------------------------------------------------- attention block
def attn_init(c: Creator, cfg: ModelConfig, prefix="attn"):
    D = cfg.d_model
    return {
        "wq": c(f"{prefix}.wq", (D, cfg.q_dim), ("embed", "heads")),
        "wk": c(f"{prefix}.wk", (D, cfg.kv_dim), ("embed", "heads")),
        "wv": c(f"{prefix}.wv", (D, cfg.kv_dim), ("embed", "heads")),
        "wo": c(f"{prefix}.wo", (cfg.q_dim, D), ("heads", "embed")),
    }


def attn_qkv(p, x, cfg: ModelConfig, positions, theta):
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    x = x.astype(dt)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt)).reshape(b, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt)).reshape(b, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt)).reshape(b, s, cfg.num_kv_heads, hd)
    if theta is not None:   # theta may be traced (per-layer kind selection)
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, *, positions, theta, causal=True,
               window=None, kv_len=None, collect=False):
    q, k, v = attn_qkv(p, x, cfg, positions, theta)
    pdt = None if cfg.attn_p_dtype == "float32" else jnp.dtype(cfg.attn_p_dtype)
    o = attention(q, k, v, impl=cfg.attn_impl, causal=causal, window=window,
                  kv_len=kv_len, chunk=cfg.attn_chunk, p_dtype=pdt)
    b, s, _, _ = o.shape
    dt = jnp.dtype(cfg.compute_dtype)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), p["wo"].astype(dt))
    if collect:
        return out, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
    return out


def attn_apply_cross(p, x, enc_h, cfg: ModelConfig, kv: tuple | None = None):
    """Cross attention: queries from x, keys/values from encoder output
    (or a precomputed (k, v) pair during decode). No RoPE, not causal."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x.astype(dt), p["wq"].astype(dt)).reshape(
        b, s, cfg.num_heads, hd)
    if kv is None:
        k = jnp.einsum("bsd,dh->bsh", enc_h.astype(dt), p["wk"].astype(dt)).reshape(
            b, -1, cfg.num_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", enc_h.astype(dt), p["wv"].astype(dt)).reshape(
            b, -1, cfg.num_kv_heads, hd)
    else:
        k, v = kv
    o = attention(q, k, v, impl=cfg.attn_impl, causal=False, window=None,
                  chunk=cfg.attn_chunk)
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), p["wo"].astype(dt))


def attn_decode_apply(p, x, cfg: ModelConfig, cache_k, cache_v, pos, *,
                      theta, window=None):
    """One-token decode against a (B, S, KVH, hd) cache; returns new kv too."""
    q, k, v = attn_qkv(p, x, cfg, pos[:, None], theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos[0], axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos[0], axis=1)
    o = attention_decode(q, cache_k, cache_v, pos[0] + 1, window=window)
    b = x.shape[0]
    dt = jnp.dtype(cfg.compute_dtype)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1), p["wo"].astype(dt))
    return out, cache_k, cache_v
