"""Unified model API: init / forward / prefill / decode for all families.

Every entry point is a pure function of (cfg, params, inputs) so the same
code path serves real training (Initializer params), sharding-spec derivation
(SpecCreator), and the 512-device dry-run (AbstractCreator + jit.lower).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from . import mamba2 as M
from . import xlstm as X
from .attention import NO_WINDOW
from .config import ModelConfig
from .module import AbstractCreator, Creator, Initializer, ShardingRules, stack_init
from .transformer import (block_apply, block_decode, block_init,
                          hybrid_block_init, shared_attn_init,
                          xlstm_group_init, _remat, _constrain)

# =========================================================== param building

def init_params(cfg: ModelConfig, creator: Creator):
    D, V = cfg.d_model, cfg.vocab_size
    p: dict[str, Any] = {
        "embed": creator("embed", (V, D), ("vocab", "embed"), scale=1.0),
        "final_norm": creator("final_norm", (D,), (None,), scale="zeros"),
    }
    if not cfg.tie_embeddings:
        p["head"] = creator("head", (D, V), ("embed", "vocab"))

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        p["layers"] = stack_init(creator, cfg.num_layers,
                                 lambda c: block_init(c, cfg))
    elif fam == "hybrid":
        p["layers"] = stack_init(creator, cfg.num_layers,
                                 lambda c: hybrid_block_init(c, cfg))
        p["shared"] = shared_attn_init(creator, cfg)
    elif fam == "ssm":
        G = cfg.num_layers // cfg.slstm_every
        p["groups"] = stack_init(creator, G,
                                 lambda c: xlstm_group_init(c, cfg))
    elif fam == "audio":
        p["enc_layers"] = stack_init(creator, cfg.enc_layers,
                                     lambda c: block_init(c, cfg))
        p["enc_norm"] = creator("enc_norm", (D,), (None,), scale="zeros")
        p["layers"] = stack_init(creator, cfg.num_layers,
                                 lambda c: _dec_block_init(c, cfg))
    else:
        raise ValueError(fam)
    return p


def _dec_block_init(c: Creator, cfg: ModelConfig):
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    return {
        "ln1": c("ln1", (cfg.d_model,), (None,), scale="zeros"),
        "attn": L.attn_init(c, cfg),
        "lnx": c("lnx", (cfg.d_model,), (None,), scale="zeros"),
        "xattn": L.attn_init(c, cfg, prefix="xattn"),
        "ln2": c("ln2", (cfg.d_model,), (None,), scale="zeros"),
        "mlp": L.mlp_init(c, cfg),
    }


def param_specs(cfg: ModelConfig, rules: ShardingRules):
    from .module import SpecCreator
    return init_params(cfg, SpecCreator(rules))


def abstract_params(cfg: ModelConfig):
    return init_params(cfg, AbstractCreator(cfg.param_dtype))


# ============================================================= forward paths

def _embed(cfg, params, tokens):
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embed"].astype(dt)[tokens] * jnp.asarray(
        cfg.d_model ** 0.5, dt)
    return h


def _head(cfg, params, h):
    dt = jnp.dtype(cfg.compute_dtype)
    h = L.rmsnorm(h, params["final_norm"])
    w = (params["embed"].T if cfg.tie_embeddings else params["head"]).astype(dt)
    return jnp.einsum("bsd,dv->bsv", h.astype(dt), w).astype(cfg.logit_dtype)


def _kinds(cfg):
    return jnp.asarray(cfg.layer_kinds(), jnp.int32)


def forward(cfg: ModelConfig, params, tokens, *, rules: ShardingRules,
            frontend: jax.Array | None = None, collect_cache: bool = False):
    """Causal-LM forward. Returns logits, or (logits, cache) for prefill."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _forward_stack(cfg, params, tokens, rules, frontend, collect_cache)
    if fam == "hybrid":
        return _forward_hybrid(cfg, params, tokens, rules, collect_cache)
    if fam == "ssm":
        return _forward_xlstm(cfg, params, tokens, rules, collect_cache)
    if fam == "audio":
        return _forward_encdec(cfg, params, tokens, rules, frontend, collect_cache)
    raise ValueError(fam)


def _forward_stack(cfg, params, tokens, rules, frontend, collect):
    h = _embed(cfg, params, tokens)
    if cfg.family == "vlm":
        assert frontend is not None, "vlm needs patch embeddings"
        h = jnp.concatenate([frontend.astype(h.dtype), h], axis=1)
    h = _constrain(h, rules, False)
    S = h.shape[1]
    positions = jnp.arange(S)
    kinds = _kinds(cfg)

    def body(h, xs):
        lp, kind = xs
        if collect:
            h, kv = block_apply(lp, h, cfg, rules, kind=kind,
                                positions=positions, collect=True)
            return h, kv
        h = block_apply(lp, h, cfg, rules, kind=kind, positions=positions)
        return h, None

    body = _remat(body, cfg.remat_policy)
    h, kv = jax.lax.scan(body, h, (params["layers"], kinds))
    logits = _head(cfg, params, h)
    if collect:
        cache = {"k": kv[0], "v": kv[1], "pos": jnp.int32(S)}
        return logits, cache
    return logits


def _hybrid_split(cfg, params):
    """Split the stacked hybrid layers into [G, every, ...] groups + tail."""
    every = cfg.shared_attn_every
    G = cfg.num_layers // every
    tail_n = cfg.num_layers - G * every
    grouped = jax.tree.map(
        lambda t: t[: G * every].reshape(G, every, *t.shape[1:]), params["layers"])
    tail = jax.tree.map(lambda t: t[G * every:], params["layers"])
    return grouped, tail, G, tail_n


def _mamba_block(lp, h, cfg, rules):
    h = h + M.mamba2_apply(lp["mamba"], L.rmsnorm(h, lp["ln"]), cfg)
    return _constrain(h, rules, False)


def _shared_attn_apply(sp, h, cfg, rules, positions):
    a = L.attn_apply(sp["attn"], L.rmsnorm(h, sp["ln1"]), cfg,
                     positions=positions, theta=cfg.rope_theta, causal=True,
                     window=None)
    h = h + a
    h = h + L.mlp_apply(sp["mlp"], L.rmsnorm(h, sp["ln2"]), cfg.compute_dtype)
    return _constrain(h, rules, False)


def _forward_hybrid(cfg, params, tokens, rules, collect):
    h = _embed(cfg, params, tokens)
    h = _constrain(h, rules, False)
    S = h.shape[1]
    positions = jnp.arange(S)
    grouped, tail, G, tail_n = _hybrid_split(cfg, params)
    sp = params["shared"]
    every = cfg.shared_attn_every

    def group(h, gp):
        def inner(h, lp):
            return _mamba_block(lp, h, cfg, rules), None
        pre = jax.tree.map(lambda t: t[: every - 1], gp)
        h, _ = jax.lax.scan(inner, h, pre)
        h = _shared_attn_apply(sp, h, cfg, rules, positions)
        last = jax.tree.map(lambda t: t[every - 1], gp)
        h = _mamba_block(last, h, cfg, rules)
        return h, None

    h, _ = jax.lax.scan(_remat(group, cfg.remat_policy), h, grouped)
    for i in range(tail_n):
        lp = jax.tree.map(lambda t: t[i], tail)
        h = _mamba_block(lp, h, cfg, rules)
    logits = _head(cfg, params, h)
    if collect:
        raise NotImplementedError("hybrid prefill uses prefill()")
    return logits


def _forward_xlstm(cfg, params, tokens, rules, collect):
    h = _embed(cfg, params, tokens)
    h = _constrain(h, rules, False)

    def group(h, gp):
        def inner(h, xs):
            ln, lp = xs
            y = X.mlstm_apply(lp, L.rmsnorm(h, ln), cfg)
            return _constrain(h + y, rules, False), None
        h, _ = jax.lax.scan(inner, h, (gp["mlstm_ln"], gp["mlstm"]))
        y, _ = X.slstm_apply(gp["slstm"], L.rmsnorm(h, gp["slstm_ln"]), cfg)
        return _constrain(h + y, rules, False), None

    h, _ = jax.lax.scan(_remat(group, cfg.remat_policy), h, params["groups"])
    logits = _head(cfg, params, h)
    if collect:
        raise NotImplementedError("ssm prefill uses prefill()")
    return logits


def _forward_encdec(cfg, params, tokens, rules, frames, collect):
    assert frames is not None, "audio family needs frame embeddings"
    dt = jnp.dtype(cfg.compute_dtype)
    enc_h = _constrain(frames.astype(dt), rules, False)
    enc_pos = jnp.arange(enc_h.shape[1])

    def enc_body(h, lp):
        h = block_apply(lp, h, cfg, rules, kind=jnp.int32(0),
                        positions=enc_pos, causal=False)
        return h, None

    enc_h, _ = jax.lax.scan(_remat(enc_body, cfg.remat_policy),
                            enc_h, params["enc_layers"])
    enc_h = L.rmsnorm(enc_h, params["enc_norm"])

    h = _embed(cfg, params, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)

    def dec_body(h, lp):
        a = L.attn_apply(lp["attn"], L.rmsnorm(h, lp["ln1"]), cfg,
                         positions=positions, theta=cfg.rope_theta,
                         causal=True, window=None)
        h = h + a
        x = L.attn_apply_cross(lp["xattn"], L.rmsnorm(h, lp["lnx"]), enc_h, cfg)
        h = h + x
        h = h + L.mlp_apply(lp["mlp"], L.rmsnorm(h, lp["ln2"]), cfg.compute_dtype)
        return _constrain(h, rules, False), None

    h, _ = jax.lax.scan(_remat(dec_body, cfg.remat_policy), h, params["layers"])
    logits = _head(cfg, params, h)
    if collect:
        raise NotImplementedError("audio prefill uses prefill()")
    return logits


# ============================================================ serving paths

def init_cache(cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False):
    """Decode-state pytree. KV caches are bf16; SSM states f32."""
    z = ((lambda s, d: jax.ShapeDtypeStruct(s, jnp.dtype(d))) if abstract
         else (lambda s, d: jnp.zeros(s, d)))
    hd = cfg.resolved_head_dim
    Lc, B, S = cfg.num_layers, batch, max_len
    KVH = cfg.num_kv_heads
    fam = cfg.family
    cache: dict[str, Any] = {"pos": z((), jnp.int32)}
    if fam in ("dense", "moe", "vlm"):
        cache["k"] = z((Lc, B, S, KVH, hd), jnp.bfloat16)
        cache["v"] = z((Lc, B, S, KVH, hd), jnp.bfloat16)
    elif fam == "hybrid":
        H = cfg.resolved_ssm_heads
        N = cfg.ssm_state
        Pd = cfg.d_inner // H
        G = cfg.num_layers // cfg.shared_attn_every
        cache["mamba_h"] = z((Lc, B, H, N, Pd), jnp.float32)
        cache["mamba_conv"] = z((Lc, B, M._CONV_K - 1, cfg.d_inner + 2 * N), jnp.float32)
        cache["k"] = z((G, B, S, KVH, hd), jnp.bfloat16)
        cache["v"] = z((G, B, S, KVH, hd), jnp.bfloat16)
    elif fam == "ssm":
        G = cfg.num_layers // cfg.slstm_every
        nm = cfg.slstm_every - 1
        H = cfg.num_heads
        Pm = 2 * cfg.d_model // H
        Ps = cfg.d_model // H
        cache["mlstm_h"] = z((G, nm, B, H, Pm, Pm + 1), jnp.float32)
        cache["mlstm_m"] = z((G, nm, B, H), jnp.float32)
        for nm_ in ("h", "c", "n", "m"):
            cache[f"slstm_{nm_}"] = z((G, B, H, Ps), jnp.float32)
    elif fam == "audio":
        cache["k"] = z((Lc, B, S, KVH, hd), jnp.bfloat16)
        cache["v"] = z((Lc, B, S, KVH, hd), jnp.bfloat16)
        cache["xk"] = z((Lc, B, cfg.enc_seq, KVH, hd), jnp.bfloat16)
        cache["xv"] = z((Lc, B, cfg.enc_seq, KVH, hd), jnp.bfloat16)
    return cache


def cache_specs(cfg: ModelConfig, rules: ShardingRules):
    """PartitionSpecs mirroring init_cache. KV caches are sequence-sharded on
    the model axis (flash-decoding layout) + batch-sharded on data axes —
    uniform across archs regardless of kv-head count, and the only viable
    layout at 500k context."""
    bx, sx = rules.batch, rules.heads  # seq dim of caches -> model axis
    fam = cfg.family
    specs: dict[str, Any] = {"pos": P()}
    if fam in ("dense", "moe", "vlm", "audio"):
        specs["k"] = P(None, bx, sx, None, None)
        specs["v"] = P(None, bx, sx, None, None)
        if fam == "audio":
            specs["xk"] = P(None, bx, sx, None, None)
            specs["xv"] = P(None, bx, sx, None, None)
    elif fam == "hybrid":
        specs["mamba_h"] = P(None, bx, sx, None, None)      # shard SSM heads
        specs["mamba_conv"] = P(None, bx, None, sx)
        specs["k"] = P(None, bx, sx, None, None)
        specs["v"] = P(None, bx, sx, None, None)
    elif fam == "ssm":
        specs["mlstm_h"] = P(None, None, bx, None, sx, None)  # shard memory P
        specs["mlstm_m"] = P(None, None, bx, None)
        for nm_ in ("h", "c", "n", "m"):
            specs[f"slstm_{nm_}"] = P(None, bx, None, sx)
    return specs


def prefill(cfg: ModelConfig, params, tokens, *, rules: ShardingRules,
            frontend=None):
    """Process a prompt; returns (last-token logits, cache at len(prompt))."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        logits, cache = _forward_stack(cfg, params, tokens, rules, frontend, True)
        return logits[:, -1], cache
    if fam == "hybrid":
        return _prefill_hybrid(cfg, params, tokens, rules)
    if fam == "ssm":
        return _prefill_xlstm(cfg, params, tokens, rules)
    if fam == "audio":
        return _prefill_encdec(cfg, params, tokens, rules, frontend)
    raise ValueError(fam)


def _prefill_hybrid(cfg, params, tokens, rules):
    h = _embed(cfg, params, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)
    grouped, tail, G, tail_n = _hybrid_split(cfg, params)
    sp = params["shared"]
    every = cfg.shared_attn_every

    def group(h, gp):
        def inner(h, lp):
            y, st = M.mamba2_apply(lp["mamba"], L.rmsnorm(h, lp["ln"]), cfg,
                                   return_state=True)
            return _constrain(h + y, rules, False), st
        pre = jax.tree.map(lambda t: t[: every - 1], gp)
        h, sts_pre = jax.lax.scan(inner, h, pre)
        a, kv = L.attn_apply(sp["attn"], L.rmsnorm(h, sp["ln1"]), cfg,
                             positions=positions, theta=cfg.rope_theta,
                             causal=True, window=None, collect=True)
        h = h + a
        h = h + L.mlp_apply(sp["mlp"], L.rmsnorm(h, sp["ln2"]), cfg.compute_dtype)
        h = _constrain(h, rules, False)
        h, st_last = inner(h, jax.tree.map(lambda t: t[every - 1], gp))
        sts = jax.tree.map(lambda a_, b_: jnp.concatenate([a_, b_[None]]),
                           sts_pre, st_last)
        return h, (sts, kv)

    h, (sts_g, kvs) = jax.lax.scan(group, h, grouped)
    # tail layers (unrolled)
    tail_sts = []
    for i in range(tail_n):
        lp = jax.tree.map(lambda t: t[i], tail)
        y, st = M.mamba2_apply(lp["mamba"], L.rmsnorm(h, lp["ln"]), cfg,
                               return_state=True)
        h = _constrain(h + y, rules, False)
        tail_sts.append(st)
    logits = _head(cfg, params, h)
    # assemble cache: group states (G, every, ...) -> (L, ...)
    sts_flat = jax.tree.map(lambda t: t.reshape(-1, *t.shape[2:]), sts_g)
    if tail_sts:
        tail_stack = jax.tree.map(lambda *t: jnp.stack(t), *tail_sts)
        sts_flat = jax.tree.map(lambda a_, b_: jnp.concatenate([a_, b_]),
                                sts_flat, tail_stack)
    cache = {"mamba_h": sts_flat["h"], "mamba_conv": sts_flat["conv"],
             "k": kvs[0], "v": kvs[1], "pos": jnp.int32(S)}
    return logits[:, -1], cache


def _prefill_xlstm(cfg, params, tokens, rules):
    h = _embed(cfg, params, tokens)

    def group(h, gp):
        def inner(h, xs):
            ln, lp = xs
            y, st = X.mlstm_apply(lp, L.rmsnorm(h, ln), cfg, return_state=True)
            return _constrain(h + y, rules, False), st
        h, m_sts = jax.lax.scan(inner, h, (gp["mlstm_ln"], gp["mlstm"]))
        y, s_st = X.slstm_apply(gp["slstm"], L.rmsnorm(h, gp["slstm_ln"]), cfg)
        return _constrain(h + y, rules, False), (m_sts, s_st)

    h, (m_sts, s_sts) = jax.lax.scan(group, h, params["groups"])
    logits = _head(cfg, params, h)
    cache = {"mlstm_h": m_sts["h"], "mlstm_m": m_sts["m"],
             "slstm_h": s_sts["h"], "slstm_c": s_sts["c"],
             "slstm_n": s_sts["n"], "slstm_m": s_sts["m"],
             "pos": jnp.int32(tokens.shape[1])}
    return logits[:, -1], cache


def _prefill_encdec(cfg, params, tokens, rules, frames):
    dt = jnp.dtype(cfg.compute_dtype)
    enc_h = _constrain(frames.astype(dt), rules, False)
    enc_pos = jnp.arange(enc_h.shape[1])

    def enc_body(h, lp):
        return block_apply(lp, h, cfg, rules, kind=jnp.int32(0),
                           positions=enc_pos, causal=False), None

    enc_h, _ = jax.lax.scan(enc_body, enc_h, params["enc_layers"])
    enc_h = L.rmsnorm(enc_h, params["enc_norm"])

    h = _embed(cfg, params, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)
    hd = cfg.resolved_head_dim

    def dec_body(h, lp):
        a, kv = L.attn_apply(lp["attn"], L.rmsnorm(h, lp["ln1"]), cfg,
                             positions=positions, theta=cfg.rope_theta,
                             causal=True, window=None, collect=True)
        h = h + a
        xp = lp["xattn"]
        b = h.shape[0]
        xk = jnp.einsum("bsd,dh->bsh", enc_h, xp["wk"].astype(enc_h.dtype)).reshape(
            b, -1, cfg.num_kv_heads, hd).astype(jnp.bfloat16)
        xv = jnp.einsum("bsd,dh->bsh", enc_h, xp["wv"].astype(enc_h.dtype)).reshape(
            b, -1, cfg.num_kv_heads, hd).astype(jnp.bfloat16)
        x = L.attn_apply_cross(xp, L.rmsnorm(h, lp["lnx"]), None, cfg, kv=(xk, xv))
        h = h + x
        h = h + L.mlp_apply(lp["mlp"], L.rmsnorm(h, lp["ln2"]), cfg.compute_dtype)
        return _constrain(h, rules, False), (kv[0], kv[1], xk, xv)

    h, (ks, vs, xks, xvs) = jax.lax.scan(dec_body, h, params["layers"])
    logits = _head(cfg, params, h)
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs, "pos": jnp.int32(S)}
    return logits[:, -1], cache


# ------------------------------------------------------------- decode step

def decode_step(cfg: ModelConfig, params, cache, tokens, *, rules: ShardingRules):
    """One token for every sequence. tokens: (B, 1). Returns (logits, cache)."""
    fam = cfg.family
    pos = cache["pos"]
    h = _embed(cfg, params, tokens)
    B = tokens.shape[0]
    pos_vec = jnp.full((B,), pos, jnp.int32)
    new_cache = dict(cache)
    kinds = None

    if fam in ("dense", "moe", "vlm"):
        kinds = _kinds(cfg)

        def body(h, xs):
            lp, kind, ck, cv = xs
            h, ck, cv = block_decode(lp, h, cfg, rules, ck, cv, pos_vec, kind=kind)
            return h, (ck, cv)

        h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], kinds,
                                             cache["k"], cache["v"]))
        new_cache.update(k=ks, v=vs)

    elif fam == "hybrid":
        grouped, tail, G, tail_n = _hybrid_split(cfg, params)
        sp = params["shared"]
        every = cfg.shared_attn_every
        Lg = G * every
        mh = cache["mamba_h"]
        mc = cache["mamba_conv"]
        g_st = jax.tree.map(lambda t: t[:Lg].reshape(G, every, *t.shape[1:]),
                            {"h": mh, "conv": mc})

        def group(h, xs):
            gp, st, ck, cv = xs

            def inner(h_, xs_):
                lp, st_ = xs_
                y, st2 = M.mamba2_step(lp["mamba"], L.rmsnorm(h_, lp["ln"]), st_, cfg)
                return h_ + y, st2

            pre = jax.tree.map(lambda t: t[: every - 1], gp)
            pre_st = jax.tree.map(lambda t: t[: every - 1], st)
            h, new_pre = jax.lax.scan(inner, h, (pre, pre_st))
            a, ck, cv = L.attn_decode_apply(sp["attn"], L.rmsnorm(h, sp["ln1"]),
                                            cfg, ck, cv, pos_vec,
                                            theta=cfg.rope_theta)
            h = h + a
            h = h + L.mlp_apply(sp["mlp"], L.rmsnorm(h, sp["ln2"]), cfg.compute_dtype)
            h, new_last = inner(h, (jax.tree.map(lambda t: t[every - 1], gp),
                                    jax.tree.map(lambda t: t[every - 1], st)))
            new_st = jax.tree.map(lambda a_, b_: jnp.concatenate([a_, b_[None]]),
                                  new_pre, new_last)
            return h, (new_st, ck, cv)

        h, (new_g_st, ks, vs) = jax.lax.scan(
            group, h, (grouped, g_st, cache["k"], cache["v"]))
        flat = jax.tree.map(lambda t: t.reshape(-1, *t.shape[2:]), new_g_st)
        tails = []
        for i in range(tail_n):
            lp = jax.tree.map(lambda t: t[i], tail)
            st_i = {"h": mh[Lg + i], "conv": mc[Lg + i]}
            y, st2 = M.mamba2_step(lp["mamba"], L.rmsnorm(h, lp["ln"]), st_i, cfg)
            h = h + y
            tails.append(st2)
        if tails:
            tstack = jax.tree.map(lambda *t: jnp.stack(t), *tails)
            flat = jax.tree.map(lambda a_, b_: jnp.concatenate([a_, b_]), flat, tstack)
        new_cache.update(mamba_h=flat["h"], mamba_conv=flat["conv"], k=ks, v=vs)

    elif fam == "ssm":
        def group(h, xs):
            gp, mh, mm, sh, sc, sn, sm = xs

            def inner(h_, xs_):
                ln, lp, st_h, st_m = xs_
                y, st2 = X.mlstm_step(lp, L.rmsnorm(h_, ln), {"h": st_h, "m": st_m}, cfg)
                return h_ + y, (st2["h"], st2["m"])

            h, (nh, nm_) = jax.lax.scan(inner, h, (gp["mlstm_ln"], gp["mlstm"], mh, mm))
            st = {"h": sh, "c": sc, "n": sn, "m": sm}
            y, st2 = X.slstm_step(gp["slstm"], L.rmsnorm(h, gp["slstm_ln"]), st, cfg)
            return h + y, (nh, nm_, st2["h"], st2["c"], st2["n"], st2["m"])

        h, outs = jax.lax.scan(group, h, (params["groups"], cache["mlstm_h"],
                                          cache["mlstm_m"], cache["slstm_h"],
                                          cache["slstm_c"], cache["slstm_n"],
                                          cache["slstm_m"]))
        new_cache.update(mlstm_h=outs[0], mlstm_m=outs[1], slstm_h=outs[2],
                         slstm_c=outs[3], slstm_n=outs[4], slstm_m=outs[5])

    elif fam == "audio":
        def body(h, xs):
            lp, ck, cv, xk, xv = xs
            a, ck, cv = L.attn_decode_apply(lp["attn"], L.rmsnorm(h, lp["ln1"]),
                                            cfg, ck, cv, pos_vec,
                                            theta=cfg.rope_theta)
            h = h + a
            x = L.attn_apply_cross(lp["xattn"], L.rmsnorm(h, lp["lnx"]), None,
                                   cfg, kv=(xk, xv))
            h = h + x
            h = h + L.mlp_apply(lp["mlp"], L.rmsnorm(h, lp["ln2"]), cfg.compute_dtype)
            return h, (ck, cv)

        h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"],
                                             cache["v"], cache["xk"], cache["xv"]))
        new_cache.update(k=ks, v=vs)
    else:
        raise ValueError(fam)

    logits = _head(cfg, params, h)
    new_cache["pos"] = pos + 1
    return logits[:, 0], new_cache
