"""Attention implementations: ref / chunked (flash algorithm in pure JAX) /
pallas (the TPU kernel), plus cache-decode attention.

``chunked`` is the dry-run default: a ``lax.scan`` over KV blocks with online
softmax, so the lowered HLO never materializes the (S, S) score matrix — the
compiled bytes/flops match what the TPU flash kernel would do, which keeps
the roofline honest at 32k/500k contexts.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


NO_WINDOW = 1 << 30


def _mask(rows, cols, causal: bool, window, kv_len):
    """window may be a traced int32 (per-layer kinds select it inside scan);
    NO_WINDOW (2^30) makes the clause a no-op."""
    m = cols < kv_len
    if causal:
        m &= rows >= cols
    m &= cols > rows - (NO_WINDOW if window is None else window)
    return m


def attention_ref(q, k, v, *, causal=True, window=None, kv_len=None):
    """Materialized-score GQA attention (oracle). q:(B,S,H,D) k/v:(B,S,KVH,D)."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    rows = jnp.arange(sq)[:, None] + (sk - sq if causal else 0)
    cols = jnp.arange(sk)[None, :]
    m = _mask(rows, cols, causal, window, sk if kv_len is None else kv_len)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(b, sq, h, d).astype(q.dtype)


def attention_chunked(q, k, v, *, causal=True, window=None, kv_len=None,
                      chunk=1024, p_dtype=None):
    """Flash algorithm as a lax.scan over KV chunks (no S^2 materialization).

    Wrapped in a named_scope so the HLO accounting can attribute the
    intermediate HBM traffic that the Pallas kernel keeps in VMEM on TPU."""
    with jax.named_scope("flash_attention_scope"):
        return _attention_chunked(q, k, v, causal=causal, window=window,
                                  kv_len=kv_len, chunk=chunk, p_dtype=p_dtype)


def _attention_chunked(q, k, v, *, causal, window, kv_len, chunk, p_dtype=None):
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkv = (sk + pad) // chunk
    kv_len = jnp.asarray(sk if kv_len is None else kv_len, jnp.int32)

    qf = (q.astype(jnp.float32) * (d ** -0.5)).reshape(b, sq, kvh, g, d)
    kc = k.astype(jnp.float32).reshape(b, nkv, chunk, kvh, d).swapaxes(0, 1)
    vc = v.astype(jnp.float32).reshape(b, nkv, chunk, kvh, d).swapaxes(0, 1)

    rows = jnp.arange(sq)[:, None] + (sk - sq if causal else 0)

    def step(carry, xs):
        acc, m_prev, l_prev = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb)
        cols = ci * chunk + jnp.arange(chunk)[None, :]
        msk = _mask(rows, cols, causal, window, kv_len)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        if p_dtype is not None:   # store/stream P at reduced precision
            p = p.astype(p_dtype)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    m0 = jnp.full((b, kvh, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (kc, vc, jnp.arange(nkv)))
    o = acc / jnp.maximum(l, 1e-30)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def attention_decode(q, k_cache, v_cache, kv_len, *, window=None):
    """Single-step decode: q:(B,1,H,D) against cache:(B,S,KVH,D).

    Softmax runs over the (possibly sequence-sharded) cache axis — GSPMD
    turns the max/sum into the flash-decoding partial-softmax all-reduce.
    """
    b, _, h, d = q.shape
    _, sk, kvh, _ = k_cache.shape
    g = h // kvh
    qf = (q.astype(jnp.float32) * (d ** -0.5)).reshape(b, kvh, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    cols = jnp.arange(sk)[None, :]
    m = cols < kv_len
    m &= cols > kv_len - 1 - (NO_WINDOW if window is None else window)
    s = jnp.where(m[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


def attention(q, k, v, *, impl="chunked", causal=True, window=None,
              kv_len=None, chunk=1024, p_dtype=None):
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window, kv_len=kv_len)
    if impl == "chunked":
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 kv_len=kv_len, chunk=chunk, p_dtype=p_dtype)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as kops
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        o = kops.flash_attention(qt, kt, vt, kv_len, causal=causal, window=window)
        return o.transpose(0, 2, 1, 3)
    raise ValueError(f"unknown attention impl {impl!r}")
