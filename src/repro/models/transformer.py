"""Block definitions + scan-over-layers assembly for all families.

One stacked ``lax.scan`` over layers keeps HLO size O(1) in depth (deepseek:
95 layers). Heterogeneous patterns (gemma3 local/global) ride through the
scan as a per-layer integer ``kind`` with *traced* window/theta selection —
same param shapes, branch-free. Genuinely different blocks (zamba2's shared
attention, xlstm's sLSTM) use shared closures / grouped scans.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from . import mamba2 as M
from . import xlstm as X
from .config import ModelConfig
from .module import Creator, ShardingRules

NO_WINDOW = 1 << 30


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def maybe_constrain(x, spec: P):
    """with_sharding_constraint that no-ops on an unsharded spec (so model
    code runs outside any mesh context, e.g. CPU smoke tests)."""
    if all(a is None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _constrain(h, rules: ShardingRules, seq_sharded: bool):
    spec = P(rules.batch, rules.seq if seq_sharded else None, None)
    return maybe_constrain(h, spec)


# ------------------------------------------------------------ dense / moe
def block_init(c: Creator, cfg: ModelConfig):
    p = {
        "ln1": c("ln1", (cfg.d_model,), (None,), scale="zeros"),
        "attn": L.attn_init(c, cfg),
        "ln2": c("ln2", (cfg.d_model,), (None,), scale="zeros"),
    }
    if cfg.num_experts:
        p["moe"] = L.moe_init(c, cfg)
    else:
        p["mlp"] = L.mlp_init(c, cfg)
    return p


def layer_window_theta(cfg: ModelConfig, kind):
    window = jnp.where(kind == 1,
                       jnp.int32(cfg.local_window or cfg.window or NO_WINDOW),
                       jnp.int32(cfg.window or NO_WINDOW))
    theta = jnp.where(kind == 1, cfg.rope_theta,
                      cfg.global_rope_theta or cfg.rope_theta)
    return window, theta


def block_apply(p, h, cfg: ModelConfig, rules, *, kind, positions,
                kv_len=None, causal=True, collect=False):
    """kind: 0 = global/full attn, 1 = local/windowed (traced ok)."""
    window, theta = layer_window_theta(cfg, kind)
    a = L.attn_apply(p["attn"], L.rmsnorm(h, p["ln1"]), cfg,
                     positions=positions, theta=theta, causal=causal,
                     window=window, kv_len=kv_len, collect=collect)
    if collect:
        a, kv = a
    h = h + a
    h = _constrain(h, rules, cfg.seq_parallel)
    x = L.rmsnorm(h, p["ln2"])
    if cfg.num_experts:
        m = L.moe_apply(p["moe"], x, cfg, rules)
    else:
        m = L.mlp_apply(p["mlp"], x, cfg.compute_dtype)
    h = h + m
    h = _constrain(h, rules, cfg.seq_parallel)
    return (h, kv) if collect else h


def block_decode(p, h, cfg, rules, cache_k, cache_v, pos, *, kind):
    window = jnp.where(kind == 1,
                       jnp.int32(cfg.local_window or cfg.window or NO_WINDOW),
                       jnp.int32(cfg.window or NO_WINDOW))
    theta = jnp.where(kind == 1, cfg.rope_theta,
                      cfg.global_rope_theta or cfg.rope_theta)
    a, ck, cv = L.attn_decode_apply(p["attn"], L.rmsnorm(h, p["ln1"]), cfg,
                                    cache_k, cache_v, pos, theta=theta,
                                    window=window)
    h = h + a
    x = L.rmsnorm(h, p["ln2"])
    if cfg.num_experts:
        m = L.moe_apply(p["moe"], x, cfg, rules)
    else:
        m = L.mlp_apply(p["mlp"], x, cfg.compute_dtype)
    return h + m, ck, cv


# ------------------------------------------------------------ hybrid (zamba2)
def hybrid_block_init(c: Creator, cfg: ModelConfig):
    return {
        "ln": c("ln", (cfg.d_model,), (None,), scale="zeros"),
        "mamba": M.mamba2_init(c, cfg),
    }


def shared_attn_init(c: Creator, cfg: ModelConfig):
    return {
        "ln1": c("sln1", (cfg.d_model,), (None,), scale="zeros"),
        "attn": L.attn_init(c, cfg, prefix="shared_attn"),
        "ln2": c("sln2", (cfg.d_model,), (None,), scale="zeros"),
        "mlp": L.mlp_init(c, cfg),
    }


# ------------------------------------------------------------ ssm (xlstm)
def xlstm_group_init(c: Creator, cfg: ModelConfig):
    """One group = (slstm_every - 1) stacked mLSTM blocks + 1 sLSTM block."""
    from .module import stack_init
    n_m = cfg.slstm_every - 1
    return {
        "mlstm_ln": c("gln", (n_m, cfg.d_model), ("layers", None), scale="zeros"),
        "mlstm": stack_init(c, n_m, lambda cc: X.mlstm_init(cc, cfg)),
        "slstm_ln": c("sln", (cfg.d_model,), (None,), scale="zeros"),
        "slstm": X.slstm_init(c, cfg),
    }


# ------------------------------------------------------------ stacks
def scan_or_loop(body, carry, xs, cfg: ModelConfig, length: int):
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    ys = (jax.tree.map(lambda *t: jnp.stack(t), *ys) if ys and ys[0] is not None
          else None)
    return carry, ys
