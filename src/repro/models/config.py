"""Model configuration: one dataclass covers all 10 assigned families."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // num_heads

    # attention pattern
    rope_theta: float = 10_000.0
    global_rope_theta: float = 0.0        # gemma3 global layers (0 -> same)
    window: int | None = None             # sliding window for *all* attn layers
    local_window: int = 0                 # gemma3: window of local layers
    global_every: int = 0                 # gemma3: every k-th layer is global

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                     # expert hidden (0 -> d_ff)
    capacity_factor: float = 1.25
    moe_impl: str = "dense"               # dense (GSPMD) | shard_map (explicit EP)

    # SSM / hybrid (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0                    # 0 -> d_inner // 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    shared_attn_every: int = 0            # zamba2: shared attn block period

    # xLSTM
    slstm_every: int = 0                  # every k-th block is sLSTM

    # encoder-decoder (whisper) / vlm
    enc_layers: int = 0
    enc_seq: int = 0                      # encoder frame count (stub frontend)
    num_patches: int = 0                  # vlm: vision prefix length (stub)

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    logit_dtype: str = "float32"
    tie_embeddings: bool = False

    # runtime knobs (overridable per experiment — hillclimb levers)
    remat_policy: str = "full"            # full | dots | none
    attn_impl: str = "chunked"            # chunked | ref | pallas
    attn_chunk: int = 1024
    seq_parallel: bool = False            # shard activations' seq dim (SP)
    scan_layers: bool = True
    attn_p_dtype: str = "float32"         # probability-matrix dtype in chunked attn
    slstm_bf16: bool = False              # sLSTM recurrent matmul in bf16
    slstm_unroll: int = 1                 # unroll factor of the sLSTM time scan

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:             # mamba2
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    def layer_kinds(self) -> tuple[int, ...]:
        """Per-layer kind vector consumed as scan xs.

        dense/moe: 0 = full attn, 1 = local/windowed (gemma3), shared-attn
        period for zamba2 handled in the hybrid block (kind = 1 on slots that
        also run the shared attention block); xlstm: 1 = sLSTM slot.
        """
        L = self.num_layers
        if self.global_every:             # gemma3: every k-th is global (0-idx k-1)
            return tuple(0 if (i % self.global_every == self.global_every - 1) else 1
                         for i in range(L))
        if self.shared_attn_every:        # zamba2
            return tuple(1 if (i % self.shared_attn_every == self.shared_attn_every - 1) else 0
                         for i in range(L))
        if self.slstm_every:              # xlstm
            return tuple(1 if (i % self.slstm_every == self.slstm_every - 1) else 0
                         for i in range(L))
        return tuple(0 for _ in range(L))

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (reported in configs / roofline)."""
        D, V, L = self.d_model, self.vocab_size, self.num_layers
        n = V * D  # embed
        if not self.tie_embeddings:
            n += D * V
        hd = self.resolved_head_dim
        attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        if self.family == "ssm":
            # mLSTM block params (approx): qkv + gates + out
            di = 2 * D
            blk = D * di * 2 + di * D + D * di // 2 + 4 * di
            n += L * blk
            return n
        if self.family == "hybrid":
            di = self.d_inner
            H = self.resolved_ssm_heads
            mamba = D * (2 * di + 2 * self.ssm_state * 2 + H) + di * D + di * 4
            n += L * mamba + attn + 3 * D * self.d_ff  # one shared attn+mlp
            return n
        mlp = 3 * D * self.d_ff
        if self.num_experts:
            eff = self.moe_d_ff or self.d_ff
            mlp = self.num_experts * 3 * D * eff + D * self.num_experts
        n += L * (attn + mlp)
        if self.enc_layers:
            n += self.enc_layers * (attn + 3 * D * self.d_ff)  # encoder
            n += L * attn                                      # cross attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for 6ND flops."""
        if not self.num_experts:
            return self.param_count()
        D, L = self.d_model, self.num_layers
        eff = self.moe_d_ff or self.d_ff
        dense_mlp = self.num_experts_per_tok * 3 * D * eff
        full_mlp = self.num_experts * 3 * D * eff
        return self.param_count() - L * full_mlp + L * dense_mlp
