"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, strictly recurrent with hidden-to-gate feedback).

TPU adaptation: mLSTM is a special case of the SSD chunked machinery — the
forget gate is a per-head scalar decay (like Mamba2's ``exp(a*dt)``) and the
input gate weights the ``v k^T`` outer products. We compute numerator and
normalizer in ONE chunked pass by appending a ones-channel to ``v``
(state (N, P+1)); all chunk math is MXU matmuls. sLSTM's cross-step gate
recurrence is inherently sequential -> lax.scan over time (only every 8th
block; documented cost in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .module import Creator


# ------------------------------------------------------------------ mLSTM
def mlstm_init(c: Creator, cfg: ModelConfig):
    D = cfg.d_model
    di = 2 * D                       # up-projection factor 2 (xLSTM paper)
    return {
        "up": c("mlstm.up", (D, 2 * di), ("embed", "heads")),     # [x | z]
        "wq": c("mlstm.wq", (di, di), ("heads", None)),
        "wk": c("mlstm.wk", (di, di), ("heads", None)),
        "wv": c("mlstm.wv", (di, di), ("heads", None)),
        "wif": c("mlstm.wif", (di, 2 * cfg.num_heads), ("heads", None)),
        "norm": c("mlstm.norm", (di,), (None,), scale="zeros"),
        "down": c("mlstm.down", (di, D), ("heads", "embed")),
    }


def _mlstm_qkvg(p, cfg, u):
    dt_c = jnp.dtype(cfg.compute_dtype)
    D = cfg.d_model
    di = 2 * D
    H = cfg.num_heads
    P = di // H
    proj = jnp.einsum("bsd,de->bse", u.astype(dt_c), p["up"].astype(dt_c))
    x, z = jnp.split(proj, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", x, p["wq"].astype(dt_c))
    k = jnp.einsum("bse,ef->bsf", x, p["wk"].astype(dt_c)) * (P ** -0.5)
    v = jnp.einsum("bse,ef->bsf", x, p["wv"].astype(dt_c))
    gate = jnp.einsum("bse,eg->bsg", x, p["wif"].astype(dt_c)).astype(jnp.float32)
    i_raw, f_raw = jnp.split(gate, 2, axis=-1)               # (B,S,H)
    b, s, _ = q.shape
    shp = (b, s, H, P)
    return (q.reshape(shp).astype(jnp.float32), k.reshape(shp).astype(jnp.float32),
            v.reshape(shp).astype(jnp.float32), i_raw, f_raw, z)


def _mlstm_tail(p, cfg, y, z, b, s):
    dt_c = jnp.dtype(cfg.compute_dtype)
    di = y.shape[-1]
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = y * (1.0 + p["norm"].astype(jnp.float32))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bse,ed->bsd", y.astype(dt_c), p["down"].astype(dt_c))


def mlstm_apply(p, u, cfg: ModelConfig, state=None, return_state: bool = False):
    """Chunked-parallel mLSTM. u: (B,S,D) -> (B,S,D) (+ final state)."""
    b, S, D = u.shape
    H = cfg.num_heads
    Q = cfg.ssm_chunk or 128
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(p, cfg, u)
    P = q.shape[-1]
    pad = (-S) % Q
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)
        # +30 -> log_sigmoid ~ 0: padded steps do not decay the carried state
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)
    Sp = S + pad
    nc = Sp // Q
    logf = jax.nn.log_sigmoid(f_raw)                          # (B,S',H)
    logi = i_raw                                              # exp input gate (stabilized below)
    # ones-channel trick: state tracks [v | 1] so the normalizer rides along.
    v1 = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)   # (B,S',H,P+1)

    shp = lambda t: jnp.moveaxis(t.reshape(b, nc, Q, *t.shape[2:]), 1, 0)
    qc, kc, vc, lfc, lic = map(shp, (q, k, v1, logf, logi))

    def chunk(carry, xs):
        # h is stored stabilized: h_true = h * exp(m).  m: (B,H)
        h, m = carry                                          # h:(B,H,P,P+1)
        qq, kk, vv, lf, li = xs
        cum = jnp.cumsum(lf, axis=1)                          # (B,Q,H)
        # per-row stabilizer: m_row_i = cum_i + max(m, cummax_{j<=i}(li_j - cum_j))
        gj = li - cum                                         # (B,Q,H)
        Mi = jax.lax.cummax(gj, axis=1)
        m_row = cum + jnp.maximum(Mi, m[:, None])             # (B,Q,H)
        # intra-chunk: w_ij = exp(cum_i - cum_j + li_j - m_row_i)
        diff = cum[:, :, None] - cum[:, None, :] + li[:, None] - m_row[:, :, None]
        ii = jnp.arange(Q)
        causal = ii[:, None] >= ii[None, :]
        w = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        qk = jnp.einsum("bihp,bjhp->bijh", qq, kk)
        y_intra = jnp.einsum("bijh,bjhp->bihp", qk * w, vv)
        # inter-chunk (carried state, decayed into this chunk)
        dec_in = jnp.exp(cum + m[:, None] - m_row)            # (B,Q,H)
        y_inter = jnp.einsum("bihp,bhpr->bihr", qq, h) * dec_in[..., None]
        y = y_intra + y_inter                                 # (B,Q,H,P+1)
        # state update to end of chunk
        m_new = cum[:, -1] + jnp.maximum(Mi[:, -1], m)        # (B,H)
        dec_end = jnp.exp(cum[:, -1:] - cum + li - m_new[:, None])
        hb = jnp.einsum("bjhp,bjhr->bhpr", kk * dec_end[..., None], vv)
        h = h * jnp.exp(cum[:, -1] + m - m_new)[..., None, None] + hb
        return (h, m_new), (y, m_row)

    if state is None:
        h0 = jnp.zeros((b, H, P, P + 1), jnp.float32)
        m0 = jnp.full((b, H), -30.0, jnp.float32)
    else:
        h0, m0 = state["h"], state["m"]
    with jax.named_scope("mlstm_chunk_scope"):
        (hf, mf), (ys, mrows) = jax.lax.scan(chunk, (h0, m0), (qc, kc, vc, lfc, lic))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, Sp, H, P + 1)[:, :S]
    m_row = jnp.moveaxis(mrows, 0, 1).reshape(b, Sp, H)[:, :S]
    num, den = y[..., :P], y[..., P:]
    floor = jnp.exp(jnp.clip(-m_row, -60.0, 60.0))[..., None]
    out = num / jnp.maximum(jnp.abs(den), floor)
    out = out.reshape(b, S, H * P)
    y = _mlstm_tail(p, cfg, out, z[:, :S], b, S)
    if return_state:
        return y, {"h": hf, "m": mf}
    return y


def mlstm_init_state(cfg: ModelConfig, batch: int):
    D = cfg.d_model
    H = cfg.num_heads
    P = 2 * D // H
    return {"h": jnp.zeros((batch, H, P, P + 1), jnp.float32),
            "m": jnp.full((batch, H), -30.0, jnp.float32)}


def mlstm_step(p, u, state, cfg: ModelConfig):
    """Single-token mLSTM recurrence (constant-memory decode)."""
    b = u.shape[0]
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(p, cfg, u)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                       # (B,H,P)
    P = q.shape[-1]
    lf = jax.nn.log_sigmoid(f_raw[:, 0])                      # (B,H)
    li = i_raw[:, 0]
    m_new = jnp.maximum(state["m"] + lf, li)
    fw = jnp.exp(state["m"] + lf - m_new)[..., None, None]
    iw = jnp.exp(li - m_new)[..., None, None]
    v1 = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    h = state["h"] * fw + iw * jnp.einsum("bhp,bhr->bhpr", k, v1)
    y = jnp.einsum("bhp,bhpr->bhr", q, h)
    num, den = y[..., :P], y[..., P:]
    floor = jnp.exp(jnp.clip(-m_new, -60.0, 60.0))[..., None]
    out = (num / jnp.maximum(jnp.abs(den), floor)).reshape(b, 1, -1)
    return _mlstm_tail(p, cfg, out, z, b, 1), {"h": h, "m": m_new}


# ------------------------------------------------------------------ sLSTM
def slstm_init(c: Creator, cfg: ModelConfig):
    D = cfg.d_model
    H = cfg.num_heads
    P = D // H
    f = int(D * 4 / 3 / 64) * 64 or 64
    return {
        "w": c("slstm.w", (D, 4 * D), ("embed", "heads")),        # z i f o
        "r": c("slstm.r", (H, P, 4 * P), (None, None, None), scale=0.05),
        "norm": c("slstm.norm", (D,), (None,), scale="zeros"),
        "ff_up": c("slstm.ffu", (D, 2 * f), ("embed", "mlp")),
        "ff_down": c("slstm.ffd", (f, D), ("mlp", "embed")),
    }


def _slstm_cell(p, cfg, wx_t, state):
    """One sLSTM step. wx_t: (B,4D) precomputed input projection."""
    H = cfg.num_heads
    D = cfg.d_model
    P = D // H
    h, cell, n, m = state
    rdt = jnp.bfloat16 if cfg.slstm_bf16 else jnp.float32
    rx = jnp.einsum("bhp,hpq->bhq", h.astype(rdt), p["r"].astype(rdt),
                    preferred_element_type=jnp.float32).reshape(-1, 4 * D)
    zifo = (wx_t + rx).reshape(-1, H, 4, P)
    zt = jnp.tanh(zifo[:, :, 0])
    it = zifo[:, :, 1]
    ft = zifo[:, :, 2]
    ot = jax.nn.sigmoid(zifo[:, :, 3])
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    iw = jnp.exp(it - m_new)
    fw = jnp.exp(lf + m - m_new)
    cell = fw * cell + iw * zt
    n = fw * n + iw
    h_new = ot * cell / jnp.maximum(jnp.abs(n), 1.0)
    return (h_new, cell, n, m_new)


def slstm_apply(p, u, cfg: ModelConfig, state=None):
    """Recurrent sLSTM over time + gated FFN tail. u: (B,S,D)."""
    dt_c = jnp.dtype(cfg.compute_dtype)
    b, S, D = u.shape
    H = cfg.num_heads
    P = D // H
    wx = jnp.einsum("bsd,dg->bsg", u.astype(dt_c), p["w"].astype(dt_c)).astype(jnp.float32)
    if state is None:
        state = slstm_init_state(cfg, b)
    st = (state["h"], state["c"], state["n"], state["m"])

    def step(carry, wx_t):
        carry = _slstm_cell(p, cfg, wx_t, carry)
        return carry, carry[0]

    with jax.named_scope("slstm_rec_scope"):
        # unroll lets XLA read the loop-invariant recurrent matrix R once per
        # unrolled block instead of once per step (8x less R traffic at 8).
        st, hs = jax.lax.scan(step, st, jnp.moveaxis(wx, 1, 0),
                              unroll=cfg.slstm_unroll)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, S, D)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = y * (1.0 + p["norm"].astype(jnp.float32))
    g, v = jnp.split(jnp.einsum("bsd,df->bsf", y.astype(dt_c),
                                p["ff_up"].astype(dt_c)), 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * v, p["ff_down"].astype(dt_c))
    new_state = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
    return y, new_state


def slstm_init_state(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    P = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, P), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": jnp.full((batch, H, P), -30.0)}


def slstm_step(p, u, state, cfg: ModelConfig):
    y, new_state = slstm_apply(p, u, cfg, state)
    return y, new_state
