"""Explicit expert-parallel MoE via shard_map (the collective-lean path).

The dense/GSPMD formulation (``layers.moe_apply``) lets the SPMD partitioner
reshard the (tokens x experts) scatter/gather — measured at ~24 TB of
all-gather/all-reduce per device per step on qwen3 (48L x 8mb). This
implementation pins the data movement by construction:

* tokens are *replicated over the model axis* (they are only batch-sharded),
  so every model shard routes every local token — router flops are tiny;
* each model shard owns ``E / model`` experts and builds a LOCAL
  (E_loc, C_loc, D) dispatch buffer — no collective;
* expert weights are FSDP-sharded on D over the data axis; one explicit
  ``all_gather`` per layer recovers them (grads flow back as psum-scatter);
* the only cross-shard traffic for activations is ONE bf16 ``psum`` of the
  (T_loc, D) combine over the model axis — same size as a TP all-reduce.

Per layer per microbatch: psum(B_loc*S*D*2B) + weight gather — vs the dense
path's token-matrix all-gathers. See EXPERIMENTS.md §Perf cell A.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .config import ModelConfig
from .module import ShardingRules


def _local_moe(xt, router, gate, up, down, *, cfg: ModelConfig, model_axis,
               data_axes, n_model: int):
    """Body runs per (data, model) shard. xt: (T_loc, D) tokens (replicated
    over model). gate/up/down: (E_loc, D_loc, F) FSDP shards."""
    dt = jnp.dtype(cfg.compute_dtype)
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    e_loc = E // n_model
    my_first = jax.lax.axis_index(model_axis) * e_loc

    # FSDP: recover full-D expert weights for the experts this shard owns.
    if data_axes:
        gate = jax.lax.all_gather(gate, data_axes, axis=1, tiled=True)
        up = jax.lax.all_gather(up, data_axes, axis=1, tiled=True)
        down = jax.lax.all_gather(down, data_axes, axis=1, tiled=True)

    t_loc, D = xt.shape
    logits = jnp.einsum("td,de->te", xt, router.astype(dt)).astype(jnp.float32)
    gates, idx = jax.lax.top_k(logits, K)                    # (T_loc, K)
    gates = jax.nn.softmax(gates, axis=-1)

    cap = max(8, int(cfg.capacity_factor * t_loc * K / E))
    flat_e = idx.reshape(-1)                                 # (T_loc*K,)
    rel = flat_e - my_first                                  # local expert id
    mine = (rel >= 0) & (rel < e_loc)
    rel_c = jnp.clip(rel, 0, e_loc - 1)
    onehot = jax.nn.one_hot(rel_c, e_loc, dtype=jnp.int32) * mine[:, None]
    pos = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(pos, rel_c[:, None], axis=1)[:, 0]
    keep = mine & (slot < cap)
    slot = jnp.where(keep, slot, cap - 1)

    src = jnp.repeat(jnp.arange(t_loc), K)
    disp = jnp.zeros((e_loc, cap, D), dt).at[rel_c, slot].add(
        jnp.where(keep[:, None], xt[src], 0).astype(dt), mode="drop")

    g = jnp.einsum("ecd,edf->ecf", disp, gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", disp, up.astype(dt))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, down.astype(dt))

    gathered = out[rel_c, slot] * keep[:, None]              # (T_loc*K, D)
    w = gates.reshape(-1)[:, None].astype(dt)
    partial = (gathered * w).reshape(t_loc, K, D).sum(axis=1)
    return jax.lax.psum(partial, model_axis)                 # (T_loc, D)


def moe_apply_ep(p, x, cfg: ModelConfig, rules: ShardingRules):
    """shard_map expert-parallel MoE. Requires an ambient mesh whose model
    axis divides num_experts; falls back to the dense path otherwise."""
    from repro.compat import get_abstract_mesh

    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        from . import layers as L
        return L.moe_apply_dense(p, x, cfg, rules)
    n_model = mesh.shape["model"]
    if cfg.num_experts % n_model != 0:
        from . import layers as L
        return L.moe_apply_dense(p, x, cfg, rules)

    b, s, D = x.shape
    dt = jnp.dtype(cfg.compute_dtype)
    batch_axes = rules.batch if isinstance(rules.batch, tuple) else (
        (rules.batch,) if rules.batch else ())
    data_axes = rules.embed if rules.embed else None   # FSDP axis of weights

    body = functools.partial(
        _local_moe, cfg=cfg, model_axis="model",
        data_axes=data_axes, n_model=n_model)

    wspec = P("model", rules.embed, None)    # (E, D, F): EP on E, FSDP on D
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes if batch_axes else None, None),
                  P(None, None),            # router replicated (D x E, ~1 MB)
                  wspec, wspec, wspec),
        out_specs=P(batch_axes if batch_axes else None, None),
        check_vma=False,
    )
    xt = x.reshape(b * s, D).astype(dt)
    out = fn(xt, p["router"], p["gate"], p["up"], p["down"])
    return out.reshape(b, s, D)
