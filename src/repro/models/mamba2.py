"""Mamba2 (SSD) mixer: chunked parallel scan for train/prefill, recurrent
step for decode. TPU adaptation: the chunk size is the MXU tile (128), so the
intra-chunk quadratic term and the inter-chunk state propagation are all
dense matmuls; the sequential dimension only appears in a lax.scan over
chunks (S/128 steps), keeping both HLO size and VMEM pressure flat.

State-space parameters follow the Mamba2 paper: per-head scalar decay
``a = -exp(A_log)``, input-dependent ``dt`` (softplus), shared (G=1) B/C
projections of size ``ssm_state``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .module import Creator

_CONV_K = 4


def mamba2_init(c: Creator, cfg: ModelConfig):
    D = cfg.d_model
    di = cfg.d_inner
    H = cfg.resolved_ssm_heads
    N = cfg.ssm_state
    return {
        # order: [z (gate) | x | B | C | dt]
        "in_proj": c("mamba.in", (D, 2 * di + 2 * N + H), ("embed", "heads")),
        "conv": c("mamba.conv", (_CONV_K, di + 2 * N), (None, "heads"), scale=0.5),
        "A_log": c("mamba.A", (H,), (None,), scale="zeros"),
        "D": c("mamba.D", (H,), (None,), scale="ones"),
        "dt_bias": c("mamba.dtb", (H,), (None,), scale="zeros"),
        "norm": c("mamba.norm", (di,), (None,), scale="zeros"),
        "out_proj": c("mamba.out", (di, D), ("heads", "embed")),
    }


def _split(p, cfg, u):
    """in_proj + causal depthwise conv; returns z, x, Bm, Cm, dt, raw xBC."""
    dt_c = jnp.dtype(cfg.compute_dtype)
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.resolved_ssm_heads
    proj = jnp.einsum("bsd,de->bse", u.astype(dt_c), p["in_proj"].astype(dt_c))
    z, xBC_raw, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    # causal depthwise conv over (x|B|C)
    k = p["conv"].astype(dt_c)
    pad = jnp.pad(xBC_raw, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    xBC = sum(pad[:, i:i + xBC_raw.shape[1]] * k[i] for i in range(_CONV_K))
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    return z, x, Bm, Cm, dt, xBC_raw


def _gates(p, cfg, dt):
    a = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return a, dt                                            # dt: (B,S,H)


def mamba2_apply(p, u, cfg: ModelConfig, return_state: bool = False):
    """Chunked SSD forward. u: (B, S, D) -> (B, S, D) (+ final state)."""
    dt_c = jnp.dtype(cfg.compute_dtype)
    B_, S, D = u.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.resolved_ssm_heads
    P = di // H
    Q = cfg.ssm_chunk
    pad = (-S) % Q
    z, x, Bm, Cm, dt, xBC_raw = _split(p, cfg, u)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        # -1e9 -> softplus ~ 0: padded steps neither decay nor feed the state
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
    a, dtf = _gates(p, cfg, dt)                              # dtf (B,S',H)
    Sp = S + pad
    nc = Sp // Q

    xh = x.reshape(B_, nc, Q, H, P).astype(jnp.float32)
    Bh = Bm.reshape(B_, nc, Q, N).astype(jnp.float32)
    Ch = Cm.reshape(B_, nc, Q, N).astype(jnp.float32)
    ad = (a[None, None] * dtf).reshape(B_, nc, Q, H)         # log decay per step
    dtc = dtf.reshape(B_, nc, Q, H)

    def chunk(h, xs):
        xq, bq, cq, adq, dtq = xs                            # (B,Q,...)
        cum = jnp.cumsum(adq, axis=1)                        # (B,Q,H)
        # intra-chunk: L_ij = exp(cum_i - cum_j), i >= j
        diff = cum[:, :, None] - cum[:, None, :]             # (B,Q,Q,H)
        ii = jnp.arange(Q)
        causal = ii[:, None] >= ii[None, :]
        Lm = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cq, bq)              # (B,Q,Q)
        w = cb[..., None] * Lm * dtq[:, None]                # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhnp->bihp", cq, h) * jnp.exp(cum)[..., None]
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # (B,Q,H)
        sb = jnp.einsum("bjn,bjh,bjhp->bhnp", bq, dtq * decay_to_end, xq)
        h = h * jnp.exp(cum[:, -1])[:, :, None, None] + sb
        return h, y_intra + y_inter

    h0 = jnp.zeros((B_, H, N, P), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xh, Bh, Ch, ad, dtc))
    with jax.named_scope("ssd_chunk_scope"):
        h_final, ys = jax.lax.scan(chunk, h0, xs)            # (nc,B,Q,H,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, Sp, H, P)[:, :S]
    y = y + xh.reshape(B_, Sp, H, P)[:, :S] * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B_, S, di)
    # gated RMSNorm then out-proj (mamba2 block tail)
    zf = z.astype(jnp.float32)
    y = y * jax.nn.silu(zf)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = y * (1.0 + p["norm"].astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(dt_c), p["out_proj"].astype(dt_c))
    if return_state:
        tail = xBC_raw[:, -(_CONV_K - 1):].astype(jnp.float32)
        need = _CONV_K - 1 - tail.shape[1]
        if need > 0:
            tail = jnp.pad(tail, ((0, 0), (need, 0), (0, 0)))
        return out, {"h": h_final, "conv": tail}
    return out


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, N = cfg.resolved_ssm_heads, cfg.ssm_state
    P = cfg.d_inner // H
    return {
        "h": jnp.zeros((batch, H, N, P), dtype),
        "conv": jnp.zeros((batch, _CONV_K - 1, cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def mamba2_step(p, u, state, cfg: ModelConfig):
    """Single-token recurrence. u: (B, 1, D). Constant memory in context."""
    dt_c = jnp.dtype(cfg.compute_dtype)
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.resolved_ssm_heads
    P = di // H
    proj = jnp.einsum("bsd,de->bse", u.astype(dt_c), p["in_proj"].astype(dt_c))
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    hist = jnp.concatenate([state["conv"], xBC.astype(jnp.float32)[:, 0:1]], axis=1)
    k = p["conv"].astype(jnp.float32)
    xBC = sum(hist[:, i] * k[i] for i in range(_CONV_K))     # (B, di+2N)
    new_conv = hist[:, 1:]
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    a, dtf = _gates(p, cfg, dt[:, 0])                        # dtf (B,H)
    xh = x.reshape(-1, H, P).astype(jnp.float32)
    decay = jnp.exp(a[None] * dtf)                           # (B,H)
    h = state["h"] * decay[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dtf, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + xh * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(-1, 1, di)
    zf = z.astype(jnp.float32)
    y = y * jax.nn.silu(zf)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = y * (1.0 + p["norm"].astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(dt_c), p["out_proj"].astype(dt_c))
    return out, {"h": h, "conv": new_conv}
