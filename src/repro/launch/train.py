"""End-to-end training driver.

CPU-runnable (reduced configs) and production-lowerable (full configs under
the 512-device mesh — see dryrun.py). Wires together: EventFrame data
pipeline -> packed batches -> jitted train step -> checkpoint manager ->
failure/straggler handling.

  PYTHONPATH=src python -m repro.launch.train --arch eventlm-100m \
      --steps 300 --batch 8 --seq 128 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.eventframe import ACTIVITY
from repro.data import pipeline, synthetic, tokenizer
from repro.models import model as Mdl
from repro.models.module import Initializer, ShardingRules
from repro.train import trainstep as TS
from repro.train.checkpoint import CheckpointManager
from repro.train.ft import FailureInjector, StragglerMonitor
from repro.train.optimizer import OptConfig


def local_rules() -> ShardingRules:
    return ShardingRules(embed=None, vocab=None, heads=None, mlp=None,
                         expert=None, batch=None, seq=None)


def make_data(cfg, batch, seq, num_cases=20000, seed=0, host_id=0, num_hosts=1):
    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=min(cfg.vocab_size - 8, 64),
                                       seed=seed)
    tok = tokenizer.ActivityTokenizer(tables[ACTIVITY])
    stream = pipeline.frame_to_token_stream(frame, tok, host_id, num_hosts)

    def epochs():
        while True:
            yield from pipeline.batches(stream, batch, seq)

    return pipeline.Prefetcher(epochs()), tok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="eventlm-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    rules = local_rules()
    oc = OptConfig(total_steps=max(args.steps, 10), warmup_steps=max(args.steps // 20, 5))

    params = Mdl.init_params(cfg, Initializer(jax.random.PRNGKey(args.seed),
                                              cfg.param_dtype))
    state = TS.init_state(cfg, params)
    step_fn = jax.jit(TS.make_train_step(cfg, rules, oc, args.microbatches),
                      donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir, keep=3, async_save=True) if args.ckpt_dir else None
    start = 0
    if mgr and args.resume:
        got = mgr.restore_latest(state)
        if got[0] is not None:
            start, state = got
            print(f"[train] resumed from step {start}")

    data, tok = make_data(cfg, args.batch, args.seq, seed=args.seed)
    injector = FailureInjector(set(args.fail_at))
    monitor = StragglerMonitor()
    losses = []
    t_start = time.time()
    for step in range(start, args.steps):
        batch = next(data)
        t0 = time.time()
        injector.check(step)
        state, metrics = step_fn(state, {
            "tokens": jnp.asarray(batch.tokens),
            "targets": jnp.asarray(batch.targets),
            "loss_mask": jnp.asarray(batch.loss_mask)})
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if monitor.observe(dt):
            print(f"[train] straggler step {step}: {dt:.2f}s vs ewma {monitor.ewma:.2f}s")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state)
        if step % args.log_every == 0 or step == args.steps - 1:
            tput = args.batch * args.seq / dt
            print(f"[train] step {step} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} {tput:.0f} tok/s", flush=True)
    if mgr:
        mgr.save(args.steps, state)
        mgr.wait()
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"({time.time()-t_start:.1f}s)")
    return losses


if __name__ == "__main__":
    main()
