"""Production mesh + sharding-rule resolution.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (tests/benches must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh
from repro.models.config import ModelConfig
from repro.models.module import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_rules(mesh, cfg: ModelConfig, *, seq_parallel: bool = False) -> ShardingRules:
    """Resolve logical-axis -> mesh-axis rules for this (mesh, arch).

    MoE: experts shard on "model" only when the expert count divides it
    (qwen3: 128/16 ok); otherwise (mixtral: 8 experts) experts stay replicated
    and the expert FFN is TP-sharded on d_ff.
    """
    batch = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    model_size = mesh.shape["model"]
    expert = "model"
    mlp = "model"
    if cfg.num_experts:
        if cfg.num_experts % model_size == 0:
            mlp = None      # EP: experts own the model axis; expert FFN local
        else:
            expert = None   # mixtral: 8 experts < 16 -> replicate experts, TP d_ff
    return ShardingRules(
        embed="data", vocab="model", heads="model", mlp=mlp,
        expert=expert, layers=None,
        seq="model" if seq_parallel else None, batch=batch)


def sanitize_spec(shape: tuple, spec, mesh) -> "P":
    """Drop sharding on dims the mesh cannot divide evenly (vocab 51865,
    batch 1, ...). For tuple entries keep the largest divisible prefix.
    Production frameworks pad instead; for lower+compile analysis dropping is
    equivalent and keeps the numbers honest."""
    from jax.sharding import PartitionSpec as P
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for n in names:
            if dim % (prod * mesh.shape[n]) == 0:
                kept.append(n)
                prod *= mesh.shape[n]
            else:
                break
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def sanitize_specs(abstract_tree, spec_tree, mesh):
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(
        lambda a, s: sanitize_spec(a.shape, s, mesh),
        abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P))
