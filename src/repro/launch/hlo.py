"""Multiplicity-aware HLO accounting for the roofline terms.

``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE, which
under-counts a 95-layer scanned transformer by ~100x. This module parses the
post-SPMD HLO text instead and weights every op by its *execution
multiplicity* (product of enclosing loop trip counts):

* computations are split by header; ``while`` ops carry ``body=%B`` /
  ``condition=%C``; the trip count is the limit constant in the condition
  (scan induction always starts at 0);
* **dot FLOPs**: ``2 * numel(result) * contracted_size`` per dot, weighted —
  the compute term (matmul-dominated; elementwise flops are memory-bound and
  accounted by the bytes term);
* **HBM bytes**: for ops at loop-body/entry level (fusion internals excluded
  — a fusion reads its operands and writes its result through HBM exactly
  once), operand+result bytes, weighted;
* **collective bytes**: result bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, weighted.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# Ops that stream HBM on TPU. Excluded on purpose: copy / broadcast /
# transpose / reshape / get-tuple-element — XLA:TPU aliases or fuses these
# (the CPU HLO text keeps loop-carried copies that no real backend executes),
# counting them inflates the memory term ~50-100x.
_HBM_OPS = {
    "dot", "fusion", "convolution", "reduce", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "select-and-scatter", "sort",
    "rng", "cholesky", "triangular-solve", "reduce-window", "pad", "iota",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"^\(?\s*(\w+)\[([\d,]*)\]")
_TUPLE_SHAPES_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPNAME_RE = re.compile(r"^(?:\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+([\w\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    kind: str
    dtype: str
    dims: str
    rhs: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list = field(default_factory=list)


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        h = _HEADER_RE.match(line)
        if h and line.endswith("{"):
            cur = Computation(h.group(2), bool(h.group(1)))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.groups()
        sm = _SHAPE_RE.match(rhs)
        dtype, dims = (sm.group(1), sm.group(2)) if sm else ("", "")
        om = _OPNAME_RE.match(rhs)
        kind = om.group(1) if om else ""
        cur.ops.append(Op(name, kind, dtype, dims, rhs))
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop limit = the constant in the condition (induction starts at 0)."""
    consts = []
    for op in cond.ops:
        consts += [int(c) for c in _CONST_RE.findall(op.rhs)]
    return max(consts) if consts else 1


def multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution count per computation; fusion-internal comps get the parent
    multiplicity but are flagged separately by callers via `fusion_called`."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult = {c: 0.0 for c in comps}
    fusion_called: set[str] = set()
    if entry is None:
        return mult, fusion_called

    # build edges
    while_edges: dict[str, list[tuple[str, int]]] = {c: [] for c in comps}
    call_edges: dict[str, list[str]] = {c: [] for c in comps}
    for c in comps.values():
        for op in c.ops:
            wm = _WHILE_RE.search(op.rhs)
            if wm:
                cond_name, body_name = wm.groups()
                trip = _trip_count(comps[cond_name]) if cond_name in comps else 1
                while_edges[c.name].append((body_name, trip))
                call_edges[c.name].append(cond_name)  # cond runs trip+1, ~trip
                continue
            for m in _CALLS_RE.finditer(op.rhs):
                if m.group(1) in comps:
                    call_edges[c.name].append(m.group(1))
                    fusion_called.add(m.group(1))
            for m in _TO_APPLY_RE.finditer(op.rhs):
                if m.group(1) in comps:
                    fusion_called.add(m.group(1))

    # BFS from entry
    mult[entry.name] = 1.0
    frontier = [entry.name]
    seen_edges = set()
    while frontier:
        cn = frontier.pop()
        for body, trip in while_edges[cn]:
            if (cn, body) in seen_edges:
                continue
            seen_edges.add((cn, body))
            if body in mult:
                mult[body] += mult[cn] * trip
                frontier.append(body)
        for callee in call_edges[cn]:
            if (cn, callee, "c") in seen_edges:
                continue
            seen_edges.add((cn, callee, "c"))
            mult[callee] += mult[cn]
            frontier.append(callee)
    return mult, fusion_called


def _operand_names(rhs: str) -> list[str]:
    m = _OPERANDS_RE.search(rhs[rhs.find(" "):] if " " in rhs else rhs)
    # take the first (...) after the op name — operands list
    om = _OPNAME_RE.match(rhs)
    if not om:
        return []
    tail = rhs[om.end():]
    m = _OPERANDS_RE.search(tail)
    if not m:
        return []
    out = []
    for part in m.group(1).split(","):
        part = part.strip()
        if part.startswith("%"):
            out.append(part[1:])
        else:
            toks = part.split("%")
            if len(toks) > 1:
                out.append(toks[-1].strip())
    return out


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_param_reads(comp: Computation) -> dict[int, int]:
    """Bytes actually READ per parameter index inside a fusion computation.

    A scan body receives the full stacked (L, ...) parameter tensor but a
    dynamic-slice inside the fusion reads one layer's slice; counting the
    full operand inflates HBM traffic by ~L*mb. For every parameter that is
    consumed (only) through dynamic-slice / dynamic-update-slice, charge the
    slice/update bytes instead of the full tensor.
    """
    idx_of: dict[str, int] = {}
    for op in comp.ops:
        m = _PARAM_IDX_RE.search(op.rhs)
        if op.kind == "parameter" and m:
            idx_of[op.name] = int(m.group(1))
    sliced: dict[int, int] = {}
    full_use: set[int] = set()
    shapes = {op.name: (op.dtype, op.dims) for op in comp.ops}
    for op in comp.ops:
        if op.kind == "parameter":
            continue
        operands = _operand_names(op.rhs)
        for pos, o in enumerate(operands):
            if o not in idx_of:
                continue
            i = idx_of[o]
            if op.kind == "dynamic-slice" and pos == 0:
                sliced[i] = sliced.get(i, 0) + _shape_bytes(op.dtype, op.dims)
            elif op.kind == "dynamic-update-slice" and pos == 0:
                upd = operands[1] if len(operands) > 1 else None
                b = _shape_bytes(*shapes[upd]) if upd in shapes else 0
                sliced[i] = sliced.get(i, 0) + b
            else:
                full_use.add(i)
    return {i: b for i, b in sliced.items() if i not in full_use}


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    mult, fusion_called = multiplicities(comps)

    # name -> (dtype, dims) across all comps for operand resolution
    shapes: dict[str, tuple[str, str]] = {}
    for c in comps.values():
        for op in c.ops:
            shapes[op.name] = (op.dtype, op.dims)

    # fusion-computation name -> {param_idx: bytes actually read}
    fusion_reads: dict[str, dict[int, int]] = {
        name: _fusion_param_reads(c) for name, c in comps.items()}

    dot_flops = 0.0
    hbm_bytes = 0.0
    coll_bytes = 0.0
    coll_by_op: dict[str, float] = {}
    coll_counts: dict[str, int] = {}
    # HBM bytes attributable to kernel-fusable scopes (flash attention, SSD
    # chunks, mLSTM chunks): on TPU these live in VMEM inside a Pallas
    # kernel; the XLA-only lowering streams them. Reported separately so the
    # roofline can show both the XLA baseline and the kernelized projection.
    scope_bytes: dict[str, float] = {}
    scope_re = re.compile(r'op_name="[^"]*?(\w+_scope)')

    for c in comps.values():
        w = mult.get(c.name, 0.0)
        if w <= 0:
            continue
        body_level = c.name not in fusion_called
        for op in c.ops:
            if op.kind == "dot":
                cm = _CONTRACT_RE.search(op.rhs)
                operands = _operand_names(op.rhs)
                csize = 1
                if cm and operands and operands[0] in shapes:
                    ldims = shapes[operands[0]][1].split(",")
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(ldims) and ldims[int(ci)]:
                            csize *= int(ldims[int(ci)])
                dot_flops += w * 2.0 * _shape_numel(op.dims) * csize
            base = op.kind.replace("-start", "")
            if base in _COLL_OPS and not op.kind.endswith("-done"):
                if op.dims or op.dtype:
                    b = _shape_bytes(op.dtype, op.dims)
                else:  # tuple result
                    b = sum(_shape_bytes(d, s)
                            for d, s in _TUPLE_SHAPES_RE.findall(op.rhs.split(base)[0]))
                coll_bytes += w * b
                coll_by_op[base] = coll_by_op.get(base, 0.0) + w * b
                coll_counts[base] = coll_counts.get(base, 0) + 1
            if body_level and op.kind in _HBM_OPS:
                b = _shape_bytes(op.dtype, op.dims) if op.dims or op.dtype else 0
                operands = _operand_names(op.rhs)
                reads = None
                if op.kind == "fusion":
                    cm = _CALLS_RE.search(op.rhs)
                    if cm and cm.group(1) in fusion_reads:
                        reads = fusion_reads[cm.group(1)]
                if op.kind == "dynamic-slice":
                    # read = the slice (result), not the sliced-into tensor
                    b += _shape_bytes(op.dtype, op.dims)
                    operands = []
                elif op.kind == "dynamic-update-slice":
                    upd = operands[1] if len(operands) > 1 else None
                    b += 2 * (_shape_bytes(*shapes[upd]) if upd in shapes else 0)
                    operands = []
                for pos, o in enumerate(operands):
                    if o not in shapes:
                        continue
                    if reads is not None and pos in reads:
                        b += reads[pos]
                    else:
                        b += _shape_bytes(*shapes[o])
                hbm_bytes += w * b
                if op.kind != "dot":  # dots stay in the kernelized projection
                    sm = scope_re.search(op.rhs)
                    if sm:
                        scope_bytes[sm.group(1)] = scope_bytes.get(sm.group(1), 0.0) + w * b

    return {"dot_flops": dot_flops, "hbm_bytes": hbm_bytes,
            "collective_bytes": coll_bytes, "coll_by_op": coll_by_op,
            "coll_counts": coll_counts, "scope_bytes": scope_bytes}


def collective_bytes(hlo_text: str) -> dict:
    """Back-compat wrapper used by dryrun: multiplicity-weighted collectives."""
    a = analyze(hlo_text)
    return {"total": a["collective_bytes"], "by_op": a["coll_by_op"],
            "counts": a["coll_counts"]}
