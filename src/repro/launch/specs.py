"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns (abstract_inputs, partition_specs) for the
given (arch, input-shape) cell. Modality frontends are STUBS: the audio/vlm
entries provide precomputed frame/patch embeddings, per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import Shape
from repro.models import model as Mdl
from repro.models.config import ModelConfig
from repro.models.module import ShardingRules


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: Shape, rules: ShardingRules):
    B, S = shape.batch, shape.seq
    toks = S
    batch = {}
    specs = {}
    if cfg.family == "vlm":
        toks = S - cfg.num_patches
        batch["frontend"] = _sds((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        specs["frontend"] = P(rules.batch, None, None)
    if cfg.family == "audio":
        batch["frontend"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        specs["frontend"] = P(rules.batch, None, None)
    batch["tokens"] = _sds((B, toks), jnp.int32)
    batch["targets"] = _sds((B, toks if cfg.family != "vlm" else toks), jnp.int32)
    batch["loss_mask"] = _sds(batch["targets"].shape, jnp.float32)
    for k in ("tokens", "targets", "loss_mask"):
        specs[k] = P(rules.batch, None)
    return batch, specs


def prefill_specs(cfg: ModelConfig, shape: Shape, rules: ShardingRules):
    B, S = shape.batch, shape.seq
    toks = S - (cfg.num_patches if cfg.family == "vlm" else 0)
    inputs = {"tokens": _sds((B, toks), jnp.int32)}
    specs = {"tokens": P(rules.batch, None)}
    if cfg.family == "vlm":
        inputs["frontend"] = _sds((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        specs["frontend"] = P(rules.batch, None, None)
    if cfg.family == "audio":
        inputs["frontend"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        specs["frontend"] = P(rules.batch, None, None)
    return inputs, specs


def decode_specs(cfg: ModelConfig, shape: Shape, rules: ShardingRules):
    """decode_* cells: one new token with a KV cache of seq_len."""
    B, S = shape.batch, shape.seq
    cache = Mdl.init_cache(cfg, B, S, abstract=True)
    cspecs = Mdl.cache_specs(cfg, rules)
    inputs = {"cache": cache, "tokens": _sds((B, 1), jnp.int32)}
    specs = {"cache": cspecs, "tokens": P(rules.batch, None)}
    return inputs, specs
