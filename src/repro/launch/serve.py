"""Serving driver: batched next-activity serving on a trained checkpoint.

  PYTHONPATH=src python -m repro.launch.serve --arch eventlm-100m --reduced \
      --ckpt-dir /path/to/ckpts --requests 16 --steps 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.eventframe import ACTIVITY
from repro.data import pipeline, synthetic, tokenizer
from repro.models import model as Mdl
from repro.models.module import Initializer
from repro.serve.engine import Engine
from repro.train.checkpoint import CheckpointManager
from repro.launch.train import local_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="eventlm-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = Mdl.init_params(cfg, Initializer(jax.random.PRNGKey(args.seed),
                                              cfg.param_dtype))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        step, state = mgr.restore_latest({"params": params})
        if step is not None:
            params = state["params"]
            print(f"[serve] restored step {step} from {args.ckpt_dir}")

    frame, tables = synthetic.generate(num_cases=2_000,
                                       num_activities=min(cfg.vocab_size - 8, 32),
                                       seed=args.seed)
    tok = tokenizer.ActivityTokenizer(tables[ACTIVITY])
    stream = pipeline.frame_to_token_stream(frame, tok)
    prompts = np.stack([stream[i * 37:i * 37 + args.prompt_len]
                        for i in range(args.requests)])

    engine = Engine(cfg, params, max_len=args.max_len)
    t0 = time.time()
    out = engine.generate(prompts, steps=args.steps)
    dt = time.time() - t0
    total = args.requests * args.steps
    print(f"[serve] {args.requests} requests x {args.steps} tokens "
          f"in {dt:.2f}s = {total/dt:.1f} tok/s (incl. prefill + compile)")
    for r in range(min(3, args.requests)):
        print(f"  req {r}: ...{' '.join(tok.decode(prompts[r])[-3:])} => "
              f"{' '.join(tok.decode(out.tokens[r]))}")
    return out


if __name__ == "__main__":
    main()
