import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Per cell this script:
  1. builds the production mesh (16x16 or 2x16x16),
  2. lowers the right step fn (train_step / prefill / decode_step) against
     ShapeDtypeStruct inputs with full NamedShardings,
  3. compiles, prints memory_analysis() (proves it fits) and cost_analysis()
     (FLOPs/bytes for the roofline),
  4. parses the HLO for collective operand bytes,
  5. appends a JSON record to --out.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # every runnable cell
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, cells
from repro.launch import specs as SP
from repro.launch.hlo import analyze
from repro.launch.mesh import (make_production_mesh, make_rules,
                               sanitize_spec, sanitize_specs)
from repro.models import model as Mdl
from repro.train import trainstep as TS
from repro.train.optimizer import OptConfig


def shard_tree(mesh, abstract_tree, spec_tree):
    """Sanitize (divisibility) then wrap in NamedShardings."""
    clean = sanitize_specs(abstract_tree, spec_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), clean,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_rules(rules, mesh, global_batch):
    """Shrink the activation batch axes to what the batch size divides."""
    names = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    kept, prod = [], 1
    for n in names:
        if n and global_batch % (prod * mesh.shape[n]) == 0:
            kept.append(n)
            prod *= mesh.shape[n]
        else:
            break
    import dataclasses as _dc
    return _dc.replace(rules, batch=tuple(kept) if kept else None)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               overrides: dict | None = None, num_microbatches: int = 8):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # Baseline production knobs: sequence-parallel activations for training
    # (saved residuals shard over the model axis -> 16x less live activation
    # memory under scan+remat); serving stays batch/seq-cache sharded.
    if shape.kind == "train":
        cfg = cfg.with_overrides(seq_parallel=True)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, cfg, seq_parallel=cfg.seq_parallel)
    rules = _batch_rules(rules, mesh, shape.batch)

    from repro.compat import set_mesh

    with set_mesh(mesh):
        if shape.kind == "train":
            oc = OptConfig()
            step = TS.make_train_step(cfg, rules, oc, num_microbatches)
            state = TS.abstract_state(cfg)
            sspecs = TS.state_specs(cfg, rules)
            batch, bspecs = SP.train_batch_specs(cfg, shape, rules)
            fn = jax.jit(step,
                         in_shardings=(shard_tree(mesh, state, sspecs),
                                       shard_tree(mesh, batch, bspecs)),
                         out_shardings=(shard_tree(mesh, state, sspecs), None),
                         donate_argnums=(0,))
            lowered = fn.lower(state, batch)
        elif shape.kind == "prefill":
            scfg = cfg.with_overrides(param_dtype="bfloat16")
            params = Mdl.abstract_params(scfg)
            pspecs = Mdl.param_specs(scfg, rules)
            inputs, ispecs = SP.prefill_specs(scfg, shape, rules)

            def fn(params, inputs):
                return Mdl.prefill(scfg, params, inputs["tokens"], rules=rules,
                                   frontend=inputs.get("frontend"))

            lowered = jax.jit(
                fn,
                in_shardings=(shard_tree(mesh, params, pspecs),
                              shard_tree(mesh, inputs, ispecs)),
            ).lower(params, inputs)
        else:  # decode
            scfg = cfg.with_overrides(param_dtype="bfloat16")
            params = Mdl.abstract_params(scfg)
            pspecs = Mdl.param_specs(scfg, rules)
            inputs, ispecs = SP.decode_specs(scfg, shape, rules)
            cache_sh = shard_tree(mesh, inputs["cache"], ispecs["cache"])

            def fn(params, cache, tokens):
                return Mdl.decode_step(scfg, params, cache, tokens, rules=rules)

            lowered = jax.jit(
                fn,
                in_shardings=(shard_tree(mesh, params, pspecs),
                              cache_sh,
                              NamedSharding(mesh, sanitize_spec(
                                  (shape.batch, 1), ispecs["tokens"], mesh))),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params, inputs["cache"], inputs["tokens"])
    return cfg, mesh, lowered


# Per-arch baseline microbatch counts (train cells): chosen so the activation
# working set fits 16 GiB HBM at global batch 256 x 4k.
TRAIN_MICROBATCHES = {"deepseek-67b": 16}


def run_cell(arch, shape_name, *, multi_pod, out_path=None, overrides=None,
             num_microbatches=8, tag="baseline"):
    num_microbatches = TRAIN_MICROBATCHES.get(arch, num_microbatches)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag}
    try:
        cfg, mesh, lowered = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                        overrides=overrides,
                                        num_microbatches=num_microbatches)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # old jax: one dict per device kind
            ca = ca[0] if ca else {}
        # multiplicity-aware HLO accounting (lax.scan bodies x trip count) —
        # XLA's own cost_analysis counts loop bodies once (kept as *_xla).
        acct = analyze(compiled.as_text())
        rec.update(
            ok=True, lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            flops_per_device=acct["dot_flops"],
            bytes_per_device=acct["hbm_bytes"],
            collective_bytes_per_device=acct["collective_bytes"],
            collectives=acct["coll_by_op"],
            collective_counts=acct["coll_counts"],
            scope_bytes=acct["scope_bytes"],
            flops_xla_bodyonce=ca.get("flops", 0.0),
            bytes_xla_bodyonce=ca.get("bytes accessed", 0.0),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
        )
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']} OK "
              f"compile={rec['compile_s']}s flops/dev={rec['flops_per_device']:.3e} "
              f"mem(temp)={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"coll={acct['collective_bytes']/2**20:.1f}MiB", flush=True)
    except Exception as e:  # a failing cell is a bug in the system
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']} FAIL {rec['error']}",
              flush=True)
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf iterations)")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    overrides = json.loads(args.override) if args.override else None

    if args.all:
        ok = True
        for arch in ARCH_IDS:
            for shape_name in cells(arch):
                rec = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                               out_path=args.out, overrides=overrides,
                               num_microbatches=args.microbatches, tag=args.tag)
                ok &= rec["ok"]
        raise SystemExit(0 if ok else 1)

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   out_path=args.out, overrides=overrides,
                   num_microbatches=args.microbatches, tag=args.tag)
    raise SystemExit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
