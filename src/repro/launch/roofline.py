"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh), all **per device** (cost_analysis is
post-SPMD):

    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per token — the
"useful flops" yardstick that catches remat/redundancy waste, and the
roofline fraction = useful_time / max(term)s.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (~per chip, 1 link active)

TRAIN_FLOP_MULT = 3.0        # fwd + bwd = 3x forward matmul flops


def tokens_of(shape_name: str) -> int:
    from repro.configs.shapes import SHAPES
    s = SHAPES[shape_name]
    if s.kind == "train" or s.kind == "prefill":
        return s.batch * s.seq
    return s.batch                           # decode: one token per sequence


def analyze_record(rec: dict, chips: int) -> dict:
    from repro.configs.shapes import SHAPES
    shape = SHAPES[rec["shape"]]
    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    t_mem = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    n_active = rec.get("active_params", rec.get("params", 0))
    mult = TRAIN_FLOP_MULT if shape.kind == "train" else 1.0
    useful = 2.0 * n_active * tokens_of(rec["shape"]) * mult  # 2ND fwd (6ND train)
    useful_per_dev = useful / chips
    hlo_flops = max(rec["flops_per_device"], 1.0)
    t_bound = max(terms.values())
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "bottleneck": bottleneck,
        "model_flops_per_dev": useful_per_dev,
        "useful_ratio": useful_per_dev / hlo_flops,
        "roofline_fraction": (useful_per_dev / PEAK_FLOPS) / t_bound if t_bound else 0.0,
        "step_time_bound_s": t_bound,
    }


def load(path: str, mesh: str | None = None, tag: str = "baseline"):
    recs = []
    seen = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if not r.get("ok"):
                continue
            if mesh and r["mesh"] != mesh:
                continue
            if tag and r.get("tag", "baseline") != tag:
                continue
            seen[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    return list(seen.values())


def table(path: str, mesh: str = "16x16", tag: str = "baseline") -> list[dict]:
    chips = 512 if mesh == "2x16x16" else 256
    rows = []
    for r in load(path, mesh, tag):
        a = analyze_record(r, chips)
        rows.append({**r, **a})
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def render(rows: list[dict]) -> str:
    hdr = (f"{'arch':<20} {'shape':<12} {'bottleneck':<11} "
           f"{'t_comp(ms)':>10} {'t_mem(ms)':>10} {'t_coll(ms)':>10} "
           f"{'useful%':>8} {'roofline%':>9}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(
            f"{r['arch']:<20} {r['shape']:<12} {r['bottleneck']:<11} "
            f"{r['t_compute']*1e3:>10.2f} {r['t_memory']*1e3:>10.2f} "
            f"{r['t_collective']*1e3:>10.2f} {r['useful_ratio']*100:>7.1f}% "
            f"{r['roofline_fraction']*100:>8.1f}%")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = table(args.inp, args.mesh, args.tag)
    print(render(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
