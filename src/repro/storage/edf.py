"""EDF — a columnar event-log container (the Parquet/ORC role of the paper).

Three on-disk layouts share one reader:

EDFV0001 (legacy, whole-column blocks)::

    [8B magic "EDFV0001"] [4B header_len] [header json] [column blocks...]

EDFV0002 (row groups — the out-of-core layout)::

    [8B magic "EDFV0002"] [4B header_len] [header json]
    [group 0: column blocks...] [group 1: column blocks...] ...

The v2 header carries the column schema once (name, dtype, kind
numeric | dict, dictionary tables) plus per-group, per-column byte extents,
so a reader can stream one row group at a time with **column projection** —
only the requested columns' byte ranges of the current group are read and
decoded (the paper's "attribute selection at load time", now also bounded in
*rows*). Per-column compression (raw | zlib1 | zlib6 | zlib9) exploits type
homogeneity exactly as Parquet does (Snappy ~ zlib1, Gzip ~ zlib9).

EDFV0003 (current: v2 + per-group **zone maps**) keeps the v2 byte layout
and adds three header-only aggregates per row group, the statistics the
``repro.query`` planner prunes scans with (Parquet's column-index /
ORC-stripe-statistics role):

* ``zones``    — per column: min / max over the group's stored values,
  ``nulls`` (epsilon count), and for dictionary columns a packed *presence
  bitset* of the dictionary ids that occur in the group, so a predicate
  like ``activity == "pay"`` can refute a group exactly;
* ``segments`` — number of distinct contiguous case segments in the group
  (a (case,time)-sorted log makes this the case count), which lets a pruned
  scan advance global segment numbering across skipped groups without
  reading them;
* ``tail``     — the last row's values (+ epsilon flags): the one-row halo
  ``repro.core.engine`` carries across chunk boundaries, persisted so a
  skipped group can still hand the correct carry to its successor;
* ``sketch``   — per case segment, the uint32 affine polyhash coefficients
  ``(mul, add)`` of the segment's activity run (``repro.core.polyhash``),
  hex-encoded ``<u4`` bands keyed ``mul1/add1/mul2/add2``.  Affine maps
  compose, so the query layer rebuilds the exact variant-hash carry of any
  skipped run — and whole-dataset variant fingerprints — from headers
  alone, which is what lets ``variants`` prune like every other verb.

All three are synthesized on open for v1/v2 files (one streaming pass — a
compatibility fallback, not a fast path), so the query layer treats every
EDF file uniformly.  ``read`` loads any version whole; ``read_streaming`` /
``read_group`` are the chunk sources for
``repro.core.chunked.ChunkedEventFrame``; :class:`EDFReader` is the cached
random-access view the query planner uses.

**Append-only growth** (:func:`append`): new rows become new row groups at
the end of the data region; the header — the only part of the file that
references them — is rewritten through a temp file + ``os.replace``, so a
concurrent reader sees either the old file or the new one, never a torn
mix, and a reader holding an open handle keeps a consistent snapshot of
the version it opened.  The old groups' bytes are copied verbatim, so
their content signatures (:meth:`EDFReader.group_signature`) — and with
them every cached per-group fold in ``repro.query.statecache`` — survive
the append untouched.

Every written header leads with a ``stamp``: a content hash of the rest
of the header, placed first so :func:`header_tag` can read it from the
file's first bytes without parsing the (possibly large) header JSON.
``(st_mtime_ns, st_size, stamp)`` — :func:`file_sig` — is the staleness
signature the reader pool and the result memo key on: a rewrite that
lands within one mtime tick at the same size still changes the stamp.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import struct
import threading
import zlib
from collections import OrderedDict
from contextlib import contextmanager
from typing import Iterable, Mapping

import numpy as np

from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from repro.core.polyhash import SKETCH_KEYS, segment_sketch

MAGIC = b"EDFV0001"          # legacy, still readable
MAGIC_V2 = b"EDFV0002"       # row groups, no zone maps — still readable
MAGIC_V3 = b"EDFV0003"
CODECS = ("raw", "zlib1", "zlib6", "zlib9")

# dictionary presence bitsets are only recorded for tables up to this size
# (a 4096-entry alphabet packs to 512 bytes of header per column per group)
MAX_BITSET_TABLE = 4096


def _encode(buf: bytes, codec: str) -> bytes:
    if codec == "raw":
        return buf
    if codec.startswith("zlib"):
        return zlib.compress(buf, int(codec[4:]))
    raise ValueError(f"unknown codec {codec!r}")


def _decode(buf: bytes, codec: str) -> bytes:
    if not buf:
        # zero-byte extent (e.g. an empty trailing row group written by
        # another producer) — nothing to decompress
        return b""
    return buf if codec == "raw" else zlib.decompress(buf)


def _scalar(x):
    """A JSON-safe Python scalar preserving the stored value exactly
    (``float(np.float32)`` is the exact binary64 widening of the float32)."""
    return int(x) if np.issubdtype(np.asarray(x).dtype, np.integer) else float(x)


def _json_safe(obj):
    """Recursively convert numpy scalars/arrays so ``json.dumps`` yields a
    canonical, content-only encoding (group signatures hash this)."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (bool, int, float, str)) or obj is None:
        return obj
    return str(obj)


def _stamp_header(header: dict) -> bytes:
    """Serialize a header with a leading content ``stamp`` key.

    The stamp hashes the canonical header content and is emitted as the
    *first* key of the JSON object, so :func:`header_tag` can recover it
    from the first few dozen bytes of the file without parsing a
    possibly-megabyte header.
    """
    body = {k: v for k, v in header.items() if k != "stamp"}
    blob = json.dumps(_json_safe(body), sort_keys=True).encode()
    stamp = hashlib.sha1(blob).hexdigest()[:16]
    return json.dumps({"stamp": stamp, **body}).encode()


_TAG_NEEDLE = b'{"stamp": "'


def header_tag(path: str) -> str:
    """Content tag of a file's header — O(1) bytes for stamped files.

    Every file this module writes leads its header with a ``stamp`` key
    (see :func:`_stamp_header`), recovered here from the file's first
    bytes.  Files from other producers fall back to hashing up to 64 KiB
    of the header itself — still content-sensitive, just not O(1).
    """
    with open(path, "rb") as f:
        head = f.read(12 + 64)
        if len(head) < 12 or head[:8] not in (MAGIC, MAGIC_V2, MAGIC_V3):
            raise ValueError(f"{path!r} is not an EDF file")
        (hlen,) = struct.unpack("<I", head[8:12])
        body = head[12:12 + min(hlen, 64)]
        if body.startswith(_TAG_NEEDLE):
            end = body.find(b'"', len(_TAG_NEEDLE))
            if end > 0:
                return body[len(_TAG_NEEDLE):end].decode()
        f.seek(12)
        return hashlib.sha1(f.read(min(hlen, 65536))).hexdigest()[:16]


def file_sig(path: str) -> tuple[int, int, str]:
    """Staleness signature ``(st_mtime_ns, st_size, header_tag)``.

    The stat pair catches ordinary rewrites cheaply; the header tag
    catches the pathological one — a same-size rewrite landing within a
    single mtime tick — so a cached reader (or a memoized result keyed on
    this signature) can never serve bytes from a file it didn't read.
    """
    st = os.stat(path)
    return (st.st_mtime_ns, st.st_size, header_tag(path))


class StaleFileError(ValueError):
    """An EDF file changed on disk under a cached-header reader.

    Subclasses ``ValueError`` so existing callers that guarded the stat
    check keep working; the mining service catches this specifically to
    re-resolve its snapshot and retry.
    """


def _group_aux(data: Mapping[str, np.ndarray], valid: Mapping[str, np.ndarray],
               tables: Mapping[str, list], lo: int, hi: int) -> dict:
    """Zone maps + segment count + tail halo for rows ``[lo, hi)``.

    Shared between the v3 writer and the on-open synthesis fallback for
    v1/v2 files (there ``lo=0, hi=nrows`` of one loaded group).
    """
    zones: dict[str, dict] = {}
    for name in sorted(data):
        arr = data[name][lo:hi]
        z: dict = {"nulls": 0}
        if name in valid:
            z["nulls"] = int((~np.asarray(valid[name][lo:hi], bool)).sum())
        if arr.size:
            z["min"] = _scalar(arr.min())
            z["max"] = _scalar(arr.max())
            table = tables.get(name)
            if table is not None and len(table) <= MAX_BITSET_TABLE:
                present = np.zeros(len(table), bool)
                ids = arr[(arr >= 0) & (arr < len(table))].astype(np.int64)
                present[ids] = True
                z["bits"] = np.packbits(present).tobytes().hex()
        zones[name] = z
    aux: dict = {"zones": zones}
    if hi > lo:
        if CASE in data:
            case = data[CASE][lo:hi]
            aux["segments"] = int((case[1:] != case[:-1]).sum()) + 1
            if ACTIVITY in data:
                sk = segment_sketch(data[ACTIVITY][lo:hi], case)
                aux["sketch"] = {k: sk[k].astype("<u4").tobytes().hex()
                                 for k in SKETCH_KEYS}
        aux["tail"] = {
            "values": {name: _scalar(data[name][hi - 1]) for name in sorted(data)},
            "valid": {name: bool(valid[name][hi - 1]) for name in sorted(valid)},
        }
    return aux


# ------------------------------------------------------------------ write
def _write_v1(path: str, frame: EventFrame, tables, codec: str) -> dict:
    """Legacy whole-column layout (kept for back-compat round-trip tests)."""
    cols = []
    blobs = []
    offset = 0
    data = frame.to_numpy()
    valid = {k: np.asarray(v) for k, v in frame.valid.items()}
    for name in sorted(data):
        arr = np.ascontiguousarray(data[name])
        raw = arr.tobytes()
        enc = _encode(raw, codec)
        meta = {
            "name": name, "dtype": str(arr.dtype), "codec": codec,
            "offset": offset, "nbytes": len(enc), "raw_nbytes": len(raw),
            "kind": "dict" if name in tables else "numeric",
        }
        if name in tables:
            meta["table"] = list(tables[name])
        if name in valid:
            venc = _encode(np.packbits(valid[name]).tobytes(), codec)
            meta["valid_offset"] = offset + len(enc)
            meta["valid_nbytes"] = len(venc)
            blobs.append(enc + venc)
            offset += len(enc) + len(venc)
        else:
            blobs.append(enc)
            offset += len(enc)
        cols.append(meta)
    header = {"nrows": frame.nrows, "columns": cols}
    hjson = _stamp_header(header)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)
    return header


def write(path: str, frame: EventFrame, tables: Mapping[str, list] | None = None,
          codec: str = "zlib1", row_group_rows: int | None = None,
          version: int = 3) -> dict:
    """Serialize an EventFrame. Returns the header (for size accounting).

    ``row_group_rows`` splits the rows into groups of that size (the unit of
    streaming reads); ``None`` writes a single group.  ``version=3`` (the
    default) additionally records per-group zone maps / segment counts /
    tail halos in the header (byte layout identical to v2); ``version=2``
    and ``version=1`` emit the older layouts for back-compat round-trips.
    """
    tables = dict(tables or {})
    if version == 1:
        if row_group_rows is not None:
            raise ValueError("row groups need version>=2")
        return _write_v1(path, frame, tables, codec)
    if version not in (2, 3):
        raise ValueError(f"unknown EDF version {version!r}")

    data = {k: np.ascontiguousarray(v) for k, v in frame.to_numpy().items()}
    valid = {k: np.asarray(v) for k, v in frame.valid.items()}
    nrows = frame.nrows
    if row_group_rows is not None and int(row_group_rows) <= 0:
        raise ValueError("row_group_rows must be positive")
    # a zero-row frame still writes one (empty) row group, so the schema,
    # dictionary tables, and validity flags round-trip through read/
    # read_streaming exactly like any other frame
    step = max(nrows, 1) if row_group_rows is None else int(row_group_rows)
    bounds = list(range(0, nrows, step)) or [0]

    schema = []
    for name in sorted(data):
        meta = {"name": name, "dtype": str(data[name].dtype), "codec": codec,
                "kind": "dict" if name in tables else "numeric"}
        if name in tables:
            meta["table"] = list(tables[name])
        if name in valid:
            meta["has_valid"] = True
        schema.append(meta)

    groups, blobs = _encode_groups(data, valid, tables, bounds, step, nrows,
                                   codec, version)

    header = {"version": version, "nrows": nrows, "codec": codec,
              "columns": schema, "groups": groups}
    hjson = _stamp_header(header)
    with open(path, "wb") as f:
        f.write(MAGIC_V3 if version >= 3 else MAGIC_V2)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)
    return header


def _encode_groups(data, valid, tables, bounds, step, nrows, codec, version,
                   offset: int = 0):
    """Encode rows ``[lo, lo+step)`` per bound into row-group metadata +
    blobs.  Shared between :func:`write` (``offset=0``) and :func:`append`
    (``offset`` = current data-region size, so the new groups' extents
    continue where the file ends)."""
    groups = []
    blobs = []
    for lo in bounds:
        hi = min(lo + step, nrows)
        gcols = {}
        for name in sorted(data):
            raw = data[name][lo:hi].tobytes()
            enc = _encode(raw, codec)
            ext = {"offset": offset, "nbytes": len(enc), "raw_nbytes": len(raw)}
            blobs.append(enc)
            offset += len(enc)
            if name in valid:
                venc = _encode(np.packbits(valid[name][lo:hi]).tobytes(), codec)
                ext["valid_offset"] = offset
                ext["valid_nbytes"] = len(venc)
                blobs.append(venc)
                offset += len(venc)
            gcols[name] = ext
        group = {"nrows": hi - lo, "columns": gcols}
        if version >= 3:
            group.update(_group_aux(data, valid, tables, lo, hi))
        groups.append(group)
    return groups, blobs


# ----------------------------------------------------------------- append
_APPEND_LOCKS: dict[str, threading.Lock] = {}
_APPEND_LOCKS_GUARD = threading.Lock()


def _append_lock(path: str) -> threading.Lock:
    key = os.path.abspath(path)
    with _APPEND_LOCKS_GUARD:
        lock = _APPEND_LOCKS.get(key)
        if lock is None:
            lock = _APPEND_LOCKS[key] = threading.Lock()
        return lock


def append(path: str, frame: EventFrame,
           tables: Mapping[str, list] | None = None,
           row_group_rows: int | None = None) -> dict:
    """Append ``frame``'s rows to an existing v2/v3 EDF file, atomically.

    The new rows become new row groups at the end of the data region;
    the rewritten header (with extended zone maps / segment counts / tail
    halos / sketch bands for the fresh groups) goes through a temp file +
    ``os.replace``, so a concurrent reader observes either the old file or
    the new one — never a torn mix — and a reader holding an open handle
    keeps reading its consistent pre-append snapshot via the old inode.
    Old groups are copied verbatim: their content signatures
    (:meth:`EDFReader.group_signature`), and therefore every cached
    per-group fold, stay valid.

    Constraints enforced:

    * the frame's schema (column names, dtypes, validity flags) must match
      the file's;
    * dictionary ``tables`` may only *extend* the file's (old ids keep
      their meaning; pass the merged tables when the alphabet grew);
    * the file stays (case, time)-sorted case-major: the appended frame
      must be case-sorted and start at/after the file's tail case.

    ``row_group_rows=None`` writes the whole frame as one new group.
    Returns the new header.  Thread-safe per path within this process;
    cross-process writers need external coordination.
    """
    with _append_lock(path):
        return _append_locked(path, frame, tables, row_group_rows)


def _append_locked(path, frame, tables, row_group_rows):
    header, base = read_header(path)
    version = header["version"]
    if version < 2:
        raise ValueError(
            f"append needs the row-group layout (EDFV0002+); {path!r} is v1")
    if frame.nrows == 0:
        return header
    codec = header.get("codec", "raw")
    old_tables = _tables_from_schema(header)
    schema = {c["name"]: c for c in header["columns"]}
    tables = dict(tables) if tables is not None else dict(old_tables)

    data = {k: np.ascontiguousarray(v) for k, v in frame.to_numpy().items()}
    valid = {k: np.asarray(v) for k, v in frame.valid.items()}

    if set(data) != set(schema):
        raise ValueError(
            f"appended frame columns {sorted(data)} != file schema "
            f"{sorted(schema)}")
    for name, meta in schema.items():
        if str(data[name].dtype) != meta["dtype"]:
            raise ValueError(
                f"column {name!r}: appended dtype {data[name].dtype} != "
                f"file dtype {meta['dtype']}")
        if bool(meta.get("has_valid")) != (name in valid):
            raise ValueError(
                f"column {name!r}: validity flags must match the file")
    for name, old in old_tables.items():
        new = list(tables.get(name, old))
        if new[:len(old)] != list(old):
            raise ValueError(
                f"column {name!r}: dictionary table may only extend the "
                "file's (old ids must keep their meaning)")
        if len(new) > len(old):
            schema[name]["table"] = new
        tables[name] = new

    if CASE in data:
        case = data[CASE]
        if case.size > 1 and bool(np.any(case[1:] < case[:-1])):
            raise ValueError("appended frame must be case-sorted "
                             "(case-major, like the file)")
        tail = (header["groups"][-1].get("tail") or {}).get("values", {}) \
            if header["groups"] else {}
        if CASE in tail and case.size and case[0] < tail[CASE]:
            raise ValueError(
                f"appended rows start at case {int(case[0])} < the file's "
                f"tail case {int(tail[CASE])}; appends must not reopen "
                "earlier cases")

    nrows = frame.nrows
    if row_group_rows is not None and int(row_group_rows) <= 0:
        raise ValueError("row_group_rows must be positive")
    step = nrows if row_group_rows is None else int(row_group_rows)
    data_size = os.path.getsize(path) - base
    groups, blobs = _encode_groups(data, valid, tables,
                                   list(range(0, nrows, step)), step, nrows,
                                   codec, version, offset=data_size)
    header["groups"] = list(header["groups"]) + groups
    header["nrows"] = int(header["nrows"]) + nrows
    hjson = _stamp_header(header)

    tmp = f"{path}.append.{os.getpid()}.tmp"
    try:
        with open(tmp, "wb") as out, open(path, "rb") as src:
            out.write(MAGIC_V3 if version >= 3 else MAGIC_V2)
            out.write(struct.pack("<I", len(hjson)))
            out.write(hjson)
            src.seek(base)
            shutil.copyfileobj(src, out, 1 << 20)   # old groups, verbatim
            for b in blobs:
                out.write(b)
            out.flush()
            os.fsync(out.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass
    return header


# ------------------------------------------------------------------- read
def read_header(path: str) -> tuple[dict, int]:
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic in (MAGIC, MAGIC_V2, MAGIC_V3), "not an EDF file"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        header.setdefault("version",
                          {MAGIC: 1, MAGIC_V2: 2, MAGIC_V3: 3}[magic])
        return header, 12 + hlen


def num_row_groups_header(header: dict) -> int:
    return len(header["groups"]) if header.get("version", 1) >= 2 else 1


def num_row_groups(path: str) -> int:
    header, _ = read_header(path)
    return num_row_groups_header(header)


def _tables_from_schema(header: dict) -> dict[str, list]:
    return {c["name"]: c["table"] for c in header["columns"] if "table" in c}


def _fetch_group_v2(f, base: int, header: dict, group: dict, want
                    ) -> list[tuple]:
    """Raw (still-compressed) byte extents of one group's projected
    columns — the only step that touches the shared file handle.  Kept
    separate from :func:`_decode_group_v2` so a reader can hold its I/O
    lock for the seek/read pairs only and decompress outside it (what
    lets a background prefetch thread decode group g+1 while another
    thread/device works on group g)."""
    fetched: list[tuple] = []
    codec = header.get("codec", "raw")
    for meta in header["columns"]:
        name = meta["name"]
        if want is not None and name not in want:
            continue
        ext = group["columns"][name]
        ccodec = meta.get("codec", codec)
        f.seek(base + ext["offset"])
        raw = f.read(ext["nbytes"])
        vraw = None
        if "valid_offset" in ext:
            f.seek(base + ext["valid_offset"])
            vraw = f.read(ext["valid_nbytes"])
        fetched.append((meta, ccodec, raw, vraw))
    return fetched


def _decode_group_v2(fetched: list[tuple], gn: int) -> EventFrame:
    """Decompress + deserialize fetched extents (no file handle needed)."""
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    for meta, ccodec, raw, vraw in fetched:
        name = meta["name"]
        buf = _decode(raw, ccodec)
        cols[name] = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])).copy()
        if vraw is not None:
            valid[name] = np.unpackbits(
                np.frombuffer(_decode(vraw, ccodec), np.uint8),
                count=gn).astype(bool)
    return EventFrame.from_numpy(cols, valid)


def _read_group_v2(f, base: int, header: dict, group: dict, want):
    return _decode_group_v2(_fetch_group_v2(f, base, header, group, want),
                            group["nrows"])


def _read_v1(path: str, columns):
    header, base = read_header(path)
    want = set(columns) if columns is not None else None
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    tables: dict[str, list] = {}
    nrows = header["nrows"]
    with open(path, "rb") as f:
        for meta in header["columns"]:
            name = meta["name"]
            if want is not None and name not in want:
                continue
            f.seek(base + meta["offset"])
            raw = _decode(f.read(meta["nbytes"]), meta["codec"])
            cols[name] = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).copy()
            if "valid_offset" in meta:
                f.seek(base + meta["valid_offset"])
                vraw = _decode(f.read(meta["valid_nbytes"]), meta["codec"])
                valid[name] = np.unpackbits(
                    np.frombuffer(vraw, np.uint8), count=nrows).astype(bool)
            if "table" in meta:
                tables[name] = meta["table"]
    return EventFrame.from_numpy(cols, valid), tables


def read(path: str, columns: Iterable[str] | None = None
         ) -> tuple[EventFrame, dict[str, list]]:
    """Load an EventFrame; ``columns`` projects at read time (partial I/O).

    Reads both EDF versions; v2 row groups are concatenated.
    """
    header, base = read_header(path)
    if header["version"] == 1:
        return _read_v1(path, columns)
    want = set(columns) if columns is not None else None
    parts = []
    with open(path, "rb") as f:
        for group in header["groups"]:
            parts.append(_read_group_v2(f, base, header, group, want))
    names = parts[0].names if parts else ()
    cols = {k: np.concatenate([np.asarray(p.columns[k]) for p in parts])
            for k in names}
    valid = {k: np.concatenate([np.asarray(p.valid[k]) for p in parts])
             for k in (parts[0].valid if parts else {})}
    tables = _tables_from_schema(header)
    if want is not None:
        tables = {k: v for k, v in tables.items() if k in want}
    return EventFrame.from_numpy(cols, valid), tables


def read_group(path: str, index: int, columns: Iterable[str] | None = None
               ) -> tuple[EventFrame, dict[str, list]]:
    """Load a single row group (partial I/O in both rows and columns)."""
    header, base = read_header(path)
    if header["version"] == 1:
        if index != 0:
            raise IndexError("EDFV0001 has a single row group")
        return _read_v1(path, columns)
    group = header["groups"][index]
    want = set(columns) if columns is not None else None
    with open(path, "rb") as f:
        frame = _read_group_v2(f, base, header, group, want)
    return frame, _tables_from_schema(header)


def read_streaming(path: str, columns: Iterable[str] | None = None):
    """Yield ``(EventFrame, tables)`` per row group — one group resident at
    a time. EDFV0001 files degrade to a single chunk."""
    header, base = read_header(path)
    if header["version"] == 1:
        yield _read_v1(path, columns)
        return
    want = set(columns) if columns is not None else None
    tables = _tables_from_schema(header)
    with open(path, "rb") as f:
        for group in header["groups"]:
            yield _read_group_v2(f, base, header, group, want), tables


def file_sizes(path: str) -> dict:
    """Per-column compressed/raw byte accounting (Table 2 style).

    ``total`` equals ``os.path.getsize(path)`` exactly: magic + header +
    every column extent *including* the packed validity bitmaps.  ``raw``
    is the uncompressed size of the column data.  ``groups`` is the
    per-row-group breakdown (``nrows`` / ``nbytes`` / per-column bytes)
    the query planner's skip-ratio reporting sums over; v1 files expose
    their single whole-column block as one pseudo-group.
    """
    header, base = read_header(path)
    out: dict = {"total": base, "raw": 0, "header": base}
    groups: list[dict] = []
    if header["version"] == 1:
        gcols = {}
        for c in header["columns"]:
            gcols[c["name"]] = c["nbytes"] + c.get("valid_nbytes", 0)
            out["raw"] += c["raw_nbytes"]
        groups.append({"nrows": header["nrows"],
                       "nbytes": sum(gcols.values()), "columns": gcols})
    else:
        for group in header["groups"]:
            gcols = {}
            for name, ext in group["columns"].items():
                gcols[name] = ext["nbytes"] + ext.get("valid_nbytes", 0)
                out["raw"] += ext["raw_nbytes"]
            groups.append({"nrows": group["nrows"],
                           "nbytes": sum(gcols.values()), "columns": gcols})
    per_col: dict[str, int] = {c["name"]: 0 for c in header["columns"]}
    for g in groups:
        for name, nb in g["columns"].items():
            per_col[name] += nb
        out["total"] += g["nbytes"]
    out.update(per_col)
    out["groups"] = groups
    return out


# ---------------------------------------------------------------- reader
class EDFReader:
    """Cached-header random access to an EDF file — the query planner's view.

    One header parse serves every ``read_group`` / ``group_meta`` /
    ``group_nbytes`` call.  ``group_meta`` returns the zone-map / segment /
    tail metadata of a row group: for EDFV0003 files straight from the
    header (no data I/O); for v1/v2 files it is synthesized by loading each
    group once on first access (a compatibility fallback — correct pruning,
    but the synthesis pass itself reads the data it would later skip).
    """

    def __init__(self, path: str):
        self.path = path
        self.header, self.base = read_header(path)
        self.version: int = self.header["version"]
        self.tables = _tables_from_schema(self.header)
        self.schema = {c["name"]: c for c in self.header["columns"]}
        self.column_names = tuple(sorted(self.schema))
        self.nrows: int = self.header["nrows"]
        self._synth: list[dict] | None = None   # v1/v2 metadata cache
        self._synth_lock = threading.Lock()     # one synthesis per group
        self._sketch: dict[int, dict] = {}      # decoded/synthesized sketches
        self._gsig: dict[int, str] = {}         # per-group content signatures
        self._file = None                       # persistent handle (lazy)
        self._io_lock = threading.Lock()        # seek/read pairs are shared
        self._pins = 0                          # pin() snapshot holds
        self._close_deferred = False            # close() arrived while pinned
        # sig must describe the header actually cached above: if an append
        # raced between the header read and the sig read, take it again
        # (the pool would otherwise evict this reader on first revalidation)
        sig = file_sig(path)
        if sig[2] != self.header.get("stamp", sig[2]):
            self.header, self.base = read_header(path)
            sig = file_sig(path)
        self._sig = sig

    # --------------------------------------------------------- file handle
    def _check_sig(self) -> None:
        """Re-validate before touching bytes with no open handle: decoding
        a rewritten file against the cached header would return garbage, so
        it fails loudly instead.  The check is content-aware
        (:func:`file_sig`), so even a same-stat rewrite is caught."""
        if file_sig(self.path) != self._sig:
            raise StaleFileError(
                f"{self.path!r} changed on disk since this reader cached "
                f"its header; get a fresh reader via pooled_reader()")

    def _fh(self):
        """The persistent read handle, reopened transparently if the reader
        was closed (or evicted from a :class:`ReaderPool`) between uses —
        what makes pruned-scan sources safely re-iterable."""
        if self._file is None or self._file.closed:
            self._check_sig()
            self._file = open(self.path, "rb")
        return self._file

    @property
    def closed(self) -> bool:
        return self._file is None or self._file.closed

    def close(self) -> None:
        """Release the file handle. The reader stays usable: the next read
        reopens the handle (the header is already cached).  While a
        :meth:`pin` is active the close is deferred to the last unpin —
        pool eviction must never yank a pinned snapshot's handle."""
        with self._io_lock:             # never yank the handle mid-read
            if self._pins > 0:
                self._close_deferred = True
                return
            if self._file is not None and not self._file.closed:
                self._file.close()

    @contextmanager
    def pin(self):
        """Hold this reader's snapshot open for the duration of a request.

        Opens the persistent handle eagerly (raising
        :class:`StaleFileError` now rather than mid-scan if the file
        already changed) and defers any ``close()`` — including
        :class:`ReaderPool` eviction — until the last pin is released.
        Because :func:`append` replaces the *path*, never the inode, a
        pinned reader keeps reading its consistent pre-append snapshot
        even while appends land.
        """
        with self._io_lock:
            self._fh()                  # validate + open before pinning
            self._pins += 1
        try:
            yield self
        finally:
            with self._io_lock:
                self._pins -= 1
                if self._pins == 0 and self._close_deferred:
                    self._close_deferred = False
                    if self._file is not None and not self._file.closed:
                        self._file.close()

    def __enter__(self) -> "EDFReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def num_groups(self) -> int:
        return num_row_groups_header(self.header)

    def _groups(self) -> list[dict]:
        if self.version == 1:
            # present the single whole-column block as one pseudo-group
            return [{"nrows": self.nrows, "columns": {
                c["name"]: c for c in self.header["columns"]}}]
        return self.header["groups"]

    def group_nrows(self, index: int) -> int:
        return self._groups()[index]["nrows"]

    def read_group(self, index: int, columns: Iterable[str] | None = None
                   ) -> EventFrame:
        if self.version == 1:
            if index != 0:
                raise IndexError("EDFV0001 has a single row group")
            self._check_sig()           # v1 re-opens per read: same guard
            return _read_v1(self.path, columns)[0]
        group = self.header["groups"][index]
        want = set(columns) if columns is not None else None
        # one handle serves every plan over this file (ReaderPool); its
        # seek/read pairs must not interleave across threads — but the
        # decompression happens *outside* the lock, so concurrent scans
        # (or a prefetch thread) of the same pooled reader decode in
        # parallel instead of serializing on the handle
        with self._io_lock:
            fetched = _fetch_group_v2(self._fh(), self.base, self.header,
                                      group, want)
        return _decode_group_v2(fetched, group["nrows"])

    def group_meta(self, index: int) -> dict:
        """``{"nrows", "zones", "segments"?, "tail"?}`` for one row group."""
        group = self._groups()[index]
        if "zones" in group:
            return group
        # v1/v2 synthesis fallback: serialized so two threads planning over
        # the same pooled reader synthesize each group exactly once
        with self._synth_lock:
            if self._synth is None:
                self._synth = [dict() for _ in range(self.num_groups)]
            if not self._synth[index]:
                frame = self.read_group(index)
                data = {k: np.asarray(v) for k, v in frame.columns.items()}
                valid = {k: np.asarray(v) for k, v in frame.valid.items()}
                meta = {"nrows": frame.nrows}
                meta.update(_group_aux(data, valid, self.tables, 0,
                                       frame.nrows))
                self._synth[index] = meta
            return self._synth[index]

    def group_sketch(self, index: int) -> dict[str, np.ndarray] | None:
        """Per-segment affine polyhash maps of one row group, as
        ``{"mul1","add1","mul2","add2"}`` uint32 arrays (one entry per case
        segment), or ``None`` when the group has no case/activity columns.

        EDFV0003 files written with the sketch band decode it straight from
        the header; older v3 files (and the v1/v2 synthesis path) fall back
        to a one-time two-column ``(activity, case)`` read per group, cached
        under ``_synth_lock`` exactly like the zone-map synthesis.
        """
        cached = self._sketch.get(index)
        if cached is not None:
            return cached
        meta = self.group_meta(index)       # v1/v2: synthesizes sketch too
        if "sketch" in meta:
            sk = {k: np.frombuffer(bytes.fromhex(meta["sketch"][k]), "<u4")
                  for k in SKETCH_KEYS}
        elif ("segments" in meta and ACTIVITY in self.schema
                and CASE in self.schema):
            # v3 file from before the sketch band: synthesize lazily from a
            # projected read of just the two id columns
            with self._synth_lock:
                cached = self._sketch.get(index)
                if cached is not None:
                    return cached
                frame = self.read_group(index, (ACTIVITY, CASE))
                sk = segment_sketch(np.asarray(frame.columns[ACTIVITY]),
                                    np.asarray(frame.columns[CASE]))
        else:
            return None
        self._sketch[index] = sk
        return sk

    def group_signature(self, index: int) -> str:
        """Stable, content-derived signature of one row group.

        Hashes the group's *content* metadata — row count, zone maps,
        segment count, tail halo, variant sketch bands, and per-column
        byte sizes — but never byte offsets.  An append that adds new
        groups and rewrites the header therefore keeps the signatures of
        untouched groups stable, which is exactly what lets the
        group-state cache (``repro.query.statecache``) reuse their folded
        states while only fresh groups are decoded.
        """
        cached = self._gsig.get(index)
        if cached is not None:
            return cached
        meta = self.group_meta(index)
        group = self._groups()[index]
        payload = {
            "nrows": meta.get("nrows"),
            "zones": meta.get("zones"),
            "segments": meta.get("segments"),
            "tail": meta.get("tail"),
            "sketch": meta.get("sketch"),
            "columns": sorted(
                (name, int(ext.get("nbytes", 0)),
                 int(ext.get("valid_nbytes", 0)))
                for name, ext in group.get("columns", {}).items()
                if isinstance(ext, dict)),
        }
        blob = json.dumps(_json_safe(payload), sort_keys=True, default=str)
        sig = hashlib.sha1(blob.encode()).hexdigest()[:16]
        self._gsig[index] = sig
        return sig

    def group_nbytes(self, index: int, columns: Iterable[str] | None = None
                     ) -> int:
        """On-disk bytes of one group restricted to ``columns`` (data +
        validity bitmap extents — what a projected read actually touches)."""
        group = self._groups()[index]
        want = set(columns) if columns is not None else None
        total = 0
        for name, ext in group["columns"].items():
            if want is not None and name not in want:
                continue
            total += ext["nbytes"] + ext.get("valid_nbytes", 0)
        return total


# ------------------------------------------------------------ reader pool
class ReaderPool:
    """Shared cache of :class:`EDFReader` instances, keyed by path.

    A multi-file dataset compiles one plan per file and may re-iterate each
    pruned scan several times (phase-one passes, benchmarks, dashboards); the
    pool gives all of them the *same* cached-header reader per file — one
    header parse, one v1/v2 metadata synthesis, one open handle.  Entries
    are validated against :func:`file_sig` — ``(mtime_ns, size, header
    tag)`` — on every ``get``, so a file rewritten in place (including an
    :func:`append`, and even a same-stat rewrite) is picked up fresh;
    least-recently-used readers beyond ``capacity`` are closed (not
    invalidated — a plan still holding an evicted reader keeps working
    because :meth:`EDFReader._fh` reopens; a *pinned* reader defers the
    close until its request finishes).
    """

    def __init__(self, capacity: int = 16):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._readers: OrderedDict[str, EDFReader] = OrderedDict()
        self._lock = threading.Lock()   # get/evict race across threads

    def get(self, path: str) -> EDFReader:
        key = os.path.abspath(path)
        sig = file_sig(key)
        evicted = []
        with self._lock:
            reader = self._readers.get(key)
            if reader is not None and reader._sig != sig:
                evicted.append(reader)         # stale: the file changed
                reader = None
            if reader is None:
                reader = EDFReader(key)
                self._readers[key] = reader
            self._readers.move_to_end(key)
            while len(self._readers) > self.capacity:
                _, old = self._readers.popitem(last=False)
                evicted.append(old)
        for old in evicted:                    # close() takes the reader's
            old.close()                        # io lock — never mid-read
        return reader

    def close(self) -> None:
        """Close every pooled handle (readers reopen lazily if reused)."""
        with self._lock:
            readers, self._readers = list(self._readers.values()), \
                OrderedDict()
        for reader in readers:
            reader.close()

    def __len__(self) -> int:
        with self._lock:
            return len(self._readers)


_POOL = ReaderPool()


def reader_pool() -> ReaderPool:
    """The process-wide pool the query planner draws readers from."""
    return _POOL


def pooled_reader(path: str) -> EDFReader:
    """Shared cached-header reader for ``path`` (see :class:`ReaderPool`)."""
    return _POOL.get(path)
