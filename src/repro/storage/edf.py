"""EDF — a columnar event-log container (the Parquet/ORC role of the paper).

Two on-disk layouts share one reader:

EDFV0001 (legacy, whole-column blocks)::

    [8B magic "EDFV0001"] [4B header_len] [header json] [column blocks...]

EDFV0002 (current, row groups — the out-of-core layout)::

    [8B magic "EDFV0002"] [4B header_len] [header json]
    [group 0: column blocks...] [group 1: column blocks...] ...

The v2 header carries the column schema once (name, dtype, kind
numeric | dict, dictionary tables) plus per-group, per-column byte extents,
so a reader can stream one row group at a time with **column projection** —
only the requested columns' byte ranges of the current group are read and
decoded (the paper's "attribute selection at load time", now also bounded in
*rows*). Per-column compression (raw | zlib1 | zlib6 | zlib9) exploits type
homogeneity exactly as Parquet does (Snappy ~ zlib1, Gzip ~ zlib9).

``read`` loads any version whole; ``read_streaming`` / ``read_group`` are
the chunk sources for ``repro.core.chunked.ChunkedEventFrame``.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Iterable, Mapping

import numpy as np

from repro.core.eventframe import EventFrame

MAGIC = b"EDFV0001"          # legacy, still readable
MAGIC_V2 = b"EDFV0002"
CODECS = ("raw", "zlib1", "zlib6", "zlib9")


def _encode(buf: bytes, codec: str) -> bytes:
    if codec == "raw":
        return buf
    if codec.startswith("zlib"):
        return zlib.compress(buf, int(codec[4:]))
    raise ValueError(f"unknown codec {codec!r}")


def _decode(buf: bytes, codec: str) -> bytes:
    if not buf:
        # zero-byte extent (e.g. an empty trailing row group written by
        # another producer) — nothing to decompress
        return b""
    return buf if codec == "raw" else zlib.decompress(buf)


# ------------------------------------------------------------------ write
def _write_v1(path: str, frame: EventFrame, tables, codec: str) -> dict:
    """Legacy whole-column layout (kept for back-compat round-trip tests)."""
    cols = []
    blobs = []
    offset = 0
    data = frame.to_numpy()
    valid = {k: np.asarray(v) for k, v in frame.valid.items()}
    for name in sorted(data):
        arr = np.ascontiguousarray(data[name])
        raw = arr.tobytes()
        enc = _encode(raw, codec)
        meta = {
            "name": name, "dtype": str(arr.dtype), "codec": codec,
            "offset": offset, "nbytes": len(enc), "raw_nbytes": len(raw),
            "kind": "dict" if name in tables else "numeric",
        }
        if name in tables:
            meta["table"] = list(tables[name])
        if name in valid:
            venc = _encode(np.packbits(valid[name]).tobytes(), codec)
            meta["valid_offset"] = offset + len(enc)
            meta["valid_nbytes"] = len(venc)
            blobs.append(enc + venc)
            offset += len(enc) + len(venc)
        else:
            blobs.append(enc)
            offset += len(enc)
        cols.append(meta)
    header = {"nrows": frame.nrows, "columns": cols}
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)
    return header


def write(path: str, frame: EventFrame, tables: Mapping[str, list] | None = None,
          codec: str = "zlib1", row_group_rows: int | None = None,
          version: int = 2) -> dict:
    """Serialize an EventFrame. Returns the header (for size accounting).

    ``row_group_rows`` splits the rows into groups of that size (the unit of
    streaming reads); ``None`` writes a single group. ``version=1`` emits
    the legacy layout.
    """
    tables = dict(tables or {})
    if version == 1:
        if row_group_rows is not None:
            raise ValueError("row groups need version=2")
        return _write_v1(path, frame, tables, codec)
    if version != 2:
        raise ValueError(f"unknown EDF version {version!r}")

    data = {k: np.ascontiguousarray(v) for k, v in frame.to_numpy().items()}
    valid = {k: np.asarray(v) for k, v in frame.valid.items()}
    nrows = frame.nrows
    if row_group_rows is not None and int(row_group_rows) <= 0:
        raise ValueError("row_group_rows must be positive")
    # a zero-row frame still writes one (empty) row group, so the schema,
    # dictionary tables, and validity flags round-trip through read/
    # read_streaming exactly like any other frame
    step = max(nrows, 1) if row_group_rows is None else int(row_group_rows)
    bounds = list(range(0, nrows, step)) or [0]

    schema = []
    for name in sorted(data):
        meta = {"name": name, "dtype": str(data[name].dtype), "codec": codec,
                "kind": "dict" if name in tables else "numeric"}
        if name in tables:
            meta["table"] = list(tables[name])
        if name in valid:
            meta["has_valid"] = True
        schema.append(meta)

    groups = []
    blobs = []
    offset = 0
    for lo in bounds:
        hi = min(lo + step, nrows)
        gcols = {}
        for name in sorted(data):
            raw = data[name][lo:hi].tobytes()
            enc = _encode(raw, codec)
            ext = {"offset": offset, "nbytes": len(enc), "raw_nbytes": len(raw)}
            blobs.append(enc)
            offset += len(enc)
            if name in valid:
                venc = _encode(np.packbits(valid[name][lo:hi]).tobytes(), codec)
                ext["valid_offset"] = offset
                ext["valid_nbytes"] = len(venc)
                blobs.append(venc)
                offset += len(venc)
            gcols[name] = ext
        groups.append({"nrows": hi - lo, "columns": gcols})

    header = {"version": 2, "nrows": nrows, "codec": codec,
              "columns": schema, "groups": groups}
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(MAGIC_V2)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)
    return header


# ------------------------------------------------------------------- read
def read_header(path: str) -> tuple[dict, int]:
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic in (MAGIC, MAGIC_V2), "not an EDF file"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        header.setdefault("version", 1 if magic == MAGIC else 2)
        return header, 12 + hlen


def num_row_groups_header(header: dict) -> int:
    return len(header["groups"]) if header.get("version", 1) == 2 else 1


def num_row_groups(path: str) -> int:
    header, _ = read_header(path)
    return num_row_groups_header(header)


def _tables_from_schema(header: dict) -> dict[str, list]:
    return {c["name"]: c["table"] for c in header["columns"] if "table" in c}


def _read_group_v2(f, base: int, header: dict, group: dict, want):
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    codec = header.get("codec", "raw")
    gn = group["nrows"]
    for meta in header["columns"]:
        name = meta["name"]
        if want is not None and name not in want:
            continue
        ext = group["columns"][name]
        ccodec = meta.get("codec", codec)
        f.seek(base + ext["offset"])
        raw = _decode(f.read(ext["nbytes"]), ccodec)
        cols[name] = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).copy()
        if "valid_offset" in ext:
            f.seek(base + ext["valid_offset"])
            vraw = _decode(f.read(ext["valid_nbytes"]), ccodec)
            valid[name] = np.unpackbits(
                np.frombuffer(vraw, np.uint8), count=gn).astype(bool)
    return EventFrame.from_numpy(cols, valid)


def _read_v1(path: str, columns):
    header, base = read_header(path)
    want = set(columns) if columns is not None else None
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    tables: dict[str, list] = {}
    nrows = header["nrows"]
    with open(path, "rb") as f:
        for meta in header["columns"]:
            name = meta["name"]
            if want is not None and name not in want:
                continue
            f.seek(base + meta["offset"])
            raw = _decode(f.read(meta["nbytes"]), meta["codec"])
            cols[name] = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).copy()
            if "valid_offset" in meta:
                f.seek(base + meta["valid_offset"])
                vraw = _decode(f.read(meta["valid_nbytes"]), meta["codec"])
                valid[name] = np.unpackbits(
                    np.frombuffer(vraw, np.uint8), count=nrows).astype(bool)
            if "table" in meta:
                tables[name] = meta["table"]
    return EventFrame.from_numpy(cols, valid), tables


def read(path: str, columns: Iterable[str] | None = None
         ) -> tuple[EventFrame, dict[str, list]]:
    """Load an EventFrame; ``columns`` projects at read time (partial I/O).

    Reads both EDF versions; v2 row groups are concatenated.
    """
    header, base = read_header(path)
    if header["version"] == 1:
        return _read_v1(path, columns)
    want = set(columns) if columns is not None else None
    parts = []
    with open(path, "rb") as f:
        for group in header["groups"]:
            parts.append(_read_group_v2(f, base, header, group, want))
    names = parts[0].names if parts else ()
    cols = {k: np.concatenate([np.asarray(p.columns[k]) for p in parts])
            for k in names}
    valid = {k: np.concatenate([np.asarray(p.valid[k]) for p in parts])
             for k in (parts[0].valid if parts else {})}
    tables = _tables_from_schema(header)
    if want is not None:
        tables = {k: v for k, v in tables.items() if k in want}
    return EventFrame.from_numpy(cols, valid), tables


def read_group(path: str, index: int, columns: Iterable[str] | None = None
               ) -> tuple[EventFrame, dict[str, list]]:
    """Load a single row group (partial I/O in both rows and columns)."""
    header, base = read_header(path)
    if header["version"] == 1:
        if index != 0:
            raise IndexError("EDFV0001 has a single row group")
        return _read_v1(path, columns)
    group = header["groups"][index]
    want = set(columns) if columns is not None else None
    with open(path, "rb") as f:
        frame = _read_group_v2(f, base, header, group, want)
    return frame, _tables_from_schema(header)


def read_streaming(path: str, columns: Iterable[str] | None = None):
    """Yield ``(EventFrame, tables)`` per row group — one group resident at
    a time. EDFV0001 files degrade to a single chunk."""
    header, base = read_header(path)
    if header["version"] == 1:
        yield _read_v1(path, columns)
        return
    want = set(columns) if columns is not None else None
    tables = _tables_from_schema(header)
    with open(path, "rb") as f:
        for group in header["groups"]:
            yield _read_group_v2(f, base, header, group, want), tables


def file_sizes(path: str) -> dict:
    """Per-column compressed/raw byte accounting (Table 2 style)."""
    header, _ = read_header(path)
    out = {"total": 0, "raw": 0}
    if header["version"] == 1:
        for c in header["columns"]:
            out["total"] += c["nbytes"]
            out["raw"] += c["raw_nbytes"]
            out[c["name"]] = c["nbytes"]
        return out
    per_col = {c["name"]: 0 for c in header["columns"]}
    for group in header["groups"]:
        for name, ext in group["columns"].items():
            per_col[name] += ext["nbytes"]
            out["total"] += ext["nbytes"]
            out["raw"] += ext["raw_nbytes"]
    out.update(per_col)
    return out
