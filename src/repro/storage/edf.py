"""EDF — a columnar event-log container (the Parquet/ORC role of the paper).

Layout::

    [8B magic "EDFV0001"] [4B header_len] [header json] [column blocks...]

The header carries, per column: name, dtype, kind (numeric | dict), codec
(raw | zlib1 | zlib6 | zlib9), byte offset and compressed/raw sizes, plus the
dictionary tables of dict-encoded (string) columns. Reading supports
**column projection** — only the requested columns' byte ranges are read and
decoded (the paper's "attribute selection at load time"), and per-column
compression exploits type homogeneity exactly as Parquet does (Snappy ~
zlib1, Gzip ~ zlib9 in our codec ladder).
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Iterable, Mapping

import numpy as np

from repro.core.eventframe import EventFrame

MAGIC = b"EDFV0001"
CODECS = ("raw", "zlib1", "zlib6", "zlib9")


def _encode(buf: bytes, codec: str) -> bytes:
    if codec == "raw":
        return buf
    if codec.startswith("zlib"):
        return zlib.compress(buf, int(codec[4:]))
    raise ValueError(f"unknown codec {codec!r}")


def _decode(buf: bytes, codec: str) -> bytes:
    return buf if codec == "raw" else zlib.decompress(buf)


def write(path: str, frame: EventFrame, tables: Mapping[str, list] | None = None,
          codec: str = "zlib1") -> dict:
    """Serialize an EventFrame. Returns the header (for size accounting)."""
    tables = tables or {}
    cols = []
    blobs = []
    offset = 0
    data = frame.to_numpy()
    valid = {k: np.asarray(v) for k, v in frame.valid.items()}
    for name in sorted(data):
        arr = np.ascontiguousarray(data[name])
        raw = arr.tobytes()
        enc = _encode(raw, codec)
        meta = {
            "name": name, "dtype": str(arr.dtype), "codec": codec,
            "offset": offset, "nbytes": len(enc), "raw_nbytes": len(raw),
            "kind": "dict" if name in tables else "numeric",
        }
        if name in tables:
            meta["table"] = list(tables[name])
        if name in valid:
            venc = _encode(np.packbits(valid[name]).tobytes(), codec)
            meta["valid_offset"] = offset + len(enc)
            meta["valid_nbytes"] = len(venc)
            blobs.append(enc + venc)
            offset += len(enc) + len(venc)
        else:
            blobs.append(enc)
            offset += len(enc)
        cols.append(meta)
    header = {"nrows": frame.nrows, "columns": cols}
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)
    return header


def read_header(path: str) -> dict:
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "not an EDF file"
        (hlen,) = struct.unpack("<I", f.read(4))
        return json.loads(f.read(hlen)), 12 + hlen


def read(path: str, columns: Iterable[str] | None = None
         ) -> tuple[EventFrame, dict[str, list]]:
    """Load an EventFrame; ``columns`` projects at read time (partial I/O)."""
    header, base = read_header(path)
    want = set(columns) if columns is not None else None
    cols: dict[str, np.ndarray] = {}
    valid: dict[str, np.ndarray] = {}
    tables: dict[str, list] = {}
    nrows = header["nrows"]
    with open(path, "rb") as f:
        for meta in header["columns"]:
            name = meta["name"]
            if want is not None and name not in want:
                continue
            f.seek(base + meta["offset"])
            raw = _decode(f.read(meta["nbytes"]), meta["codec"])
            cols[name] = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).copy()
            if "valid_offset" in meta:
                f.seek(base + meta["valid_offset"])
                vraw = _decode(f.read(meta["valid_nbytes"]), meta["codec"])
                valid[name] = np.unpackbits(
                    np.frombuffer(vraw, np.uint8), count=nrows).astype(bool)
            if "table" in meta:
                tables[name] = meta["table"]
    return EventFrame.from_numpy(cols, valid), tables


def file_sizes(path: str) -> dict:
    """Per-column compressed/raw byte accounting (Table 2 style)."""
    header, _ = read_header(path)
    out = {"total": sum(c["nbytes"] for c in header["columns"]),
           "raw": sum(c["raw_nbytes"] for c in header["columns"])}
    for c in header["columns"]:
        out[c["name"]] = c["nbytes"]
    return out
