"""Row-based event-log storage (the Avro role of the paper): JSONL (+gzip).

Each line is one event's full attribute map — reading any single attribute
requires parsing every row in its entirety, which is precisely the access
pattern the paper contrasts against columnar projection.
"""
from __future__ import annotations

import gzip
import json

from repro.core.classic_log import ClassicEventLog


def write(path: str, log: ClassicEventLog, compress: bool = False) -> None:
    op = gzip.open if compress else open
    with op(path, "wt") as f:
        for e in log.events:
            f.write(json.dumps(e) + "\n")


def read(path: str, compress: bool = False) -> ClassicEventLog:
    op = gzip.open if compress else open
    with op(path, "rt") as f:
        return ClassicEventLog([json.loads(line) for line in f])
