from . import edf, rowlog, xes

__all__ = ["edf", "rowlog", "xes"]
