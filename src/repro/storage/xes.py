"""Minimal XES XML interop (the IEEE-standard format of the paper §2).

Intentionally simple: traces > events > string/int/float/date attributes.
XES is row-structured XML — its size/parse overheads versus EDF columns are
exactly the Table 1/2 comparison of the paper.

Timestamps are serialized as the XES-standard ``<date>`` attribute in
ISO-8601 with an explicit UTC offset (``1970-01-01T00:00:12.500000+00:00``)
rather than a raw epoch float — what PM4Py/ProM expect — and parsed back
to epoch seconds on read (a trailing ``Z`` offset is accepted too).
"""
from __future__ import annotations

import xml.etree.ElementTree as ET
from datetime import datetime, timezone
from xml.sax.saxutils import quoteattr

from repro.core.classic_log import ClassicEventLog
from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP


def _iso8601(epoch: float) -> str:
    return datetime.fromtimestamp(float(epoch), tz=timezone.utc).isoformat()


def _epoch(iso: str) -> float:
    if iso.endswith("Z"):
        iso = iso[:-1] + "+00:00"
    dt = datetime.fromisoformat(iso)
    if dt.tzinfo is None:        # naive timestamps are taken as UTC
        dt = dt.replace(tzinfo=timezone.utc)
    return dt.timestamp()


def write(path: str, log: ClassicEventLog) -> None:
    by_case: dict = {}
    for e in log.events:
        by_case.setdefault(e[CASE], []).append(e)
    with open(path, "w") as f:
        f.write('<?xml version="1.0" encoding="UTF-8" ?>\n<log xes.version="1.0">\n')
        for cid, evs in by_case.items():
            # quoteattr (not escape): escape() leaves " untouched, which
            # breaks value="..." for values containing quotes
            f.write(f'  <trace>\n    <string key="concept:name" value={quoteattr(str(cid))}/>\n')
            for e in evs:
                f.write("    <event>\n")
                for k, v in e.items():
                    if k == CASE:
                        continue
                    if k == TIMESTAMP and isinstance(v, (int, float)):
                        f.write(f'      <date key={quoteattr(k)} '
                                f'value={quoteattr(_iso8601(v))}/>\n')
                        continue
                    tag = "int" if isinstance(v, int) else "float" if isinstance(v, float) else "string"
                    f.write(f'      <{tag} key={quoteattr(k)} value={quoteattr(str(v))}/>\n')
                f.write("    </event>\n")
            f.write("  </trace>\n")
        f.write("</log>\n")


def read(path: str) -> ClassicEventLog:
    tree = ET.parse(path)
    events = []
    order = 0
    for trace in tree.getroot().iter("trace"):
        cid = None
        for child in trace:
            if child.tag == "string" and child.get("key") == "concept:name":
                cid = child.get("value")
        for ev in trace.iter("event"):
            e = {CASE: cid}
            for a in ev:
                k, v = a.get("key"), a.get("value")
                if a.tag == "int":
                    e[k] = int(v)
                elif a.tag == "float":
                    e[k] = float(v)
                elif a.tag == "date":
                    e[k] = _epoch(v)
                else:
                    e[k] = v
            e.setdefault(TIMESTAMP, float(order))
            events.append(e)
            order += 1
    events.sort(key=lambda e: e[TIMESTAMP])
    return ClassicEventLog(events)
