"""Minimal XES XML interop (the IEEE-standard format of the paper §2).

Intentionally simple: traces > events > string/int/float/date attributes.
XES is row-structured XML — its size/parse overheads versus EDF columns are
exactly the Table 1/2 comparison of the paper.
"""
from __future__ import annotations

import xml.etree.ElementTree as ET
from xml.sax.saxutils import quoteattr

from repro.core.classic_log import ClassicEventLog
from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP


def write(path: str, log: ClassicEventLog) -> None:
    by_case: dict = {}
    for e in log.events:
        by_case.setdefault(e[CASE], []).append(e)
    with open(path, "w") as f:
        f.write('<?xml version="1.0" encoding="UTF-8" ?>\n<log xes.version="1.0">\n')
        for cid, evs in by_case.items():
            # quoteattr (not escape): escape() leaves " untouched, which
            # breaks value="..." for values containing quotes
            f.write(f'  <trace>\n    <string key="concept:name" value={quoteattr(str(cid))}/>\n')
            for e in evs:
                f.write("    <event>\n")
                for k, v in e.items():
                    if k == CASE:
                        continue
                    tag = "int" if isinstance(v, int) else "float" if isinstance(v, float) else "string"
                    f.write(f'      <{tag} key={quoteattr(k)} value={quoteattr(str(v))}/>\n')
                f.write("    </event>\n")
            f.write("  </trace>\n")
        f.write("</log>\n")


def read(path: str) -> ClassicEventLog:
    tree = ET.parse(path)
    events = []
    order = 0
    for trace in tree.getroot().iter("trace"):
        cid = None
        for child in trace:
            if child.tag == "string" and child.get("key") == "concept:name":
                cid = child.get("value")
        for ev in trace.iter("event"):
            e = {CASE: cid}
            for a in ev:
                k, v = a.get("key"), a.get("value")
                if a.tag == "int":
                    e[k] = int(v)
                elif a.tag == "float":
                    e[k] = float(v)
                else:
                    e[k] = v
            e.setdefault(TIMESTAMP, float(order))
            events.append(e)
            order += 1
    events.sort(key=lambda e: e[TIMESTAMP])
    return ClassicEventLog(events)
