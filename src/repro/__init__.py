"""repro — scalable process mining on event dataframes (JAX/Pallas).

The public surface is the ``Dataset`` facade::

    import repro
    from repro import col, cases_containing, case_size

    ds = repro.open(["jan.edf", "feb.edf"])          # or one path, or a frame
    graph = ds.filter(col("concept:name") == 3).dfg()
    stats = ds.stats(engine="streaming")

Everything below it stays importable directly (``repro.core`` kernels,
``repro.query`` plans, ``repro.storage.edf`` files, ``repro.distributed``
lowerings); the attributes here are loaded lazily so ``import repro`` is
cheap and subprocess tests can still set JAX flags before anything
touches a device.
"""
from __future__ import annotations

_EXPORTS = {
    "open": ("repro.dataset", "open_dataset"),
    "open_dataset": ("repro.dataset", "open_dataset"),
    "Dataset": ("repro.dataset", "Dataset"),
    "CollectResult": ("repro.dataset.engines", "CollectResult"),
    "Windows": ("repro.dataset.window", "Windows"),
    "WindowResult": ("repro.dataset.window", "WindowResult"),
    "StateCache": ("repro.query.statecache", "StateCache"),
    "state_cache": ("repro.query.statecache", "state_cache"),
    "col": ("repro.query.expr", "col"),
    "cases_containing": ("repro.query.expr", "cases_containing"),
    "case_size": ("repro.query.expr", "case_size"),
    "variant_in": ("repro.query.expr", "variant_in"),
    "variant_of": ("repro.query.expr", "variant_of"),
    "Ingestor": ("repro.service.ingest", "Ingestor"),
    "MiningService": ("repro.service.server", "MiningService"),
    "serve": ("repro.service.server", "serve"),
    "ProcessGraph": ("repro.graph", "ProcessGraph"),
    "compile_graph": ("repro.graph", "compile_graph"),
    "alpha_to_pnml": ("repro.graph", "alpha_to_pnml"),
    "heuristics_to_dot": ("repro.graph", "heuristics_to_dot"),
    "discover_process_tree": ("repro.graph", "discover_process_tree"),
    "dfg_to_json": ("repro.graph", "dfg_to_json"),
    "dfg_from_json": ("repro.graph", "dfg_from_json"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") \
            from None
    import importlib

    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value         # cache: next access skips the import
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
