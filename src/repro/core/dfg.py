"""Directly-Follows Graph on dataframes — paper §5.4, three lowerings.

The paper gives two strategies; we implement both, plus the TPU-native matmul
formulation used by the Pallas kernel:

1. ``dfg_shift_count``  — *shifting and counting* (§5.4 strategy 2), literally
   composed from the §5.3 transformation functions: ``concat(D, shift(D))``,
   keep rows with equal case id, ``mergstrv`` the two activity columns, count.
2. ``dfg_segment``      — *map-reduce* (§5.4 strategy 1): pair keys reduced via
   scatter-add (``segment_sum``-style); this is the per-shard "map" used by the
   distributed version (``repro.distributed.dfg``), whose "reduce" is a psum.
3. ``dfg_matmul``       — counts as a matrix product ``C = X^T Y`` with one-hot
   operands; the systolic MXU does the counting. This is the reference for
   ``repro.kernels.dfg_count`` and the fastest TPU path for small alphabets.

All variants assume the frame is sorted by (case, time) — the paper's stated
precondition ("the strategy assumes that the dataframe is sorted"). Start/end
activities (needed to convert a DFG into a Petri net / IMDF input) come free
from segment boundaries.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, EventFrame
from . import ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DFG:
    """Dense DFG: ``counts[a, b]`` = #times b directly follows a."""

    counts: jax.Array        # (A, A) int32
    starts: jax.Array        # (A,)   int32 — start-activity histogram
    ends: jax.Array          # (A,)   int32 — end-activity histogram

    def tree_flatten(self):
        return (self.counts, self.starts, self.ends), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_activities(self) -> int:
        return self.counts.shape[-1]

    def edges(self):
        """Host-side sparse view: list of ((src, dst), count), count > 0."""
        import numpy as np

        c = np.asarray(self.counts)
        src, dst = np.nonzero(c)
        return [((int(a), int(b)), int(c[a, b])) for a, b in zip(src, dst)]


def _pair_arrays(frame: EventFrame):
    """(src_act, dst_act, pair_mask, case, act, rv) for adjacent rows."""
    case = frame[CASE]
    act = frame[ACTIVITY]
    rv = frame.rows_valid()
    same_case = (case[1:] == case[:-1]) & rv[1:] & rv[:-1]
    return act[:-1], act[1:], same_case, case, act, rv


def _boundaries(case: jax.Array, rv: jax.Array):
    n = case.shape[0]
    is_start = jnp.concatenate([jnp.ones((1,), bool), case[1:] != case[:-1]]) & rv
    is_end = jnp.concatenate([case[1:] != case[:-1], jnp.ones((1,), bool)]) & rv
    return is_start, is_end


@partial(jax.jit, static_argnames=("num_activities",))
def dfg_shift_count(frame: EventFrame, num_activities: int) -> DFG:
    """Paper §5.4 strategy 2, composed from the §5.3 ops verbatim.

    sort -> shift -> concat -> proj(case == case.2) -> mergstrv -> value_counts.
    """
    shifted = ops.shift(frame)
    both = ops.concat(frame, shifted, ".2")
    both = ops.proj(both, both[CASE] == both[CASE + ".2"])
    both = ops.mergstrv(both, "df:pair", ACTIVITY, ACTIVITY + ".2", num_activities)
    keep = both.rows_valid()
    # value_counts over the pair key; masked rows hit a scratch bucket.
    pair = jnp.where(keep, both["df:pair"], num_activities * num_activities)
    flat = jnp.zeros((num_activities * num_activities + 1,), jnp.int32).at[pair].add(1)
    counts = flat[:-1].reshape(num_activities, num_activities)
    is_start, is_end = _boundaries(frame[CASE], frame.rows_valid())
    act = frame[ACTIVITY]
    starts = ops.value_counts(jnp.where(is_start, act, num_activities),
                              num_activities + 1)[:-1]
    ends = ops.value_counts(jnp.where(is_end, act, num_activities),
                            num_activities + 1)[:-1]
    return DFG(counts, starts, ends)


@partial(jax.jit, static_argnames=("num_activities",))
def dfg_segment(frame: EventFrame, num_activities: int) -> DFG:
    """Paper §5.4 strategy 1 (map-reduce): scatter-add of pair keys.

    The "map" groups by case implicitly (sorted segments); the "reduce" is a
    scatter-add into the dense count matrix. ``repro.distributed.dfg`` runs
    this per shard and psums — the paper's Spark shuffle becomes one
    all-reduce of an (A, A) matrix.
    """
    src, dst, mask, case, act, rv = _pair_arrays(frame)
    a = num_activities
    key = jnp.where(mask, src * a + dst, a * a)
    flat = jnp.zeros((a * a + 1,), jnp.int32).at[key].add(1)
    counts = flat[:-1].reshape(a, a)
    is_start, is_end = _boundaries(case, rv)
    starts = ops.value_counts(jnp.where(is_start, act, a), a + 1)[:-1]
    ends = ops.value_counts(jnp.where(is_end, act, a), a + 1)[:-1]
    return DFG(counts, starts, ends)


@partial(jax.jit, static_argnames=("num_activities", "block"))
def dfg_matmul(frame: EventFrame, num_activities: int, block: int = 2048) -> DFG:
    """TPU-native: counts as one-hot matmuls on the MXU (kernel reference).

    ``C = sum_i w_i * e[src_i] e[dst_i]^T`` computed blockwise:
    ``C += (onehot(src_blk) * w_blk)^T @ onehot(dst_blk)``. The Pallas kernel
    (``repro.kernels.dfg_count``) is this loop with explicit VMEM tiling.
    """
    src, dst, mask, case, act, rv = _pair_arrays(frame)
    a = num_activities
    n = src.shape[0]
    pad = (-n) % block
    src = jnp.pad(src, (0, pad))
    dst = jnp.pad(dst, (0, pad))
    w = jnp.pad(mask.astype(jnp.float32), (0, pad))
    nblk = (n + pad) // block

    def body(c, xs):
        s, d, ww = xs
        x = (jax.nn.one_hot(s, a, dtype=jnp.float32) * ww[:, None])
        y = jax.nn.one_hot(d, a, dtype=jnp.float32)
        return c + jnp.dot(x.T, y, preferred_element_type=jnp.float32), None

    c0 = jnp.zeros((a, a), jnp.float32)
    c, _ = jax.lax.scan(
        body, c0,
        (src.reshape(nblk, block), dst.reshape(nblk, block), w.reshape(nblk, block)),
    )
    is_start, is_end = _boundaries(case, rv)
    starts = ops.value_counts(jnp.where(is_start, act, a), a + 1)[:-1]
    ends = ops.value_counts(jnp.where(is_end, act, a), a + 1)[:-1]
    return DFG(c.astype(jnp.int32), starts, ends)


def dfg(frame: EventFrame, num_activities: int, method: str = "segment") -> DFG:
    """Front door. ``method`` in {"shift", "segment", "matmul", "kernel"}."""
    if method == "shift":
        return dfg_shift_count(frame, num_activities)
    if method == "segment":
        return dfg_segment(frame, num_activities)
    if method == "matmul":
        return dfg_matmul(frame, num_activities)
    if method == "kernel":
        from repro.kernels.dfg_count import ops as kops

        src, dst, mask, case, act, rv = _pair_arrays(frame)
        counts = kops.dfg_count(src, dst, mask, num_activities)
        is_start, is_end = _boundaries(case, rv)
        starts = ops.value_counts(jnp.where(is_start, act, num_activities),
                                  num_activities + 1)[:-1]
        ends = ops.value_counts(jnp.where(is_end, act, num_activities),
                                num_activities + 1)[:-1]
        return DFG(counts, starts, ends)
    raise ValueError(f"unknown DFG method {method!r}")
