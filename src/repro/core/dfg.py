"""Directly-Follows Graph on dataframes — paper §5.4, on the primitive layer.

The paper gives two strategies; both (plus the TPU-native matmul
formulation) are now *one* call into the segmented-primitive layer
(``repro.kernels.segment_ops.pair_count``), selected by ``method``:

1. ``method="shift"``   — *shifting and counting* (§5.4 strategy 2),
   literally composed from the §5.3 transformation functions:
   ``concat(D, shift(D))``, keep rows with equal case id, ``mergstrv`` the
   two activity columns, count.
2. ``method="segment"`` — *map-reduce* (§5.4 strategy 1): pair keys reduced
   via the XLA scatter lowering (``impl="xla"``).
3. ``method="matmul"``  — counts as a matrix product ``C = X^T Y`` with
   one-hot operands (``impl="matmul"``); the systolic MXU does the counting.
4. ``method="kernel"``  — the Pallas MXU kernel (``impl="pallas"``).
5. ``method="auto"``    — backend dispatch (``core.backend``): Pallas on
   TPU, XLA scatter elsewhere.  The default everywhere downstream, so the
   streaming engine and ``distributed.dfg`` inherit the fast path.

The lowerings are expressed as a mergeable chunk-kernel (:func:`dfg_kernel`,
see ``core.engine``): the whole-log entry points are the single-chunk
special case, the streaming out-of-core path folds the same update over EDF
row groups, and ``repro.distributed.dfg`` runs the same update per shard
with a ``ppermute`` halo as the carry and ``psum`` as the merge.  All
variants assume the frame is sorted by (case, time) — the paper's stated
precondition.  Counting is integer-exact under any accumulation order, so
every method/impl returns bitwise-identical counts.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops import histogram, pair_count

from .eventframe import ACTIVITY, CASE, EventFrame
from . import backend as _backend
from . import engine, ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DFG:
    """Dense DFG: ``counts[a, b]`` = #times b directly follows a."""

    counts: jax.Array        # (A, A) int32
    starts: jax.Array        # (A,)   int32 — start-activity histogram
    ends: jax.Array          # (A,)   int32 — end-activity histogram

    def tree_flatten(self):
        return (self.counts, self.starts, self.ends), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_activities(self) -> int:
        return self.counts.shape[-1]

    def edges(self):
        """Host-side sparse view: list of ((src, dst), count), count > 0."""
        import numpy as np

        c = np.asarray(self.counts)
        src, dst = np.nonzero(c)
        return [((int(a), int(b)), int(c[a, b])) for a, b in zip(src, dst)]


def _boundaries(case: jax.Array, rv: jax.Array):
    is_start = jnp.concatenate([jnp.ones((1,), bool), case[1:] != case[:-1]]) & rv
    is_end = jnp.concatenate([case[1:] != case[:-1], jnp.ones((1,), bool)]) & rv
    return is_start, is_end


# method -> pair_count impl; "auto" resolves through core.backend.
_METHOD_IMPL = {"segment": "xla", "matmul": "matmul", "kernel": "pallas"}


def _method_impl(method: str) -> str:
    if method == "auto":
        return _backend.resolve(None)
    if method not in _METHOD_IMPL:
        raise ValueError(f"unknown DFG chunk method {method!r}")
    return _METHOD_IMPL[method]


# ------------------------------------------------------------ chunk kernel
def dfg_kernel(num_activities: int, method: str = "auto") -> engine.ChunkKernel:
    """DFG as a mergeable chunk-kernel (init / update / merge / finalize).

    The carry is the one-row halo: the directly-follows pair straddling a
    chunk boundary is (carry.act -> first row), a case continuing across the
    boundary produces no start/end, and the stream's final end activity is
    resolved in ``finalize`` from the last carry.  Any chunking of a sorted
    log therefore yields counts identical to the whole-log pass.

    ``method="auto"`` resolves through ``core.backend`` *now* (factory
    call time) and is part of the kernel cache key, so backend switches
    rebuild the jitted update.
    """
    return _dfg_kernel(num_activities, _method_impl(method))


def stitch_dfg_state(A: DFG, B: DFG, a_tail: dict, b_row0: dict,
                     straddle: bool) -> DFG:
    """Group-state stitch of two fresh DFG folds (``core.engine`` algebra).

    Elementwise sums plus the boundary-halo corrections the fresh fold of
    ``b`` could not see (its carry had ``exists=False``):

    * straddle — ``b``'s first valid row is *not* a case start (subtract
      the spurious start) and ``(a.last -> b.first)`` is a directly-follows
      pair when both rows are valid;
    * no straddle — ``a``'s last valid row *ends* its case at the boundary
      (``a``'s own fold deferred that end to ``finalize``, which never ran).

    Integer state, so the reconstruction is bitwise.  Shared by the dfg,
    alpha, discovery, and heuristics kernels (the latter two through their
    embedded DFG state).
    """
    counts = A.counts + B.counts
    starts = A.starts + B.starts
    ends = A.ends + B.ends
    if straddle:
        if b_row0["rv"]:
            starts = starts.at[b_row0["act"]].add(-1, mode="drop")
            if a_tail["rv"]:
                counts = counts.at[a_tail["act"], b_row0["act"]].add(
                    1, mode="drop")
    elif a_tail["rv"]:
        ends = ends.at[a_tail["act"]].add(1, mode="drop")
    return DFG(counts, starts, ends)


def _dfg_stitch(ctx: engine.StitchCtx):
    return stitch_dfg_state(ctx.a.state, ctx.b.state, ctx.a.tail,
                            ctx.b.head["rows"][0], ctx.straddle), {}


@lru_cache(maxsize=None)
def _dfg_kernel(num_activities: int, impl: str) -> engine.ChunkKernel:
    a = num_activities
    # "matmul" is a pair_count-only lowering; histograms take the scatter
    hist_impl = "xla" if impl == "matmul" else impl

    def init():
        state = DFG(jnp.zeros((a, a), jnp.int32),
                    jnp.zeros((a,), jnp.int32),
                    jnp.zeros((a,), jnp.int32))
        return state, engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        adj = engine.adjacent(chunk, carry)
        counts = state.counts + pair_count(adj.prev_act, adj.act, a,
                                           weights=adj.pair, impl=impl)
        starts = state.starts + histogram(adj.act, a, weights=adj.is_start,
                                          impl=hist_impl)
        ends = state.ends + histogram(adj.prev_act, a, weights=adj.end_prev,
                                      impl=hist_impl)
        return DFG(counts, starts, ends), engine.next_row_carry(carry, chunk)

    @jax.jit
    def finalize(state, carry):
        # O(1) halo update (the stream's final end activity), not an inner loop
        last_end = (carry["exists"] & carry["rv"]).astype(jnp.int32)
        ends = state.ends.at[carry["act"]].add(last_end, mode="drop")
        return DFG(state.counts, state.starts, ends)

    return engine.ChunkKernel(f"dfg[{impl}]", init, update,
                              engine.tree_sum, finalize,
                              columns=(CASE, ACTIVITY),
                              stitch=_dfg_stitch)


# ------------------------------------------------- whole-log entry points
def dfg_shift_count(frame: EventFrame, num_activities: int,
                    backend: str | None = None) -> DFG:
    """Paper §5.4 strategy 2, composed from the §5.3 ops verbatim.

    sort -> shift -> concat -> proj(case == case.2) -> mergstrv -> histogram.
    Kept in its literal whole-log form for paper fidelity; the streaming
    equivalent is ``dfg_kernel(..., method="segment")``.
    """
    return _dfg_shift_count(frame, num_activities, _backend.resolve(backend))


@partial(jax.jit, static_argnames=("num_activities", "impl"))
def _dfg_shift_count(frame: EventFrame, num_activities: int, impl: str) -> DFG:
    shifted = ops.shift(frame)
    both = ops.concat(frame, shifted, ".2")
    both = ops.proj(both, both[CASE] == both[CASE + ".2"])
    both = ops.mergstrv(both, "df:pair", ACTIVITY, ACTIVITY + ".2", num_activities)
    keep = both.rows_valid()
    flat = histogram(both["df:pair"], num_activities * num_activities,
                     weights=keep, impl=impl)
    counts = flat.reshape(num_activities, num_activities)
    is_start, is_end = _boundaries(frame[CASE], frame.rows_valid())
    act = frame[ACTIVITY]
    starts = histogram(act, num_activities, weights=is_start, impl=impl)
    ends = histogram(act, num_activities, weights=is_end, impl=impl)
    return DFG(counts, starts, ends)


def dfg_segment(frame: EventFrame, num_activities: int) -> DFG:
    """Paper §5.4 strategy 1 (map-reduce): the single-chunk special case of
    ``dfg_kernel(..., "segment")``.  ``repro.distributed.dfg`` runs the same
    update per shard and psums — the paper's Spark shuffle becomes one
    all-reduce of an (A, A) matrix."""
    return engine.run_single(dfg_kernel(num_activities, "segment"), frame)


def dfg_matmul(frame: EventFrame, num_activities: int) -> DFG:
    """TPU-native: counts as one-hot matmuls on the MXU (kernel reference);
    the single-chunk special case of ``dfg_kernel(..., "matmul")``."""
    return engine.run_single(dfg_kernel(num_activities, "matmul"), frame)


def dfg(frame: EventFrame, num_activities: int, method: str = "auto") -> DFG:
    """Front door. ``method`` in {"auto", "shift", "segment", "matmul", "kernel"}."""
    if method == "shift":
        return dfg_shift_count(frame, num_activities)
    return engine.run_single(dfg_kernel(num_activities, method), frame)


engine.register_kernel(engine.KernelSpec(
    "dfg",
    make=lambda dims, method="auto": dfg_kernel(dims.num_activities, method),
    columns=(CASE, ACTIVITY),
    sharded_state="dfg",
    from_sharded=lambda state, **_: state,
    doc="directly-follows graph (counts + start/end histograms)"))
