"""Directly-Follows Graph on dataframes — paper §5.4, three lowerings.

The paper gives two strategies; we implement both, plus the TPU-native matmul
formulation used by the Pallas kernel:

1. ``dfg_shift_count``  — *shifting and counting* (§5.4 strategy 2), literally
   composed from the §5.3 transformation functions: ``concat(D, shift(D))``,
   keep rows with equal case id, ``mergstrv`` the two activity columns, count.
2. ``dfg_segment``      — *map-reduce* (§5.4 strategy 1): pair keys reduced via
   scatter-add (``segment_sum``-style).
3. ``dfg_matmul``       — counts as a matrix product ``C = X^T Y`` with one-hot
   operands; the systolic MXU does the counting. This is the reference for
   ``repro.kernels.dfg_count`` and the fastest TPU path for small alphabets.

The segment/matmul/kernel lowerings are expressed as a mergeable chunk-kernel
(:func:`dfg_kernel`, see ``core.engine``): the whole-log jitted entry points
are the single-chunk special case, the streaming out-of-core path folds the
same update over EDF row groups, and ``repro.distributed.dfg`` runs the same
update per shard with a ``ppermute`` halo as the carry and ``psum`` as the
merge.  All variants assume the frame is sorted by (case, time) — the paper's
stated precondition.  Start/end activities (needed to convert a DFG into a
Petri net / IMDF input) come free from segment boundaries.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, EventFrame
from . import engine, ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DFG:
    """Dense DFG: ``counts[a, b]`` = #times b directly follows a."""

    counts: jax.Array        # (A, A) int32
    starts: jax.Array        # (A,)   int32 — start-activity histogram
    ends: jax.Array          # (A,)   int32 — end-activity histogram

    def tree_flatten(self):
        return (self.counts, self.starts, self.ends), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_activities(self) -> int:
        return self.counts.shape[-1]

    def edges(self):
        """Host-side sparse view: list of ((src, dst), count), count > 0."""
        import numpy as np

        c = np.asarray(self.counts)
        src, dst = np.nonzero(c)
        return [((int(a), int(b)), int(c[a, b])) for a, b in zip(src, dst)]


def _boundaries(case: jax.Array, rv: jax.Array):
    n = case.shape[0]
    is_start = jnp.concatenate([jnp.ones((1,), bool), case[1:] != case[:-1]]) & rv
    is_end = jnp.concatenate([case[1:] != case[:-1], jnp.ones((1,), bool)]) & rv
    return is_start, is_end


# ----------------------------------------------------- pair-count reducers
def _count_pairs_segment(counts, src, dst, mask, num_activities):
    """Scatter-add of pair keys; masked pairs hit a scratch bucket."""
    a = num_activities
    key = jnp.where(mask, src * a + dst, a * a)
    flat = counts.reshape(-1)
    flat = jnp.concatenate([flat, jnp.zeros((1,), counts.dtype)])
    flat = flat.at[key].add(1)
    return flat[:-1].reshape(a, a)


def _count_pairs_matmul(counts, src, dst, mask, num_activities, block=2048):
    """Blockwise one-hot matmul: ``C += (onehot(src) * w)^T @ onehot(dst)``."""
    a = num_activities
    n = src.shape[0]
    pad = (-n) % block
    src = jnp.pad(src, (0, pad))
    dst = jnp.pad(dst, (0, pad))
    w = jnp.pad(mask.astype(jnp.float32), (0, pad))
    nblk = (n + pad) // block

    def body(c, xs):
        s, d, ww = xs
        x = (jax.nn.one_hot(s, a, dtype=jnp.float32) * ww[:, None])
        y = jax.nn.one_hot(d, a, dtype=jnp.float32)
        return c + jnp.dot(x.T, y, preferred_element_type=jnp.float32), None

    c, _ = jax.lax.scan(
        body, jnp.zeros((a, a), jnp.float32),
        (src.reshape(nblk, block), dst.reshape(nblk, block), w.reshape(nblk, block)),
    )
    return counts + c.astype(counts.dtype)


def _count_pairs_kernel(counts, src, dst, mask, num_activities):
    """Pallas MXU kernel (``repro.kernels.dfg_count``) as the reducer."""
    from repro.kernels.dfg_count import ops as kops

    return counts + kops.dfg_count(src, dst, mask, num_activities)


_REDUCERS = {
    "segment": _count_pairs_segment,
    "matmul": _count_pairs_matmul,
    "kernel": _count_pairs_kernel,
}


# ------------------------------------------------------------ chunk kernel
@lru_cache(maxsize=None)
def dfg_kernel(num_activities: int, method: str = "segment") -> engine.ChunkKernel:
    """DFG as a mergeable chunk-kernel (init / update / merge / finalize).

    The carry is the one-row halo: the directly-follows pair straddling a
    chunk boundary is (carry.act -> first row), a case continuing across the
    boundary produces no start/end, and the stream's final end activity is
    resolved in ``finalize`` from the last carry.  Any chunking of a sorted
    log therefore yields counts identical to the whole-log pass.
    """
    a = num_activities
    if method not in _REDUCERS:
        raise ValueError(f"unknown DFG chunk method {method!r}")
    reduce_pairs = _REDUCERS[method]

    def init():
        state = DFG(jnp.zeros((a, a), jnp.int32),
                    jnp.zeros((a,), jnp.int32),
                    jnp.zeros((a,), jnp.int32))
        return state, engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        adj = engine.adjacent(chunk, carry)
        counts = reduce_pairs(state.counts, adj.prev_act, adj.act, adj.pair, a)
        starts = state.starts + ops.value_counts(
            jnp.where(adj.is_start, adj.act, a), a + 1)[:-1]
        ends = state.ends + ops.value_counts(
            jnp.where(adj.end_prev, adj.prev_act, a), a + 1)[:-1]
        return DFG(counts, starts, ends), engine.next_row_carry(carry, chunk)

    @jax.jit
    def finalize(state, carry):
        last_end = (carry["exists"] & carry["rv"]).astype(jnp.int32)
        ends = state.ends.at[carry["act"]].add(last_end, mode="drop")
        return DFG(state.counts, state.starts, ends)

    return engine.ChunkKernel(f"dfg[{method}]", init, update,
                              engine.tree_sum, finalize)


# ------------------------------------------------- whole-log entry points
@partial(jax.jit, static_argnames=("num_activities",))
def dfg_shift_count(frame: EventFrame, num_activities: int) -> DFG:
    """Paper §5.4 strategy 2, composed from the §5.3 ops verbatim.

    sort -> shift -> concat -> proj(case == case.2) -> mergstrv -> value_counts.
    Kept in its literal whole-log form for paper fidelity; the streaming
    equivalent is ``dfg_kernel(..., method="segment")``.
    """
    shifted = ops.shift(frame)
    both = ops.concat(frame, shifted, ".2")
    both = ops.proj(both, both[CASE] == both[CASE + ".2"])
    both = ops.mergstrv(both, "df:pair", ACTIVITY, ACTIVITY + ".2", num_activities)
    keep = both.rows_valid()
    # value_counts over the pair key; masked rows hit a scratch bucket.
    pair = jnp.where(keep, both["df:pair"], num_activities * num_activities)
    flat = jnp.zeros((num_activities * num_activities + 1,), jnp.int32).at[pair].add(1)
    counts = flat[:-1].reshape(num_activities, num_activities)
    is_start, is_end = _boundaries(frame[CASE], frame.rows_valid())
    act = frame[ACTIVITY]
    starts = ops.value_counts(jnp.where(is_start, act, num_activities),
                              num_activities + 1)[:-1]
    ends = ops.value_counts(jnp.where(is_end, act, num_activities),
                            num_activities + 1)[:-1]
    return DFG(counts, starts, ends)


@partial(jax.jit, static_argnames=("num_activities",))
def dfg_segment(frame: EventFrame, num_activities: int) -> DFG:
    """Paper §5.4 strategy 1 (map-reduce): the single-chunk special case of
    ``dfg_kernel(..., "segment")``.  ``repro.distributed.dfg`` runs the same
    update per shard and psums — the paper's Spark shuffle becomes one
    all-reduce of an (A, A) matrix."""
    return engine.run_single(dfg_kernel(num_activities, "segment"), frame)


@partial(jax.jit, static_argnames=("num_activities",))
def dfg_matmul(frame: EventFrame, num_activities: int) -> DFG:
    """TPU-native: counts as one-hot matmuls on the MXU (kernel reference);
    the single-chunk special case of ``dfg_kernel(..., "matmul")``."""
    return engine.run_single(dfg_kernel(num_activities, "matmul"), frame)


def dfg(frame: EventFrame, num_activities: int, method: str = "segment") -> DFG:
    """Front door. ``method`` in {"shift", "segment", "matmul", "kernel"}."""
    if method == "shift":
        return dfg_shift_count(frame, num_activities)
    if method == "segment":
        return dfg_segment(frame, num_activities)
    if method == "matmul":
        return dfg_matmul(frame, num_activities)
    if method == "kernel":
        return engine.run_single(dfg_kernel(num_activities, "kernel"), frame)
    raise ValueError(f"unknown DFG method {method!r}")
