"""Classical event log (paper Def. 1) — the compared baseline structure.

``L = (C_I, E, A, case_ev, act, attr, <=)`` where each event's ``attr`` is an
associative map (the XES / XESLite implementation strategy). This is the
structure whose per-event map lookups give the O(N*M) worst-case filtering and
O(N^2) worst-case DFG of Tables 3/4. Kept faithfully *un*-vectorized: plain
Python dicts and iteration, used by the complexity/assessment benchmarks as
the row-oriented baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

import numpy as np

from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame


@dataclasses.dataclass
class ClassicEventLog:
    """List-of-events with per-event attribute maps, totally ordered."""

    events: list[dict[str, Any]]  # each dict is the event's attr map

    # ------------------------------------------------------------- Def. 1
    @property
    def case_ids(self) -> set:
        return {e[CASE] for e in self.events}

    def case_ev(self) -> dict[Any, list[int]]:
        m: dict[Any, list[int]] = {}
        for i, e in enumerate(self.events):
            m.setdefault(e[CASE], []).append(i)
        return m

    def act(self, i: int) -> Any:
        return self.events[i][ACTIVITY]

    # --------------------------------------------------------- operations
    def filter_events(self, name: str, values: set) -> "ClassicEventLog":
        """Attr-map filtering: one map lookup per event (Table 3 baseline)."""
        kept = [e for e in self.events if e.get(name) in values]
        return ClassicEventLog(kept)

    def dfg_iterative(self) -> dict[tuple, int]:
        """Single pass over cases storing edges in a map (Table 4 baseline)."""
        counts: dict[tuple, int] = {}
        last_by_case: dict[Any, Any] = {}
        for e in self.events:  # events are totally ordered
            c, a = e[CASE], e[ACTIVITY]
            if c in last_by_case:
                key = (last_by_case[c], a)
                counts[key] = counts.get(key, 0) + 1
            last_by_case[c] = a
        return counts

    def dfg_l2_iterative(self) -> dict[tuple, int]:
        """Count ``a, b, a`` triples per case (heuristics-miner L2-loop
        counts), one pass with per-case last-two maps — the row-oriented
        oracle for ``discovery.DiscoveryState.l2_counts``."""
        counts: dict[tuple, int] = {}
        prev1: dict[Any, Any] = {}
        prev2: dict[Any, Any] = {}
        for e in self.events:
            c, a = e[CASE], e[ACTIVITY]
            if c in prev2 and prev2[c] == a:
                key = (prev2[c], prev1[c])
                counts[key] = counts.get(key, 0) + 1
            prev2[c] = prev1.get(c)
            prev1[c] = a
        return counts

    def start_end_activities(self) -> tuple[dict, dict]:
        starts: dict[Any, int] = {}
        ends: dict[Any, int] = {}
        last_act: dict[Any, Any] = {}
        seen: set = set()
        for e in self.events:
            c, a = e[CASE], e[ACTIVITY]
            if c not in seen:
                seen.add(c)
                starts[a] = starts.get(a, 0) + 1
            last_act[c] = a
        for a in last_act.values():
            ends[a] = ends.get(a, 0) + 1
        return starts, ends

    # -------------------------------------------------- conversion (§5.2)
    def to_eventframe(self) -> tuple[EventFrame, dict[str, list]]:
        """Paper §5.2 conversion: E is a <=-ordered sequence; every attribute
        name becomes a column; missing attributes become epsilon (validity 0).
        Object-valued columns are dictionary-encoded; the string tables are
        returned alongside the frame."""
        names = sorted({n for e in self.events for n in e})
        n = len(self.events)
        cols: dict[str, np.ndarray] = {}
        valid: dict[str, np.ndarray] = {}
        tables: dict[str, list] = {}
        for name in names:
            raw = [e.get(name) for e in self.events]
            mask = np.array([r is not None for r in raw])
            if all(isinstance(r, (int, float, np.integer, np.floating)) or r is None for r in raw):
                arr = np.array([r if r is not None else 0 for r in raw], dtype=np.float64)
                if all(isinstance(r, (int, np.integer)) or r is None for r in raw):
                    arr = arr.astype(np.int64)
                cols[name] = arr
            else:  # dictionary-encode
                table: list = []
                index: dict = {}
                ids = np.zeros((n,), dtype=np.int32)
                for i, r in enumerate(raw):
                    if r is None:
                        continue
                    if r not in index:
                        index[r] = len(table)
                        table.append(r)
                    ids[i] = index[r]
                cols[name] = ids
                tables[name] = table
            if not mask.all():
                valid[name] = mask
        return EventFrame.from_numpy(cols, valid), tables

    @staticmethod
    def from_eventframe(frame: EventFrame, tables: dict[str, list] | None = None) -> "ClassicEventLog":
        tables = tables or {}
        data = frame.to_numpy()
        rv = np.asarray(frame.rows_valid())
        events = []
        for i in range(frame.nrows):
            if not rv[i]:
                continue
            e = {}
            for k, v in data.items():
                if k in frame.valid and not bool(np.asarray(frame.valid[k])[i]):
                    continue
                val = v[i].item()
                if k in tables:
                    val = tables[k][int(val)]
                e[k] = val
            events.append(e)
        return ClassicEventLog(events)


# ---------------------------------------------------- discovery oracle
# Row-oriented reference implementations of the columnar miners in
# ``core.discovery`` — deliberately set/dict based and brute-force, so the
# two code paths share nothing but the definitions they implement.
def footprint_reference(log: ClassicEventLog):
    """Alpha relations as sets of activity-label pairs.

    Returns ``(alphabet, direct, causal, parallel)``; choice is the
    complement.  ``alphabet`` is sorted for deterministic iteration.
    """
    direct = set(log.dfg_iterative())
    causal = {(a, b) for (a, b) in direct if (b, a) not in direct}
    parallel = {(a, b) for (a, b) in direct if (b, a) in direct}
    alphabet = sorted({e[ACTIVITY] for e in log.events})
    return alphabet, direct, causal, parallel


def alpha_reference(log: ClassicEventLog):
    """Brute-force alpha miner: enumerate *all* subset pairs (exponential,
    test-sized alphabets only) and keep the maximal valid ones.

    Returns ``(places, starts, ends)`` with places as a set of
    ``(frozenset, frozenset)`` of activity labels.
    """
    from itertools import chain, combinations

    alphabet, direct, causal, _ = footprint_reference(log)

    def choice(a, b):
        return (a, b) not in direct and (b, a) not in direct

    def powerset(xs):
        return chain.from_iterable(combinations(xs, r)
                                   for r in range(1, len(xs) + 1))

    # only choice-cliques (incl. a#a: no self-loop) can appear on a side
    cliques = [frozenset(s) for s in powerset(alphabet)
               if all(choice(x, y) for x in s for y in s)]
    valid = {(aa, bb) for aa in cliques for bb in cliques
             if all((a, b) in causal for a in aa for b in bb)}
    places = {p for p in valid
              if not any(q != p and p[0] <= q[0] and p[1] <= q[1]
                         for q in valid)}
    starts_c, ends_c = log.start_end_activities()
    return places, frozenset(starts_c), frozenset(ends_c)


def heuristics_reference(log: ClassicEventLog, *,
                         dependency_threshold: float = 0.5,
                         l2_threshold: float = 0.5,
                         min_count: int = 1):
    """Dict-based heuristics measures + thresholded dependency graph.

    Returns ``(dep, l2, edges)``: ``dep[(a, b)]`` is the dependency measure
    (diagonal entries are the L1-loop measure), ``l2[(a, b)]`` the L2-loop
    measure, ``edges`` the set of kept label pairs (L1 loops as ``(a, a)``).
    """
    c = log.dfg_iterative()
    c2 = log.dfg_l2_iterative()
    alphabet = sorted({e[ACTIVITY] for e in log.events})
    dep: dict[tuple, float] = {}
    l2: dict[tuple, float] = {}
    for a in alphabet:
        for b in alphabet:
            ab, ba = c.get((a, b), 0), c.get((b, a), 0)
            if a == b:
                dep[(a, b)] = ab / (ab + 1.0)
                l2[(a, b)] = 0.0
            else:
                dep[(a, b)] = (ab - ba) / (ab + ba + 1.0)
                t = c2.get((a, b), 0) + c2.get((b, a), 0)
                l2[(a, b)] = t / (t + 1.0)
    loops1 = {a for a in alphabet
              if dep[(a, a)] >= dependency_threshold
              and c.get((a, a), 0) >= min_count}
    edges = {(a, b) for a in alphabet for b in alphabet if a != b
             and dep[(a, b)] >= dependency_threshold
             and c.get((a, b), 0) >= min_count}
    edges |= {(a, a) for a in loops1}
    for a in alphabet:
        for b in alphabet:
            if a == b or a in loops1 or b in loops1:
                continue
            t = c2.get((a, b), 0) + c2.get((b, a), 0)
            if l2[(a, b)] >= l2_threshold and t >= min_count:
                edges.add((a, b))
                edges.add((b, a))
    return dep, l2, edges


def make_classic_log(cases: Iterable[tuple[Any, list[tuple[Any, float]]]],
                     extra_attrs: int = 0) -> ClassicEventLog:
    """Build a classic log from (case_id, [(activity, ts), ...]) traces."""
    events = []
    for cid, trace in cases:
        for j, (a, ts) in enumerate(trace):
            e = {CASE: cid, ACTIVITY: a, TIMESTAMP: ts}
            for k in range(extra_attrs):
                e[f"attr{k}"] = j * 31 + k
            events.append(e)
    events.sort(key=lambda e: e[TIMESTAMP])
    return ClassicEventLog(events)
