"""Affine polyhash sketches — the header-resident form of the variant hash.

The variant fingerprint of a case is the rolling hash ``h <- h*BASE +
(act+1)`` (mod 2^32) over its activity sequence.  Each row is the affine
map ``h -> h*BASE + (act+1)``; affine maps compose associatively, so any
contiguous *run* of rows collapses to a single pair ``(mul, add)`` with
``h_out = h_in*mul + add`` (exact in uint32 — everything wraps mod 2^32).

:func:`segment_sketch` computes that pair per case *segment* of a row
group (the group-local slice of each case).  The pairs are what the
EDFV0003 header persists per row group (``storage.edf``): the query layer
composes them across groups at header-read time, which is how a pruned
scan reconstructs the exact rolling-hash carry of a skipped run — and how
whole-dataset variant fingerprints are derived without any data I/O.
"""
from __future__ import annotations

import numpy as np

BASE1 = 1_000_003
BASE2 = 16_777_619          # FNV prime
M32 = 0xFFFFFFFF

# ghost-chunk column names carrying per-segment composed maps (the query
# executor attaches these to synthetic chunks; real chunks never have them)
SK_MUL1, SK_ADD1 = "__sk_mul1__", "__sk_add1__"
SK_MUL2, SK_ADD2 = "__sk_mul2__", "__sk_add2__"
SKETCH_COLUMNS = (SK_MUL1, SK_ADD1, SK_MUL2, SK_ADD2)
SKETCH_KEYS = ("mul1", "add1", "mul2", "add2")
_KEY_TO_COLUMN = dict(zip(SKETCH_KEYS, SKETCH_COLUMNS))


def _powers(base: int, n: int) -> np.ndarray:
    """``pw[k] = base**k mod 2^32`` for k in [0, n]."""
    pw = np.ones(n + 1, np.uint32)
    if n:
        np.cumprod(np.full(n, base, np.uint32), out=pw[1:])
    return pw


def segment_sketch(act: np.ndarray, case: np.ndarray) -> dict:
    """Per-segment affine maps of one contiguous (case,time)-sorted slice.

    Returns ``{"mul1", "add1", "mul2", "add2"}`` uint32 arrays, one entry
    per case segment, such that folding the rows of segment ``j`` through
    the rolling hash maps ``h`` to ``h*mul[j] + add[j]`` (per base).
    """
    act = np.asarray(act)
    case = np.asarray(case)
    n = act.shape[0]
    if n == 0:
        z = np.zeros(0, np.uint32)
        return {k: z.copy() for k in SKETCH_KEYS}
    starts = np.flatnonzero(
        np.concatenate([[True], case[1:] != case[:-1]]))
    ends = np.concatenate([starts[1:] - 1, [n - 1]])
    lens = ends - starts + 1
    # row i of segment j contributes (act_i+1) * base^(end_j - i): the
    # reduceat sums those weighted addends per segment, mod 2^32
    exp = np.repeat(ends, lens) - np.arange(n)
    v = act.astype(np.uint32) + np.uint32(1)
    out = {}
    for base, mk, ak in ((BASE1, "mul1", "add1"), (BASE2, "mul2", "add2")):
        pw = _powers(base, int(lens.max()))
        out[mk] = pw[lens].astype(np.uint32)
        out[ak] = np.add.reduceat(v * pw[exp], starts).astype(np.uint32)
    return out


def compose(m1: int, a1: int, m2: int, a2: int) -> tuple[int, int]:
    """Compose two affine maps (apply map 1, then map 2), mod 2^32."""
    return (m1 * m2) & M32, (a1 * m2 + a2) & M32


def sequence_fingerprint(seq) -> tuple[int, int]:
    """The (fp1, fp2) fingerprint pair of an explicit activity-id sequence
    — what :func:`repro.query.expr.variant_of` matches cases against."""
    h1 = h2 = 0
    for a in seq:
        h1 = (h1 * BASE1 + int(a) + 1) & M32
        h2 = (h2 * BASE2 + int(a) + 1) & M32
    return h1, h2


def sketch_columns(sketch: dict, segments: int, size: int) -> dict:
    """Materialize ghost-chunk sketch columns: per-segment maps on rows
    ``[0, segments)``, the identity map ``(1, 0)`` on padding rows."""
    cols = {}
    for key, name in _KEY_TO_COLUMN.items():
        fill = 1 if key.startswith("mul") else 0
        arr = np.full(size, fill, np.uint32)
        arr[:segments] = sketch[key]
        cols[name] = arr
    return cols
