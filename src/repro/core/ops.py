"""Transformation functions on dataframes — paper §5.3, vectorized for TPU.

Each function mirrors one definition from the paper:

* ``proj``     — projection on a selective function (filter). Lazy: marks the
                 ``row_valid`` mask instead of compacting (static shapes).
* ``group``    — grouping on an attribute. Realized as *segment ids*: after a
                 sort on the grouping attribute, groups are contiguous segments
                 (hash-free; TPU-native).
* ``shift``    — index shift ``I' = {i-1 | i in I}`` i.e. ``shift(D)[i] = D[i+1]``.
* ``concat``   — horizontal concatenation with a column-name suffix.
* ``sort``     — stable sort by one or more attributes.
* ``mergstrv`` — string-attribute merge. Strings are dictionary-encoded, so the
                 merge of two id columns is the *pair encoding* ``a * base + b``
                 (an injective stand-in for ``a + sep + b``).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .eventframe import EventFrame


def proj(frame: EventFrame, mask: jax.Array) -> EventFrame:
    """Paper's ``proj(D, S, f)``: keep rows where the selective function is 1.

    ``mask`` is ``f`` evaluated per row. The result shares the input's column
    arrays and only narrows ``row_valid`` — O(N) worst case, matching Table 3.
    """
    rv = mask if frame.row_valid is None else (frame.row_valid & mask)
    return EventFrame(frame.columns, frame.valid, rv)


def proj_fn(frame: EventFrame, names: Sequence[str], f: Callable[..., jax.Array]) -> EventFrame:
    """Literal form of the paper's projection: ``f`` receives the named columns."""
    return proj(frame, f(*[frame[n] for n in names]))


def sort(frame: EventFrame, by: Sequence[str] | str) -> EventFrame:
    """Stable lexicographic sort by one or more columns (last key primary —
    mirrors ``np.lexsort`` convention; pass keys minor-to-major)."""
    if isinstance(by, str):
        by = (by,)
    keys = [frame[n] for n in by]
    order = jnp.lexsort(tuple(keys))
    return frame.take(order)


def shift(frame: EventFrame, fill: int = 0) -> EventFrame:
    """``shift(D)[i] = D[i+1]``; the final row becomes invalid (index left I)."""
    n = frame.nrows

    def shf(col):
        return jnp.concatenate([col[1:], jnp.full((1,), fill, col.dtype)])

    cols = {k: shf(v) for k, v in frame.columns.items()}
    vals = {k: jnp.concatenate([v[1:], jnp.zeros((1,), bool)]) for k, v in frame.valid.items()}
    rv = frame.rows_valid()
    rv = jnp.concatenate([rv[1:], jnp.zeros((1,), bool)])
    return EventFrame(cols, vals, rv)


def concat(a: EventFrame, b: EventFrame, suffix: str = ".2") -> EventFrame:
    """Horizontal concat; ``b``'s columns are renamed ``name + suffix``."""
    cols = dict(a.columns)
    vals = dict(a.valid)
    for k, v in b.columns.items():
        cols[k + suffix] = v
    for k, v in b.valid.items():
        vals[k + suffix] = v
    rv = None
    if a.row_valid is not None or b.row_valid is not None:
        rv = a.rows_valid() & b.rows_valid()
    return EventFrame(cols, vals, rv)


def mergstrv(frame: EventFrame, out: str, n1: str, n2: str, base: int) -> EventFrame:
    """Pair-encode two dictionary-encoded columns: ``v = col1 * base + col2``.

    ``base`` must exceed every value of ``n2`` (typically the alphabet size);
    the encoding is injective, as string concatenation with a separator is.

    The encoding lives in int32, so ``max(col1) * base + max(col2)`` must
    fit in int32.  With concrete (non-traced) columns the bound is checked
    eagerly and a clear ``OverflowError`` is raised instead of silently
    wrapping; under ``jit`` the values are tracers and the caller is
    responsible for sizing ``base`` (alphabets are static there).
    """
    c1, c2 = frame[n1], frame[n2]
    if not (isinstance(c1, jax.core.Tracer) or isinstance(c2, jax.core.Tracer)):
        if c1.size:
            hi = int(jnp.max(c1)) * int(base) + int(jnp.max(c2))
            if hi > jnp.iinfo(jnp.int32).max:
                raise OverflowError(
                    f"mergstrv({n1!r}, {n2!r}): pair encoding max "
                    f"{int(jnp.max(c1))} * {base} + {int(jnp.max(c2))} = {hi} "
                    f"exceeds int32 range; use a smaller base/alphabet or "
                    f"split the log")
    merged = c1.astype(jnp.int32) * jnp.int32(base) + c2.astype(jnp.int32)
    return frame.with_column(out, merged)


def group_segments(frame: EventFrame, by: str) -> tuple[EventFrame, jax.Array, jax.Array]:
    """Paper's ``group(D, n0)`` realized as contiguous segments.

    Returns ``(sorted_frame, segment_ids, segment_starts_mask)``. After the
    sort, rows of one group are adjacent; ``segment_ids`` numbers groups
    ``0..G-1`` in order of first appearance in the sorted frame.
    """
    sf = sort(frame, by)
    key = sf[by]
    starts = jnp.concatenate([jnp.ones((1,), bool), key[1:] != key[:-1]])
    seg_ids = jnp.cumsum(starts.astype(jnp.int32)) - 1
    return sf, seg_ids, starts


def segment_ids_sorted(key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Segment ids for an already-sorted key column (no resort)."""
    starts = jnp.concatenate([jnp.ones((1,), bool), key[1:] != key[:-1]])
    return jnp.cumsum(starts.astype(jnp.int32)) - 1, starts


def value_counts(col: jax.Array, num_values: int, weights: jax.Array | None = None,
                 *, impl: str | None = None) -> jax.Array:
    """Histogram of a dictionary-encoded column — the ``c(e)`` count of §5.4.

    Thin alias of ``kernels.segment_ops.histogram`` (backend-dispatched:
    Pallas tiled reduction on TPU, XLA scatter elsewhere); out-of-range
    values are dropped.
    """
    from repro.kernels.segment_ops import histogram

    return histogram(col, num_values, weights, impl=impl)
