"""ChunkedEventFrame — a re-iterable stream of device-sized log chunks.

The out-of-core substrate for ``core.engine``: a source of (case,time)-sorted
``EventFrame`` chunks that never materializes more than one chunk's columns
at a time.  Three constructors cover the paper's Table-6 scenario:

* :meth:`from_edf`        — stream the row groups of an EDFV0002 file with
                            per-group column projection (disk -> device);
* :meth:`from_frame`      — slice an in-memory frame into fixed-size chunks
                            (the testing / re-chunking path);
* :meth:`from_synthetic`  — generate the log case-batch by case-batch, so a
                            log 10x device memory is *born* chunked.

The stream is re-iterable (two-phase algorithms like case-level filtering
scan it twice), ordered, and chunk boundaries may split a case anywhere —
the engine's carries stitch them back together.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator

import numpy as np

from .eventframe import CASE, EventFrame


class ChunkedEventFrame:
    """Re-iterable source of (case,time)-sorted EventFrame chunks."""

    def __init__(self, factory: Callable[[], Iterable[EventFrame]],
                 num_chunks: int | None = None,
                 tables: dict[str, list] | None = None):
        self._factory = factory
        self.num_chunks = num_chunks
        self.tables = tables or {}

    def __iter__(self) -> Iterator[EventFrame]:
        return iter(self._factory())

    def __len__(self) -> int:
        if self.num_chunks is None:
            raise TypeError("chunk count unknown for this source")
        return self.num_chunks

    # ----------------------------------------------------------- sources
    @classmethod
    def from_frame(cls, frame: EventFrame, chunk_rows: int) -> "ChunkedEventFrame":
        """Slice an in-memory frame into contiguous chunks of ``chunk_rows``."""
        if chunk_rows <= 0:
            raise ValueError("chunk_rows must be positive")
        n = frame.nrows
        num = max(1, -(-n // chunk_rows))

        def gen():
            for lo in range(0, max(n, 1), chunk_rows):
                hi = min(lo + chunk_rows, n)
                yield EventFrame(
                    {k: v[lo:hi] for k, v in frame.columns.items()},
                    {k: v[lo:hi] for k, v in frame.valid.items()},
                    frame.row_valid[lo:hi] if frame.row_valid is not None else None,
                )

        return cls(gen, num_chunks=num)

    @classmethod
    def from_cuts(cls, frame: EventFrame, cuts) -> "ChunkedEventFrame":
        """Arbitrary chunking at the given sorted row offsets (testing aid:
        chunk-invariance properties exercise adversarial cut points)."""
        n = frame.nrows
        edges = [0] + [int(c) for c in cuts if 0 < int(c) < n] + [n]
        edges = sorted(set(edges))

        def gen():
            for lo, hi in zip(edges[:-1], edges[1:]):
                yield EventFrame(
                    {k: v[lo:hi] for k, v in frame.columns.items()},
                    {k: v[lo:hi] for k, v in frame.valid.items()},
                    frame.row_valid[lo:hi] if frame.row_valid is not None else None,
                )

        return cls(gen, num_chunks=len(edges) - 1)

    @classmethod
    def from_edf(cls, path: str, columns: Iterable[str] | None = None
                 ) -> "ChunkedEventFrame":
        """Stream an EDF file row-group by row-group with column projection.

        EDFV0002 files yield one chunk per row group; legacy EDFV0001 files
        (no groups) degrade to a single chunk.
        """
        from repro.storage import edf

        columns = tuple(columns) if columns is not None else None
        header, _ = edf.read_header(path)
        num = edf.num_row_groups_header(header)
        tables = {c["name"]: list(c["table"]) for c in header["columns"]
                  if "table" in c}

        def gen():
            for frame, _tables in edf.read_streaming(path, columns=columns):
                yield frame

        return cls(gen, num_chunks=num, tables=tables)

    @classmethod
    def from_synthetic(cls, num_cases: int, cases_per_chunk: int,
                       num_activities: int = 26, seed: int = 0,
                       **gen_kwargs) -> "ChunkedEventFrame":
        """Generate a Markov-chain log (``data.synthetic``) one case-batch at
        a time; case ids are offset per batch so the stream stays globally
        (case,time)-sorted without ever holding the full log."""
        from repro.data import synthetic

        if cases_per_chunk <= 0:
            raise ValueError("cases_per_chunk must be positive")
        num = max(1, -(-num_cases // cases_per_chunk))

        def gen():
            done = 0
            batch_idx = 0
            while done < num_cases:
                batch = min(cases_per_chunk, num_cases - done)
                frame, _ = synthetic.generate(
                    num_cases=batch, num_activities=num_activities,
                    seed=seed + 1_000_003 * batch_idx, **gen_kwargs)
                case = np.asarray(frame[CASE]) + done
                cols = {k: (np.asarray(v) if k != CASE else case)
                        for k, v in frame.columns.items()}
                yield EventFrame.from_numpy(cols)
                done += batch
                batch_idx += 1

        tables = {"concept:name": [f"act_{i:03d}" for i in range(num_activities)]}
        return cls(gen, num_chunks=num, tables=tables)

    # ----------------------------------------------------------- utility
    def materialize(self) -> EventFrame:
        """Concatenate the stream into one frame (small logs / testing)."""
        chunks = list(self)
        cols = {k: np.concatenate([np.asarray(c.columns[k]) for c in chunks])
                for k in chunks[0].columns}
        valid = {k: np.concatenate([np.asarray(c.valid[k]) for c in chunks])
                 for k in chunks[0].valid}
        out = EventFrame.from_numpy(cols, valid)
        if any(c.row_valid is not None for c in chunks):
            import jax.numpy as jnp
            rv = np.concatenate([np.asarray(c.rows_valid()) for c in chunks])
            out = EventFrame(out.columns, out.valid, jnp.asarray(rv))
        return out
