"""Chunked out-of-core execution engine — mergeable chunk-kernels.

The paper's headline scenario (Table 6) is a log that does *not* fit in
device memory.  This module restructures every log algorithm around
device-sized partitions of a (case, time)-sorted log: an algorithm is a
:class:`ChunkKernel` — a 4-tuple ``(init, update, merge, finalize)``::

    state, carry = kernel.init()
    for chunk in chunks:                      # EventFrame chunks, in order
        state, carry = kernel.update(state, carry, chunk)
    result = kernel.finalize(state, carry)

* ``state`` is the mergeable partial result (count matrices, histograms,
  min/max accumulators).  ``merge(a, b)`` combines the states of two runs
  over consecutive log partitions whose boundary rows were stitched with
  carries; in the distributed lowering the merge is a ``psum``
  (``repro.distributed.dfg``) — one all-reduce whose payload is
  independent of N.
* ``carry`` is the one-row halo: the last row of the previous chunk
  (case id, activity, timestamp, row-validity, and an ``exists`` flag that
  is False only before the first row), plus kernel-specific streaming
  state (open global segment id, rolling variant hash, EFG prefix
  vector).  The carry is what stitches directly-follows pairs, case
  starts/ends, and case-local scans across chunk boundaries, so *any*
  chunking of a sorted log yields results identical to the whole-log pass
  — including cases split across many chunks.

The whole-log jitted entry points in ``core.dfg`` / ``core.stats`` /
``core.variants`` / ``core.performance`` / ``core.filtering`` are the
single-chunk special case of these kernels.  :func:`run_streaming` drives
a kernel over any iterable of chunks (``core.chunked.ChunkedEventFrame``:
EDF row groups on disk, an in-memory frame, or the synthetic generator)
with peak residency of one chunk's columns plus an O(1) carry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import polyhash
from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame

State = Any
Carry = dict
Chunk = EventFrame


@dataclasses.dataclass(frozen=True)
class ChunkKernel:
    """A log algorithm in mergeable chunk form (see module docstring).

    ``update`` is jit-compiled by the factory that builds the kernel; it
    retraces once per distinct chunk shape (a fixed-size chunk stream plus
    one tail shape compiles exactly twice).

    ``mask_exact`` declares the kernel stays exact on a pruned stream:
    either masked rows contribute nothing to the state (the usual case —
    they may still move the carry's case/segment bookkeeping), or the
    kernel recovers whatever masked rows would have contributed from the
    ghost-chunk metadata the query layer supplies.  This is what lets
    ``repro.query`` replace a row group whose rows are all refuted by a
    predicate with an O(segments) ghost chunk instead of reading it.

    ``ghost_sketch`` asks the query layer to attach per-segment affine
    polyhash maps (``repro.core.polyhash.SKETCH_COLUMNS``, composed from
    EDF header sketches) to the ghost chunks it synthesizes — how the
    variants kernel replays the exact validity-blind hash of skipped runs
    without reading them, keeping ``mask_exact=True``.

    ``columns`` names the event columns ``update`` reads (what a
    projected scan must materialize for this kernel).  The empty tuple
    means "unknown — read everything"; :func:`compose` unions member
    column sets, so a fused kernel's scan can never starve one member of
    a column it needs.

    ``stitch`` declares the kernel's *group-state algebra* support: given
    a :class:`StitchCtx` pairing two :class:`GroupState` fresh folds, it
    returns the state (and carry overrides) of the fresh fold of the
    concatenation — an O(1) boundary-halo fix on top of elementwise
    combination.  ``None`` marks the kernel non-mergeable at the group
    level (order-sensitive float accumulation: the sum of f32 chunk
    contributions depends on fold order bitwise), in which case drivers
    fall back to the sequential ``update`` stream.  Everything a stitch
    may consume is exact under reordering (integer counts, min/max,
    uint32 hashes, integer-valued f32 below 2^24), which is what makes
    the merge associative *bitwise*, not just mathematically.
    """

    name: str
    init: Callable[[], tuple[State, Carry]]
    update: Callable[[State, Carry, Chunk], tuple[State, Carry]]
    merge: Callable[[State, State], State]
    finalize: Callable[[State, Carry], Any]
    mask_exact: bool = True
    columns: tuple = ()
    ghost_sketch: bool = False
    stitch: Callable[["StitchCtx"], tuple[State, dict]] | None = None


# ------------------------------------------------------- kernel registry
class Dims(NamedTuple):
    """The two capacity dimensions that size every kernel's state."""

    num_activities: int
    num_cases: int


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A terminal mining verb as *data* — the registry entry behind the
    ``repro.dataset`` facade (and any other generic driver).

    Instead of an if-chain mapping verb names to kernel factories, each
    algorithm module registers one spec describing everything a driver
    needs to run it over any source:

    * ``make(dims, **kwargs)`` — build the :class:`ChunkKernel` (``dims``
      carries both capacity dimensions; the factory picks the one(s) its
      state needs);
    * ``columns`` — the event columns the kernel's ``update`` reads (what a
      scan must project; predicates add their own columns at plan time);
    * ``sharded_state`` — name of the distributed driver that produces this
      verb's mergeable state (``"dfg"`` / ``"discovery"``), or ``None`` when
      the verb has no exact distributed lowering (order-sensitive float
      sums, validity-blind hashes);
    * ``from_sharded(state, **kwargs)`` — host-side finalize mapping that
      distributed state to the verb's result (identity for DFG, the model
      discovery step for alpha/heuristics);
    * ``members`` — for fused specs (:func:`compose_specs`): the member
      verb names, in collection order (empty for an ordinary verb).
    """

    name: str
    make: Callable[..., ChunkKernel]
    columns: tuple
    sharded_state: str | None = None
    from_sharded: Callable | None = None
    doc: str = ""
    members: tuple = ()


_KERNEL_SPECS: dict[str, KernelSpec] = {}


def register_kernel(spec: KernelSpec) -> KernelSpec:
    """Register (or replace) a terminal verb; returns the spec for chaining."""
    _KERNEL_SPECS[spec.name] = spec
    return spec


def _load_standard_specs() -> None:
    # algorithm modules register their specs at import time; make sure the
    # standard set is loaded before deciding a name is unknown
    from . import dfg, discovery, performance, stats, variants  # noqa: F401
    from repro.graph import verbs  # noqa: F401


def kernel_spec(name: str) -> KernelSpec:
    """Look up a registered verb by name (KeyError lists what exists and
    suggests close matches for typos)."""
    if name not in _KERNEL_SPECS:
        _load_standard_specs()
    try:
        return _KERNEL_SPECS[name]
    except KeyError:
        import difflib

        close = difflib.get_close_matches(name, _KERNEL_SPECS, n=3)
        hint = f" (did you mean {' / '.join(map(repr, close))}?)" if close else ""
        raise KeyError(f"no kernel spec named {name!r}{hint}; registered: "
                       f"{sorted(_KERNEL_SPECS)}") from None


def kernel_specs() -> dict[str, KernelSpec]:
    """Snapshot of the registry (import the core modules to populate it)."""
    _load_standard_specs()
    return dict(_KERNEL_SPECS)


# --------------------------------------------------------------- carries
def init_row_carry(**extra) -> Carry:
    """The halo before the first row: ``exists=False`` masks everything."""
    carry = {
        "case": jnp.int32(-1),
        "act": jnp.int32(0),
        "ts": jnp.float32(0.0),
        "rv": jnp.bool_(False),
        "exists": jnp.bool_(False),
    }
    carry.update(extra)
    return carry


def next_row_carry(carry: Carry, frame: Chunk, **extra) -> Carry:
    """Carry for the next chunk: this chunk's last row + kernel extras."""
    out = dict(carry)
    out["case"] = frame[CASE][-1].astype(jnp.int32)
    out["act"] = frame[ACTIVITY][-1].astype(jnp.int32)
    if TIMESTAMP in frame:
        out["ts"] = frame[TIMESTAMP][-1].astype(jnp.float32)
    out["rv"] = frame.rows_valid()[-1]
    out["exists"] = jnp.bool_(True)
    out.update(extra)
    return out


class Adjacent(NamedTuple):
    """Per-row arrays pairing each row with its predecessor (carry at row 0).

    Semantics match the whole-log adjacency exactly: ``pair`` marks
    directly-follows pairs (same case, both rows valid), ``new_seg`` marks
    case-segment starts *ignoring* validity (as ``ops.segment_ids_sorted``
    does), ``is_start``/``end_prev`` are the start/end-activity events.
    ``end_prev[i]`` says row ``i-1`` (the carry for ``i=0``) ended its case;
    the final row's end is resolved by ``finalize`` from the last carry.
    """

    case: jax.Array
    act: jax.Array
    rv: jax.Array
    ts: jax.Array
    prev_case: jax.Array
    prev_act: jax.Array
    prev_rv: jax.Array
    prev_ts: jax.Array
    prev_exists: jax.Array
    new_seg: jax.Array      # bool — row starts a new case segment
    pair: jax.Array         # bool — (prev row -> row) is a valid DF pair
    is_start: jax.Array     # bool — row is a start activity
    end_prev: jax.Array     # bool — previous row was an end activity


def adjacent(frame: Chunk, carry: Carry, *, need_ts: bool = False) -> Adjacent:
    case = frame[CASE]
    act = frame[ACTIVITY]
    rv = frame.rows_valid()
    n = case.shape[0]
    if TIMESTAMP in frame:
        ts = frame[TIMESTAMP].astype(jnp.float32)
    elif need_ts:
        raise KeyError(TIMESTAMP)   # timed kernel on an untimed frame
    else:
        ts = jnp.zeros((n,), jnp.float32)
    prev_case = jnp.concatenate([carry["case"][None].astype(case.dtype), case[:-1]])
    prev_act = jnp.concatenate([carry["act"][None].astype(act.dtype), act[:-1]])
    prev_ts = jnp.concatenate([carry["ts"][None].astype(ts.dtype), ts[:-1]])
    prev_rv = jnp.concatenate([carry["rv"][None], rv[:-1]])
    prev_exists = jnp.concatenate(
        [carry["exists"][None], jnp.ones((n - 1,), bool)])
    new_seg = (case != prev_case) | ~prev_exists
    pair = (case == prev_case) & prev_exists & rv & prev_rv
    is_start = new_seg & rv
    end_prev = (case != prev_case) & prev_exists & prev_rv
    return Adjacent(case, act, rv, ts, prev_case, prev_act, prev_rv, prev_ts,
                    prev_exists, new_seg, pair, is_start, end_prev)


def global_segments(adj: Adjacent, carry: Carry) -> jax.Array:
    """Global case-segment ids for a chunk: ``carry['seg']`` continues the
    numbering (``-1`` before the first row, so the first segment is 0)."""
    return carry["seg"] + jnp.cumsum(adj.new_seg.astype(jnp.int32))


# --------------------------------------------------------------- drivers
def run_streaming(kernel: ChunkKernel, chunks: Iterable[Chunk]):
    """Fold a kernel over an ordered chunk stream; O(chunk) residency."""
    state, carry = kernel.init()
    for chunk in chunks:
        if chunk.nrows == 0:        # empty source / empty tail group
            continue
        state, carry = kernel.update(state, carry, chunk)
    return kernel.finalize(state, carry)


def run_single(kernel: ChunkKernel, frame: Chunk):
    """The single-chunk special case: how the whole-log jitted entry points
    route through the same kernel code as the streaming/distributed paths."""
    state, carry = kernel.init()
    state, carry = kernel.update(state, carry, frame)
    return kernel.finalize(state, carry)


# ------------------------------------------------- group-state algebra
# A GroupState is the *fresh* fold of a kernel over one contiguous unit of
# the sorted log (a row group, a shard span, a whole file): state + carry
# from ``init()``, case segments numbered locally from 0, plus the boundary
# halo a later merge needs — the unit's leading row(s) and the lead run's
# histogram/affine summaries.  ``merge_group_states`` reconstructs, bitwise,
# the fresh fold of the concatenation of two units, so
#
#     finalize(merge_tree([fold_group(unit) for unit in units]))
#     ==  run_streaming(kernel, all chunks)            (bitwise)
#
# for every kernel with a ``stitch``.  That single identity is what makes
# eager (one unit), streaming (one unit per row group, cacheable), sharded
# (one unit per shard span), windowed (merge a slice of units), and
# incremental (re-merge cached units + fold fresh ones) the *same* schedule
# family over one algebra.
@dataclasses.dataclass
class GroupState:
    """Fresh fold of one contiguous unit: mergeable, cacheable, re-usable.

    ``head`` / ``tail`` are the boundary halo (host-side python values):
    ``head["rows"]`` holds up to two leading physical rows (the two-row
    stitch the L2-loop kernels need), ``head["hist"]`` the valid-activity
    histogram of the unit's *lead run* (all leading rows of its first
    case — the EFG cross term), ``head["affine"]`` the validity-blind
    polyhash map of that lead run (the variants hash correction).
    ``segments``/``rows`` count case segments (locally numbered from 0)
    and physical rows.  ``rows == 0`` is the merge identity.
    """

    state: State
    carry: Carry
    head: dict | None
    tail: dict | None
    segments: int
    rows: int


class StitchCtx(NamedTuple):
    """Everything a kernel ``stitch`` may consult to merge ``a ++ b``:
    ``straddle`` says the boundary splits one case segment, ``offset`` is
    the relabel added to ``b``'s local segment ids (``a.segments``, minus
    one when the straddling segment keeps ``a``'s numbering)."""

    a: GroupState
    b: GroupState
    straddle: bool
    offset: int


def mergeable(kernel: ChunkKernel) -> bool:
    """Does this kernel support the group-state algebra (has a stitch)?"""
    return kernel.stitch is not None


def empty_group_state(kernel: ChunkKernel) -> GroupState:
    """The merge identity: the fresh fold of zero rows."""
    state, carry = kernel.init()
    return GroupState(state, carry, None, None, 0, 0)


def shift_segments(arr: jax.Array, offset: int, fill=0) -> jax.Array:
    """Relabel a per-segment state vector by ``offset`` slots (how a merge
    maps ``b``'s local segment ids into the concatenation's numbering).
    Entries shifted past capacity drop — matching the sequential fold's
    out-of-range scatter drop."""
    if offset <= 0:
        return arr
    cap = arr.shape[0]
    out = jnp.full_like(arr, fill)
    if offset < cap:
        out = out.at[offset:].set(arr[:cap - offset])
    return out


def _compose4(a: tuple, b: tuple) -> tuple:
    """Compose two (mul1, add1, mul2, add2) affine-map quadruples."""
    m1, a1 = polyhash.compose(a[0], a[1], b[0], b[1])
    m2, a2 = polyhash.compose(a[2], a[3], b[2], b[3])
    return (m1, a1, m2, a2)


def fold_group(kernel: ChunkKernel, chunks: Iterable[Chunk]) -> GroupState:
    """Fold a kernel *freshly* over one contiguous unit of the stream,
    capturing the boundary halo a later :func:`merge_group_states` needs.

    The state/carry fold is exactly :func:`run_streaming`'s loop (bitwise);
    the halo bookkeeping is host-side numpy over the same chunks.  Ghost
    chunks participate like real ones: their rows are masked (so the lead
    histogram stays empty) and their sketch columns supply the lead run's
    composed affine map.
    """
    state, carry = kernel.init()
    segments = 0
    rows = 0
    head_rows: list[dict] = []
    hist: dict[int, int] = {}
    affine = (1, 0, 1, 0)
    lead_open = True
    first_case = None
    tail = None
    for chunk in chunks:
        n = int(chunk.nrows)
        if n == 0:
            continue
        case = np.asarray(chunk[CASE])
        act = np.asarray(chunk[ACTIVITY])
        rv = np.asarray(chunk.rows_valid())
        cont = rows > 0 and int(case[0]) == tail["case"]
        changes = np.flatnonzero(case[1:] != case[:-1])
        segments += 1 + int(changes.size) - (1 if cont else 0)
        if rows == 0:
            first_case = int(case[0])
        while len(head_rows) < 2 and len(head_rows) < rows + n:
            i = len(head_rows) - rows
            head_rows.append({"case": int(case[i]), "act": int(act[i]),
                              "rv": bool(rv[i])})
        if lead_open and rows > 0 and not cont:
            lead_open = False
        if lead_open:
            k = int(changes[0]) + 1 if changes.size else n
            counts = np.bincount(act[:k][rv[:k]])
            for a_id in np.flatnonzero(counts):
                hist[int(a_id)] = hist.get(int(a_id), 0) + int(counts[a_id])
            if polyhash.SK_MUL1 in chunk:
                m1 = np.asarray(chunk[polyhash.SK_MUL1])[:k]
                a1 = np.asarray(chunk[polyhash.SK_ADD1])[:k]
                m2 = np.asarray(chunk[polyhash.SK_MUL2])[:k]
                a2 = np.asarray(chunk[polyhash.SK_ADD2])[:k]
                for i in np.flatnonzero((m1 != 1) | (a1 != 0)
                                        | (m2 != 1) | (a2 != 0)):
                    affine = _compose4(affine, (int(m1[i]), int(a1[i]),
                                                int(m2[i]), int(a2[i])))
            else:
                sk = polyhash.segment_sketch(act[:k], np.zeros(k, np.int64))
                affine = _compose4(affine, (int(sk["mul1"][0]),
                                            int(sk["add1"][0]),
                                            int(sk["mul2"][0]),
                                            int(sk["add2"][0])))
            if changes.size:
                lead_open = False
        state, carry = kernel.update(state, carry, chunk)
        rows += n
        tail = {"case": int(case[-1]), "act": int(act[-1]), "rv": bool(rv[-1])}
    if rows == 0:
        return GroupState(state, carry, None, None, 0, 0)
    head = {"case": first_case, "rows": tuple(head_rows),
            "hist": hist, "affine": affine}
    return GroupState(state, carry, head, tail, segments, rows)


def _shift_carry(carry, offset: int):
    """Recursively relabel every ``"seg"`` entry of a (possibly composed)
    carry by the merge's segment offset."""
    if not isinstance(carry, dict):
        return carry
    out = {}
    for k, v in carry.items():
        if k == "seg":
            out[k] = v + jnp.int32(offset)
        elif isinstance(v, dict):
            out[k] = _shift_carry(v, offset)
        else:
            out[k] = v
    return out


def _apply_overrides(carry: dict, overrides: dict) -> dict:
    out = dict(carry)
    for k, v in overrides.items():
        if isinstance(v, dict) and isinstance(out.get(k), dict):
            out[k] = _apply_overrides(out[k], v)
        else:
            out[k] = v
    return out


def _merge_head(a: GroupState, b: GroupState, straddle: bool) -> dict:
    head = dict(a.head)
    head["rows"] = (a.head["rows"] + b.head["rows"])[:2]
    if straddle and a.segments == 1:
        # a is entirely one case run that continues into b: the merged
        # unit's lead run is a's rows followed by b's lead run
        hist = dict(a.head["hist"])
        for act, cnt in b.head["hist"].items():
            hist[act] = hist.get(act, 0) + cnt
        head["hist"] = hist
        head["affine"] = _compose4(a.head["affine"], b.head["affine"])
    return head


def merge_group_states(kernel: ChunkKernel, a: GroupState,
                       b: GroupState) -> GroupState:
    """The algebra's ``merge``: the fresh fold of ``a ++ b``, bitwise.

    Elementwise state combination plus the kernel's O(1) boundary stitch;
    ``b``'s carry becomes the merged carry with its local segment ids
    relabelled (and any kernel-specific overrides applied).  Associative
    — merging reconstructs fresh folds, so any merge-tree shape over the
    same ordered units yields the same bits.
    """
    if a.rows == 0:
        return b
    if b.rows == 0:
        return a
    if kernel.stitch is None:
        raise ValueError(
            f"kernel {kernel.name!r} has no group-state stitch "
            "(order-sensitive float state); use the sequential fold")
    straddle = a.tail["case"] == b.head["case"]
    offset = a.segments - (1 if straddle else 0)
    state, overrides = kernel.stitch(StitchCtx(a, b, straddle, offset))
    carry = _shift_carry(b.carry, offset)
    if overrides:
        carry = _apply_overrides(carry, overrides)
    return GroupState(state, carry, _merge_head(a, b, straddle), b.tail,
                      a.segments + b.segments - (1 if straddle else 0),
                      a.rows + b.rows)


def merge_tree(kernel: ChunkKernel, states: Iterable[GroupState]) -> GroupState:
    """Reduce ordered unit states pairwise (a balanced merge tree).

    The tree shape is a free choice — the merge is bitwise-associative —
    so this is simultaneously the reduction the sharded engine runs over
    shard spans, the re-merge a sliding window runs over its ring of
    cached group states, and the combine an incremental collect runs over
    cached + fresh groups.
    """
    level = [s for s in states if s is not None and s.rows > 0]
    if not level:
        return empty_group_state(kernel)
    while len(level) > 1:
        nxt = [merge_group_states(kernel, level[i], level[i + 1])
               for i in range(0, len(level) - 1, 2)]
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


def finalize_group(kernel: ChunkKernel, gs: GroupState):
    """Terminal step of the algebra: the kernel's ordinary ``finalize``."""
    return kernel.finalize(gs.state, gs.carry)


def union_columns(column_sets: Iterable[tuple]) -> tuple:
    """Union column requirements in first-seen order; any *unknown* set
    (the empty tuple) makes the union unknown — read everything."""
    out: list = []
    for cols in column_sets:
        if not cols:
            return ()
        for c in cols:
            if c not in out:
                out.append(c)
    return tuple(out)


def compose(kernels: Mapping[str, ChunkKernel]) -> ChunkKernel:
    """Fuse kernels into one that shares a single pass over the stream.

    States/carries are dicts keyed like ``kernels``; ``finalize`` returns a
    dict of results. One disk scan computes DFG + stats + variants at once.

    The fused kernel's ``columns`` is the *union* of the members' column
    requirements (unknown if any member's is unknown), ``mask_exact`` the
    conjunction (every registered verb is pruning-exact, so fused scans
    always prune), and ``ghost_sketch`` the disjunction — one
    sketch-consuming member is enough for ghost chunks to carry sketches.
    """
    names = tuple(kernels)

    def init():
        pairs = {k: kernels[k].init() for k in names}
        return ({k: s for k, (s, _) in pairs.items()},
                {k: c for k, (_, c) in pairs.items()})

    def update(state, carry, chunk):
        out_s, out_c = {}, {}
        for k in names:
            out_s[k], out_c[k] = kernels[k].update(state[k], carry[k], chunk)
        return out_s, out_c

    def merge(a, b):
        return {k: kernels[k].merge(a[k], b[k]) for k in names}

    def finalize(state, carry):
        return {k: kernels[k].finalize(state[k], carry[k]) for k in names}

    # the fused kernel joins the group-state algebra exactly when every
    # member does: its stitch slices the dict state/carry per member and
    # runs each member's stitch under the shared boundary halo
    stitch = None
    if all(k.stitch is not None for k in kernels.values()):
        def stitch(ctx):
            states, overrides = {}, {}
            for k in names:
                sub = StitchCtx(
                    dataclasses.replace(ctx.a, state=ctx.a.state[k],
                                        carry=ctx.a.carry[k]),
                    dataclasses.replace(ctx.b, state=ctx.b.state[k],
                                        carry=ctx.b.carry[k]),
                    ctx.straddle, ctx.offset)
                states[k], over = kernels[k].stitch(sub)
                if over:
                    overrides[k] = over
            return states, overrides

    return ChunkKernel("compose(" + ",".join(names) + ")",
                       init, update, merge, finalize,
                       mask_exact=all(k.mask_exact for k in kernels.values()),
                       columns=union_columns(
                           k.columns for k in kernels.values()),
                       ghost_sketch=any(
                           k.ghost_sketch for k in kernels.values()),
                       stitch=stitch)


def compose_specs(specs: Mapping[str, KernelSpec]) -> KernelSpec:
    """Fuse registered verbs into one first-class :class:`KernelSpec`.

    The fused spec is what makes multi-verb collection an ordinary verb to
    every driver: its ``make`` builds the :func:`compose` of the member
    kernels (``verb_kwargs`` routes per-verb options), its ``columns`` is
    the union of the member column sets (the projection a shared scan must
    read), and its ``sharded_state`` is ``"fused"`` exactly when *every*
    member has an exact distributed lowering — ``repro.distributed.query``
    then drives the composed state kernels through the same ppermute-halo
    + psum path in one pass.  Results come back as ``{verb: result}``,
    bitwise equal per verb to running each member alone.
    """
    specs = dict(specs)
    if not specs:
        raise ValueError("compose_specs() needs at least one verb")
    names = tuple(specs)

    def make(dims: Dims, verb_kwargs: Mapping[str, dict] | None = None,
             **common) -> ChunkKernel:
        vk = dict(verb_kwargs or {})
        unknown = set(vk) - set(names)
        if unknown:
            raise KeyError(f"verb_kwargs for verbs not in the fused set: "
                           f"{sorted(unknown)} (fusing {list(names)})")
        return compose({v: specs[v].make(dims, **{**common, **vk.get(v, {})})
                        for v in names})

    sharded = ("fused" if all(s.sharded_state is not None
                              for s in specs.values()) else None)
    return KernelSpec(
        name="fused(" + ",".join(names) + ")",
        make=make,
        columns=union_columns(s.columns for s in specs.values()),
        sharded_state=sharded,
        from_sharded=None,      # the fused driver finalizes per member
        doc="fused multi-verb collection: " + ", ".join(names),
        members=names)


def tree_sum(a, b):
    """The common merge: leafwise addition of two partial states."""
    return jax.tree.map(jnp.add, a, b)


# --------------------------------------------- convenience streaming API
# Thin front doors; kernel factories live next to their whole-log twins
# (lazy imports keep core.<algo> -> engine one-directional).
def streaming_dfg(chunks, num_activities: int, method: str = "segment"):
    from .dfg import dfg_kernel
    return run_streaming(dfg_kernel(num_activities, method=method), chunks)


def streaming_activity_counts(chunks, num_activities: int):
    from .stats import activity_counts_kernel
    return run_streaming(activity_counts_kernel(num_activities), chunks)


def streaming_case_sizes(chunks, num_cases: int):
    from .stats import case_sizes_kernel
    return run_streaming(case_sizes_kernel(num_cases), chunks)


def streaming_case_durations(chunks, num_cases: int):
    from .stats import case_durations_kernel
    return run_streaming(case_durations_kernel(num_cases), chunks)


def streaming_sojourn_times(chunks, num_activities: int):
    from .stats import sojourn_times_kernel
    return run_streaming(sojourn_times_kernel(num_activities), chunks)


def streaming_variant_fingerprints(chunks, num_cases: int):
    from .variants import variants_kernel
    return run_streaming(variants_kernel(num_cases), chunks)


def streaming_variant_counts(chunks, num_cases: int):
    from .variants import streaming_variant_counts as _svc
    return _svc(chunks, num_cases)


def streaming_performance_dfg(chunks, num_activities: int):
    from .performance import performance_dfg_kernel
    return run_streaming(performance_dfg_kernel(num_activities), chunks)


def streaming_eventually_follows(chunks, num_activities: int):
    from .performance import eventually_follows_kernel
    return run_streaming(eventually_follows_kernel(num_activities), chunks)
