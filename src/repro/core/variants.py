"""Variants (distinct activity sequences per case) on EventFrames.

The paper lists "statistics for cases/variants" among the dataframe-specific
techniques taken into PM4Py. A variant is the sequence of activities of a
case; we fingerprint it with *two* independent 32-bit polynomial rolling
hashes — O(N), no per-case Python loop, and x64-free (JAX default config).
Collision probability ~ n_cases^2 / 2^64.

Both inner loops are ``repro.kernels.segment_ops`` primitives: the rolling
hash is ``segmented_scan(op="polyhash")`` (an affine-composition scan —
uint32 arithmetic is exact mod 2^32, so the Pallas doubling scan and the
XLA sequential fold are bitwise identical), and scattering each case's
fingerprint at its last event is ``segment_reduce(op="max")`` over the
global segment ids.  The scan is a left fold, so it streams:
:func:`variants_kernel` carries the open case's hash state across chunk
boundaries (``core.engine``) — the whole-log ``variant_fingerprints`` is
the single-chunk special case.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops import (segment_reduce, segmented_affine,
                                       segmented_scan)

from .eventframe import ACTIVITY, CASE, EventFrame
from .polyhash import BASE1 as _BASE1, BASE2 as _BASE2
from .polyhash import SK_ADD1, SK_ADD2, SK_MUL1, SK_MUL2
from . import backend as _backend
from . import engine, ops


def _hash_scan(act: jax.Array, starts: jax.Array, h0, impl: str | None):
    """Segmented rolling hash pair ``h <- h * BASE + (act + 1)`` (mod 2^32),
    restarting where ``starts`` is set; ``h0 = (h1, h2)`` seeds the first
    segment.  Returns ``((e1, e2), (hs1, hs2))`` — final carries + per-row
    inclusive hashes, matching the pre-primitive ``lax.scan`` bitwise."""
    a = act.astype(jnp.uint32) + 1
    hs1, e1 = segmented_scan(a, starts, h0[0], "polyhash", base=_BASE1,
                             impl=impl)
    hs2, e2 = segmented_scan(a, starts, h0[1], "polyhash", base=_BASE2,
                             impl=impl)
    return (e1, e2), (hs1, hs2)


# ------------------------------------------------------------ chunk kernel
def variants_kernel(num_cases: int, backend: str | None = None) -> engine.ChunkKernel:
    """Per-case variant fingerprints as a mergeable chunk-kernel.

    State: ``(fp1, fp2)`` uint32 arrays indexed by global segment id.
    Carry: the open case's rolling hash pair + its segment id.  A case's
    fingerprint is scattered when its last event is identified — within the
    chunk, at the next chunk's first row, or at ``finalize`` for the final
    case of the stream.  Hashing ignores row validity, matching the
    whole-log ``variant_fingerprints`` — yet the kernel is pruning-exact
    (``mask_exact=True``): ghost chunks synthesized for refuted row groups
    carry per-segment affine sketch columns (``core.polyhash``, composed
    from EDF headers), and the update folds those pre-composed maps through
    :func:`segmented_affine` instead of hashing rows, reproducing the
    skipped runs' hashes bitwise.
    """
    return _variants_kernel(num_cases, _backend.resolve(backend))


@lru_cache(maxsize=None)
def _variants_kernel(num_cases: int, impl: str) -> engine.ChunkKernel:

    def init():
        state = (jnp.zeros((num_cases,), jnp.uint32),
                 jnp.zeros((num_cases,), jnp.uint32))
        carry = engine.init_row_carry(seg=jnp.int32(-1),
                                      h1=jnp.uint32(0), h2=jnp.uint32(0))
        return state, carry

    @jax.jit
    def update(state, carry, chunk):
        fp1, fp2 = state
        adj = engine.adjacent(chunk, carry)
        seg = engine.global_segments(adj, carry)
        if SK_MUL1 in chunk:
            # ghost chunk: each row is a whole case run collapsed to its
            # composed affine map (padding rows are the identity) — fold
            # the maps instead of hashing rows; bitwise equal to hashing
            # the skipped group's actual activity stream
            hs1, e1 = segmented_affine(chunk[SK_MUL1], chunk[SK_ADD1],
                                       adj.new_seg, carry["h1"], impl=impl)
            hs2, e2 = segmented_affine(chunk[SK_MUL2], chunk[SK_ADD2],
                                       adj.new_seg, carry["h2"], impl=impl)
        else:
            (e1, e2), (hs1, hs2) = _hash_scan(adj.act, adj.new_seg,
                                              (carry["h1"], carry["h2"]),
                                              impl)
        # the carry case ended iff this chunk opens a new segment at row 0;
        # O(1) halo scatter, not an inner loop
        closed = adj.new_seg[0] & carry["exists"]
        fp1 = fp1.at[carry["seg"]].max(jnp.where(closed, carry["h1"], 0),
                                       mode="drop")
        fp2 = fp2.at[carry["seg"]].max(jnp.where(closed, carry["h2"], 0),
                                       mode="drop")
        # in-chunk case ends: rows whose successor starts a new segment
        ends = jnp.concatenate([adj.new_seg[1:], jnp.zeros((1,), bool)])
        fp1 = jnp.maximum(fp1, segment_reduce(
            jnp.where(ends, hs1, 0), seg, num_cases, "max", impl=impl))
        fp2 = jnp.maximum(fp2, segment_reduce(
            jnp.where(ends, hs2, 0), seg, num_cases, "max", impl=impl))
        carry = engine.next_row_carry(carry, chunk, seg=seg[-1], h1=e1, h2=e2)
        return (fp1, fp2), carry

    def merge(a, b):
        return (jnp.maximum(a[0], b[0]), jnp.maximum(a[1], b[1]))

    @jax.jit
    def finalize(state, carry):
        """Returns (fp1, fp2, ncases) — ncases is the number of segments seen."""
        fp1, fp2 = state
        keep = carry["exists"]
        fp1 = fp1.at[carry["seg"]].max(jnp.where(keep, carry["h1"], 0),
                                       mode="drop")
        fp2 = fp2.at[carry["seg"]].max(jnp.where(keep, carry["h2"], 0),
                                       mode="drop")
        return fp1, fp2, jnp.maximum(carry["seg"] + 1, 0)

    def stitch(ctx):
        afp1, afp2 = ctx.a.state
        bfp1, bfp2 = ctx.b.state
        off = ctx.offset
        ac = ctx.a.carry
        if not ctx.straddle:
            # the concatenation closes a's open case at b's first row
            # (new_seg): the deferred carry hash lands in a's last slot —
            # exactly the carry-close scatter update() runs at chunk joins
            slot = ctx.a.segments - 1
            afp1 = afp1.at[slot].max(ac["h1"], mode="drop")
            afp2 = afp2.at[slot].max(ac["h2"], mode="drop")
            return (jnp.maximum(afp1, engine.shift_segments(bfp1, off)),
                    jnp.maximum(afp2, engine.shift_segments(bfp2, off))), {}
        # the boundary splits one case: b's fresh fold hashed its lead run
        # from h=0, but the true hash threads a's open carry through the
        # lead run's composed affine map (validity-blind — for ghost units
        # the map came from header sketches, same bits either way)
        m1, a1, m2, a2 = ctx.b.head["affine"]
        h1c = jnp.uint32((m1 * int(ac["h1"]) + a1) & 0xFFFFFFFF)
        h2c = jnp.uint32((m2 * int(ac["h2"]) + a2) & 0xFFFFFFFF)
        sb1 = engine.shift_segments(bfp1, off)
        sb2 = engine.shift_segments(bfp2, off)
        if ctx.b.segments > 1:
            # the straddling case closed inside b: rewrite its slot with
            # the corrected hash (a's fold left that slot untouched, and
            # b's slot 0 held the seed-0 hash)
            sb1 = sb1.at[off].set(h1c, mode="drop")
            sb2 = sb2.at[off].set(h2c, mode="drop")
            return (jnp.maximum(afp1, sb1), jnp.maximum(afp2, sb2)), {}
        # b is entirely the straddling case — still open; fix the carry
        return (jnp.maximum(afp1, sb1), jnp.maximum(afp2, sb2)), \
            {"h1": h1c, "h2": h2c}

    # hashing ignores row validity (whole-log parity); pruning stays exact
    # because ghost chunks carry the skipped runs' composed sketch maps
    # (ghost_sketch=True asks the query layer to attach them)
    return engine.ChunkKernel(f"variants[{num_cases},{impl}]", init, update,
                              merge, finalize, mask_exact=True,
                              columns=(ACTIVITY, CASE), ghost_sketch=True,
                              stitch=stitch)


# ------------------------------------------------- whole-log entry points
def variant_fingerprints(frame: EventFrame, backend: str | None = None):
    """Per-case (fp1, fp2) fingerprints + segment ids.

    Frame must be sorted by (case, time). Returns arrays of length nrows;
    entries [0..ncases) of the first two are the per-case fingerprints
    (scattered by segment id) — the single-chunk form of
    :func:`variants_kernel` with nrows as the case capacity.
    """
    return _variant_fingerprints(frame, _backend.resolve(backend))


@partial(jax.jit, static_argnames=("impl",))
def _variant_fingerprints(frame: EventFrame, impl: str):
    seg, starts = ops.segment_ids_sorted(frame[CASE])
    (_, _), (hs1, hs2) = _hash_scan(frame[ACTIVITY], starts,
                                    (jnp.uint32(0), jnp.uint32(0)), impl)
    case = frame[CASE]
    is_end = jnp.concatenate([case[1:] != case[:-1], jnp.ones((1,), bool)])
    n = hs1.shape[0]
    fp1 = segment_reduce(jnp.where(is_end, hs1, 0), seg, n, "max", impl=impl)
    fp2 = segment_reduce(jnp.where(is_end, hs2, 0), seg, n, "max", impl=impl)
    return fp1, fp2, seg


def _counts_from_fps(fp1, fp2, ncases: int) -> dict[tuple[int, int], int]:
    import numpy as np

    pairs = np.stack([np.asarray(fp1)[:ncases], np.asarray(fp2)[:ncases]], axis=1)
    vals, counts = np.unique(pairs, axis=0, return_counts=True)
    return {(int(v[0]), int(v[1])): int(c) for v, c in zip(vals, counts)}


def variant_counts(frame: EventFrame) -> dict[tuple[int, int], int]:
    """Host-side: {fingerprint: number of cases} — the paper's 'Variants'."""
    import numpy as np

    fp1, fp2, seg = variant_fingerprints(frame)
    seg = np.asarray(seg)
    ncases = int(seg.max()) + 1 if len(seg) else 0
    return _counts_from_fps(fp1, fp2, ncases)


def streaming_variant_counts(chunks, num_cases: int) -> dict[tuple[int, int], int]:
    """Out-of-core 'Variants': one pass over the chunk stream."""
    fp1, fp2, ncases = engine.run_streaming(variants_kernel(num_cases), chunks)
    return _counts_from_fps(fp1, fp2, min(int(ncases), num_cases))


engine.register_kernel(engine.KernelSpec(
    "variants",
    make=lambda dims, backend=None: variants_kernel(dims.num_cases, backend),
    columns=(ACTIVITY, CASE),
    sharded_state="variants",
    from_sharded=lambda state, **_: state,
    doc="per-case variant fingerprints (validity-blind hashing; pruned "
        "scans replay skipped runs from header sketches)"))
