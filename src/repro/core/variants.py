"""Variants (distinct activity sequences per case) on EventFrames.

The paper lists "statistics for cases/variants" among the dataframe-specific
techniques taken into PM4Py. A variant is the sequence of activities of a
case; we fingerprint it with *two* independent 32-bit polynomial rolling
hashes computed by one segmented scan — O(N), no per-case Python loop, and
x64-free (JAX default config). Collision probability ~ n_cases^2 / 2^64.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, EventFrame
from . import ops

_BASE1 = jnp.uint32(1_000_003)
_BASE2 = jnp.uint32(16_777_619)  # FNV prime


@jax.jit
def variant_fingerprints(frame: EventFrame) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-case (fp1, fp2) fingerprints + segment ids.

    Frame must be sorted by (case, time). The rolling hashes
    ``h <- h * BASE + (act + 1)`` (mod 2^32, free on uint32) restart at each
    case boundary; the value at each case's last event is the variant
    fingerprint. Returns arrays of length nrows; entries [0..ncases) of the
    first two are the per-case fingerprints (scattered by segment id).
    """
    seg, starts = ops.segment_ids_sorted(frame[CASE])
    act = frame[ACTIVITY].astype(jnp.uint32) + 1

    def step(h, xs):
        a, is_start = xs
        h1, h2 = h
        h1 = jnp.where(is_start, jnp.uint32(0), h1) * _BASE1 + a
        h2 = jnp.where(is_start, jnp.uint32(0), h2) * _BASE2 + a
        return (h1, h2), (h1, h2)

    _, (hs1, hs2) = jax.lax.scan(step, (jnp.uint32(0), jnp.uint32(0)), (act, starts))
    case = frame[CASE]
    is_end = jnp.concatenate([case[1:] != case[:-1], jnp.ones((1,), bool)])
    n = hs1.shape[0]
    fp1 = jnp.zeros((n,), jnp.uint32).at[seg].max(jnp.where(is_end, hs1, 0))
    fp2 = jnp.zeros((n,), jnp.uint32).at[seg].max(jnp.where(is_end, hs2, 0))
    return fp1, fp2, seg


def variant_counts(frame: EventFrame) -> dict[tuple[int, int], int]:
    """Host-side: {fingerprint: number of cases} — the paper's 'Variants'."""
    import numpy as np

    fp1, fp2, seg = variant_fingerprints(frame)
    seg = np.asarray(seg)
    ncases = int(seg.max()) + 1 if len(seg) else 0
    pairs = np.stack([np.asarray(fp1)[:ncases], np.asarray(fp2)[:ncases]], axis=1)
    vals, counts = np.unique(pairs, axis=0, return_counts=True)
    return {(int(v[0]), int(v[1])): int(c) for v, c in zip(vals, counts)}
