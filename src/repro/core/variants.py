"""Variants (distinct activity sequences per case) on EventFrames.

The paper lists "statistics for cases/variants" among the dataframe-specific
techniques taken into PM4Py. A variant is the sequence of activities of a
case; we fingerprint it with *two* independent 32-bit polynomial rolling
hashes computed by one segmented scan — O(N), no per-case Python loop, and
x64-free (JAX default config). Collision probability ~ n_cases^2 / 2^64.

The rolling hash is a left fold, so it streams: :func:`variants_kernel`
carries the open case's hash state across chunk boundaries (``core.engine``)
and scatters a case's fingerprint the moment its last event is seen — the
whole-log ``variant_fingerprints`` is the single-chunk special case.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, EventFrame
from . import engine, ops

_BASE1 = jnp.uint32(1_000_003)
_BASE2 = jnp.uint32(16_777_619)  # FNV prime


def _hash_scan(act: jax.Array, starts: jax.Array, h0):
    """Segmented rolling hash ``h <- h * BASE + (act + 1)`` (mod 2^32),
    restarting where ``starts`` is set; ``h0`` seeds the first segment."""
    a = act.astype(jnp.uint32) + 1

    def step(h, xs):
        ai, is_start = xs
        h1, h2 = h
        h1 = jnp.where(is_start, jnp.uint32(0), h1) * _BASE1 + ai
        h2 = jnp.where(is_start, jnp.uint32(0), h2) * _BASE2 + ai
        return (h1, h2), (h1, h2)

    return jax.lax.scan(step, h0, (a, starts))


# ------------------------------------------------------------ chunk kernel
@lru_cache(maxsize=None)
def variants_kernel(num_cases: int) -> engine.ChunkKernel:
    """Per-case variant fingerprints as a mergeable chunk-kernel.

    State: ``(fp1, fp2)`` uint32 arrays indexed by global segment id.
    Carry: the open case's rolling hash pair + its segment id.  A case's
    fingerprint is scattered when its last event is identified — within the
    chunk, at the next chunk's first row, or at ``finalize`` for the final
    case of the stream.  Hashing ignores row validity, matching the
    whole-log ``variant_fingerprints``.
    """

    def init():
        state = (jnp.zeros((num_cases,), jnp.uint32),
                 jnp.zeros((num_cases,), jnp.uint32))
        carry = engine.init_row_carry(seg=jnp.int32(-1),
                                      h1=jnp.uint32(0), h2=jnp.uint32(0))
        return state, carry

    @jax.jit
    def update(state, carry, chunk):
        fp1, fp2 = state
        adj = engine.adjacent(chunk, carry)
        seg = engine.global_segments(adj, carry)
        (e1, e2), (hs1, hs2) = _hash_scan(adj.act, adj.new_seg,
                                          (carry["h1"], carry["h2"]))
        # the carry case ended iff this chunk opens a new segment at row 0
        closed = adj.new_seg[0] & carry["exists"]
        fp1 = fp1.at[carry["seg"]].max(jnp.where(closed, carry["h1"], 0),
                                       mode="drop")
        fp2 = fp2.at[carry["seg"]].max(jnp.where(closed, carry["h2"], 0),
                                       mode="drop")
        # in-chunk case ends: rows whose successor starts a new segment
        ends = jnp.concatenate([adj.new_seg[1:], jnp.zeros((1,), bool)])
        fp1 = fp1.at[seg].max(jnp.where(ends, hs1, 0), mode="drop")
        fp2 = fp2.at[seg].max(jnp.where(ends, hs2, 0), mode="drop")
        carry = engine.next_row_carry(carry, chunk, seg=seg[-1], h1=e1, h2=e2)
        return (fp1, fp2), carry

    def merge(a, b):
        return (jnp.maximum(a[0], b[0]), jnp.maximum(a[1], b[1]))

    @jax.jit
    def finalize(state, carry):
        """Returns (fp1, fp2, ncases) — ncases is the number of segments seen."""
        fp1, fp2 = state
        keep = carry["exists"]
        fp1 = fp1.at[carry["seg"]].max(jnp.where(keep, carry["h1"], 0),
                                       mode="drop")
        fp2 = fp2.at[carry["seg"]].max(jnp.where(keep, carry["h2"], 0),
                                       mode="drop")
        return fp1, fp2, jnp.maximum(carry["seg"] + 1, 0)

    return engine.ChunkKernel(f"variants[{num_cases}]", init, update,
                              merge, finalize)


# ------------------------------------------------- whole-log entry points
@jax.jit
def variant_fingerprints(frame: EventFrame) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-case (fp1, fp2) fingerprints + segment ids.

    Frame must be sorted by (case, time). Returns arrays of length nrows;
    entries [0..ncases) of the first two are the per-case fingerprints
    (scattered by segment id) — the single-chunk form of
    :func:`variants_kernel` with nrows as the case capacity.
    """
    seg, starts = ops.segment_ids_sorted(frame[CASE])
    (_, _), (hs1, hs2) = _hash_scan(frame[ACTIVITY], starts,
                                    (jnp.uint32(0), jnp.uint32(0)))
    case = frame[CASE]
    is_end = jnp.concatenate([case[1:] != case[:-1], jnp.ones((1,), bool)])
    n = hs1.shape[0]
    fp1 = jnp.zeros((n,), jnp.uint32).at[seg].max(jnp.where(is_end, hs1, 0))
    fp2 = jnp.zeros((n,), jnp.uint32).at[seg].max(jnp.where(is_end, hs2, 0))
    return fp1, fp2, seg


def _counts_from_fps(fp1, fp2, ncases: int) -> dict[tuple[int, int], int]:
    import numpy as np

    pairs = np.stack([np.asarray(fp1)[:ncases], np.asarray(fp2)[:ncases]], axis=1)
    vals, counts = np.unique(pairs, axis=0, return_counts=True)
    return {(int(v[0]), int(v[1])): int(c) for v, c in zip(vals, counts)}


def variant_counts(frame: EventFrame) -> dict[tuple[int, int], int]:
    """Host-side: {fingerprint: number of cases} — the paper's 'Variants'."""
    import numpy as np

    fp1, fp2, seg = variant_fingerprints(frame)
    seg = np.asarray(seg)
    ncases = int(seg.max()) + 1 if len(seg) else 0
    return _counts_from_fps(fp1, fp2, ncases)


def streaming_variant_counts(chunks, num_cases: int) -> dict[tuple[int, int], int]:
    """Out-of-core 'Variants': one pass over the chunk stream."""
    fp1, fp2, ncases = engine.run_streaming(variants_kernel(num_cases), chunks)
    return _counts_from_fps(fp1, fp2, min(int(ncases), num_cases))
