"""EventFrame: the paper's dataframe abstraction (Def. 3) as a JAX pytree.

A dataframe is ``D = (I, N, T, V, chi_val, chi_type)``:

* ``I``     — row indexes. Here implicit ``0..nrows-1``; projection keeps ``I``
              lazy through a ``row_valid`` mask (no dynamic shapes on device).
* ``N``     — attribute (column) names; pytree aux data.
* ``T``     — attribute types; carried by the arrays' dtypes.
* ``V``     — attribute values. Strings are dictionary-encoded to dense int32
              ids at the host boundary (see ``repro.data.tokenizer``); the
              device only ever sees numeric columns — this is the columnar /
              Parquet-dictionary story of the paper made TPU-native.
* ``chi_val``  — per-cell valuation: ``columns[name][i]``; ``epsilon`` (missing)
              is a per-column validity bitmask (Arrow-style), so integer
              columns stay integer.
* ``chi_type`` — ``columns[name].dtype``.

The structure is registered as a pytree so it can be sharded with
``NamedSharding``, passed through ``jit`` / ``shard_map``, and donated.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

# Canonical column names (XES vocabulary, dictionary-encoded on device).
CASE = "case:concept:name"
ACTIVITY = "concept:name"
TIMESTAMP = "time:timestamp"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EventFrame:
    """Columnar event dataframe. All columns share a common length ``nrows``.

    ``valid`` holds per-column epsilon masks only for columns that can have
    missing values (absent key => column is total). ``row_valid`` is the lazy
    projection mask: ``proj`` marks rows instead of compacting them, keeping
    shapes static under jit. ``compact`` materializes at the host boundary.
    """

    columns: dict[str, jax.Array]
    valid: dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    row_valid: jax.Array | None = None

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        col_names = tuple(sorted(self.columns))
        val_names = tuple(sorted(self.valid))
        children = (
            [self.columns[k] for k in col_names]
            + [self.valid[k] for k in val_names]
            + ([self.row_valid] if self.row_valid is not None else [])
        )
        aux = (col_names, val_names, self.row_valid is not None)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        col_names, val_names, has_rv = aux
        nc, nv = len(col_names), len(val_names)
        cols = dict(zip(col_names, children[:nc]))
        vals = dict(zip(val_names, children[nc : nc + nv]))
        rv = children[nc + nv] if has_rv else None
        return cls(columns=cols, valid=vals, row_valid=rv)

    # ------------------------------------------------------------ helpers
    @property
    def nrows(self) -> int:
        return int(next(iter(self.columns.values())).shape[0]) if self.columns else 0

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self.columns))

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def cell_valid(self, name: str) -> jax.Array:
        """epsilon mask for a column, combined with the row projection mask."""
        n = self.nrows
        v = self.valid.get(name, jnp.ones((n,), dtype=bool))
        if self.row_valid is not None:
            v = v & self.row_valid
        return v

    def rows_valid(self) -> jax.Array:
        if self.row_valid is not None:
            return self.row_valid
        return jnp.ones((self.nrows,), dtype=bool)

    def with_column(self, name: str, values: jax.Array, valid: jax.Array | None = None) -> "EventFrame":
        cols = dict(self.columns)
        cols[name] = values
        vals = dict(self.valid)
        if valid is not None:
            vals[name] = valid
        return EventFrame(cols, vals, self.row_valid)

    def select(self, names: Iterable[str]) -> "EventFrame":
        """Column projection — the paper's load-time attribute selection."""
        names = tuple(names)
        return EventFrame(
            {k: self.columns[k] for k in names},
            {k: v for k, v in self.valid.items() if k in names},
            self.row_valid,
        )

    def take(self, idx: jax.Array) -> "EventFrame":
        return EventFrame(
            {k: v[idx] for k, v in self.columns.items()},
            {k: v[idx] for k, v in self.valid.items()},
            self.row_valid[idx] if self.row_valid is not None else None,
        )

    def compact(self) -> "EventFrame":
        """Materialize the lazy projection mask (host boundary; dynamic shape)."""
        if self.row_valid is None:
            return self
        keep = np.asarray(self.row_valid)
        idx = np.nonzero(keep)[0]
        return EventFrame(
            {k: jnp.asarray(np.asarray(v)[idx]) for k, v in self.columns.items()},
            {k: jnp.asarray(np.asarray(v)[idx]) for k, v in self.valid.items()},
            None,
        )

    # --------------------------------------------------------- construct
    @staticmethod
    def from_numpy(columns: Mapping[str, np.ndarray], valid: Mapping[str, np.ndarray] | None = None) -> "EventFrame":
        lens = {k: len(v) for k, v in columns.items()}
        if len(set(lens.values())) > 1:
            raise ValueError(f"ragged columns: {lens}")
        return EventFrame(
            {k: jnp.asarray(v) for k, v in columns.items()},
            {k: jnp.asarray(v) for k, v in (valid or {}).items()},
        )

    def to_numpy(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.columns.items()}


def concat_frames(parts) -> EventFrame:
    """Row-wise concatenation of same-schema frames (host-side).

    Epsilon masks and the lazy ``row_valid`` projection mask concatenate
    *separately* — folding ``row_valid`` into per-column validity would
    change what ``rows_valid()`` means to the kernels.  A column missing
    a part's epsilon mask contributes all-valid rows.  The single shared
    implementation behind dataset unions, eager multi-file loads, and
    pruned-scan materialization.
    """
    parts = list(parts)
    if not parts:
        raise ValueError("concat_frames() needs at least one frame")
    names = set(parts[0].names)
    for p in parts[1:]:
        if set(p.names) != names:
            raise ValueError(f"concat of frames with different columns: "
                             f"{sorted(names)} vs {sorted(p.names)}")
    cols = {k: np.concatenate([np.asarray(p.columns[k]) for p in parts])
            for k in parts[0].names}
    valid_names = set().union(*(set(p.valid) for p in parts))
    valid = {k: np.concatenate([
        np.asarray(p.valid[k]) if k in p.valid else np.ones(p.nrows, bool)
        for p in parts]) for k in valid_names}
    out = EventFrame.from_numpy(cols, valid)
    if any(p.row_valid is not None for p in parts):
        rv = np.concatenate([np.asarray(p.rows_valid()) for p in parts])
        out = EventFrame(out.columns, out.valid, jnp.asarray(rv))
    return out
