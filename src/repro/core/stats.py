"""Case/event statistics on EventFrames (segment reductions, all O(N))."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from . import ops


@partial(jax.jit, static_argnames=("num_cases",))
def case_sizes(frame: EventFrame, num_cases: int) -> jax.Array:
    seg, _ = ops.segment_ids_sorted(frame[CASE])
    return jnp.zeros((num_cases,), jnp.int32).at[seg].add(frame.rows_valid().astype(jnp.int32))


@partial(jax.jit, static_argnames=("num_cases",))
def case_durations(frame: EventFrame, num_cases: int) -> jax.Array:
    """max(ts) - min(ts) per case (sorted frame)."""
    seg, _ = ops.segment_ids_sorted(frame[CASE])
    ts = frame[TIMESTAMP].astype(jnp.float32)
    big = jnp.finfo(jnp.float32).max
    rv = frame.rows_valid()
    tmin = jnp.full((num_cases,), big).at[seg].min(jnp.where(rv, ts, big))
    tmax = jnp.full((num_cases,), -big).at[seg].max(jnp.where(rv, ts, -big))
    return jnp.where(tmax >= tmin, tmax - tmin, 0.0)


@partial(jax.jit, static_argnames=("num_activities",))
def activity_counts(frame: EventFrame, num_activities: int) -> jax.Array:
    act = jnp.where(frame.rows_valid(), frame[ACTIVITY], num_activities)
    return ops.value_counts(act, num_activities + 1)[:-1]


@partial(jax.jit, static_argnames=("num_activities",))
def sojourn_times(frame: EventFrame, num_activities: int) -> jax.Array:
    """Mean inter-event time by *source* activity (bottleneck analysis)."""
    case = frame[CASE]
    ts = frame[TIMESTAMP].astype(jnp.float32)
    rv = frame.rows_valid()
    same = (case[1:] == case[:-1]) & rv[1:] & rv[:-1]
    dt = jnp.where(same, ts[1:] - ts[:-1], 0.0)
    src = jnp.where(same, frame[ACTIVITY][:-1], num_activities)
    tot = jnp.zeros((num_activities + 1,), jnp.float32).at[src].add(dt)
    cnt = jnp.zeros((num_activities + 1,), jnp.int32).at[src].add(same.astype(jnp.int32))
    return (tot / jnp.maximum(cnt, 1))[:-1]
