"""Case/event statistics on EventFrames (segment reductions, all O(N)).

Each statistic is a mergeable chunk-kernel (``core.engine``): the public
whole-log functions are the single-chunk special case, and the same update
streams over EDF row groups for logs larger than device memory.  Cases
split across chunk boundaries are stitched by the carry (global segment id
+ last-row halo), so any chunking of a (case,time)-sorted log matches the
whole-log result.

Inner loops are the named primitives of ``repro.kernels.segment_ops``
(backend-dispatched, see ``core.backend``): per-case reductions are
``segment_reduce`` over the sorted global segment ids, per-activity
aggregations are ``histogram``.  Integer counting takes whichever lowering
the backend picks (bitwise identical); the float sojourn *totals* are
order-sensitive and stay on the row-order XLA scatter (see
``segment_ops.ops``), keeping streaming == whole-log bitwise.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops import histogram, segment_reduce

from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from . import backend as _backend
from . import engine

_FBIG = jnp.float32(3.4028235e38)   # finfo(float32).max, as a literal


# ------------------------------------------------------------ chunk kernels
def case_sizes_kernel(num_cases: int, backend: str | None = None) -> engine.ChunkKernel:
    """Valid-event count per case, indexed by global segment id."""
    return _case_sizes_kernel(num_cases, _backend.resolve(backend))


@lru_cache(maxsize=None)
def _case_sizes_kernel(num_cases: int, impl: str) -> engine.ChunkKernel:

    def init():
        return (jnp.zeros((num_cases,), jnp.int32),
                engine.init_row_carry(seg=jnp.int32(-1)))

    @jax.jit
    def update(state, carry, chunk):
        adj = engine.adjacent(chunk, carry)
        seg = engine.global_segments(adj, carry)
        state = state + segment_reduce(adj.rv.astype(jnp.int32), seg,
                                       num_cases, "sum", impl=impl)
        return state, engine.next_row_carry(carry, chunk, seg=seg[-1])

    def stitch(ctx):
        # per-row valid counts are position-free: relabel b's local segment
        # slots and add (a straddling segment's halves land in one slot)
        return ctx.a.state + engine.shift_segments(ctx.b.state,
                                                   ctx.offset), {}

    return engine.ChunkKernel(f"case_sizes[{num_cases},{impl}]", init, update,
                              engine.tree_sum, lambda s, c: s,
                              columns=(ACTIVITY, CASE), stitch=stitch)


def case_durations_kernel(num_cases: int, backend: str | None = None) -> engine.ChunkKernel:
    """max(ts) - min(ts) per case; state = (tmin, tmax) accumulators."""
    return _case_durations_kernel(num_cases, _backend.resolve(backend))


@lru_cache(maxsize=None)
def _case_durations_kernel(num_cases: int, impl: str) -> engine.ChunkKernel:

    def init():
        state = (jnp.full((num_cases,), _FBIG),
                 jnp.full((num_cases,), -_FBIG))
        return state, engine.init_row_carry(seg=jnp.int32(-1))

    @jax.jit
    def update(state, carry, chunk):
        tmin, tmax = state
        adj = engine.adjacent(chunk, carry, need_ts=True)
        seg = engine.global_segments(adj, carry)
        tmin = jnp.minimum(tmin, segment_reduce(
            jnp.where(adj.rv, adj.ts, jnp.inf), seg, num_cases, "min",
            impl=impl))
        tmax = jnp.maximum(tmax, segment_reduce(
            jnp.where(adj.rv, adj.ts, -jnp.inf), seg, num_cases, "max",
            impl=impl))
        return (tmin, tmax), engine.next_row_carry(carry, chunk, seg=seg[-1])

    def merge(a, b):
        return (jnp.minimum(a[0], b[0]), jnp.maximum(a[1], b[1]))

    @jax.jit
    def finalize(state, carry):
        tmin, tmax = state
        return jnp.where(tmax >= tmin, tmax - tmin, 0.0)

    def stitch(ctx):
        amin, amax = ctx.a.state
        bmin, bmax = ctx.b.state
        # min/max are exact and order-free: shift b's slots (identity
        # fills) and combine elementwise
        return (jnp.minimum(amin, engine.shift_segments(
                    bmin, ctx.offset, _FBIG)),
                jnp.maximum(amax, engine.shift_segments(
                    bmax, ctx.offset, -_FBIG))), {}

    return engine.ChunkKernel(f"case_durations[{num_cases},{impl}]", init,
                              update, merge, finalize,
                              columns=(ACTIVITY, CASE, TIMESTAMP),
                              stitch=stitch)


def activity_counts_kernel(num_activities: int, backend: str | None = None) -> engine.ChunkKernel:
    """Per-activity histogram — stateless per chunk, carry only pro forma."""
    return _activity_counts_kernel(num_activities, _backend.resolve(backend))


@lru_cache(maxsize=None)
def _activity_counts_kernel(num_activities: int, impl: str) -> engine.ChunkKernel:
    a = num_activities

    def init():
        return jnp.zeros((a,), jnp.int32), engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        state = state + histogram(chunk[ACTIVITY], a,
                                  weights=chunk.rows_valid(), impl=impl)
        return state, engine.next_row_carry(carry, chunk)

    return engine.ChunkKernel(f"activity_counts[{a},{impl}]", init, update,
                              engine.tree_sum, lambda s, c: s,
                              columns=(ACTIVITY, CASE),
                              # boundary-free integer histogram: the merge
                              # IS the stitch
                              stitch=lambda ctx: (ctx.a.state + ctx.b.state,
                                                  {}))


def sojourn_times_kernel(num_activities: int, backend: str | None = None) -> engine.ChunkKernel:
    """Mean inter-event time by *source* activity; boundary pairs stitched
    by the carry's (case, act, ts) halo."""
    return _sojourn_times_kernel(num_activities, _backend.resolve(backend))


@lru_cache(maxsize=None)
def _sojourn_times_kernel(num_activities: int, impl: str) -> engine.ChunkKernel:
    a = num_activities

    def init():
        state = (jnp.zeros((a,), jnp.float32), jnp.zeros((a,), jnp.int32))
        return state, engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        tot, cnt = state
        adj = engine.adjacent(chunk, carry, need_ts=True)
        dt = jnp.where(adj.pair, adj.ts - adj.prev_ts, 0.0)
        # float accumulation is order-sensitive: the dispatch layer keeps it
        # on the XLA scatter (no assume_exact), and into= scatters onto the
        # running state in row order, keeping streaming == whole-log bitwise
        tot = histogram(adj.prev_act, a, weights=dt, into=tot, impl=None)
        cnt = cnt + histogram(adj.prev_act, a, weights=adj.pair, impl=impl)
        return (tot, cnt), engine.next_row_carry(carry, chunk)

    @jax.jit
    def finalize(state, carry):
        tot, cnt = state
        return tot / jnp.maximum(cnt, 1)

    # stitch=None: the f32 dt totals accumulate in row order; regrouping
    # them is not bitwise-stable, so the kernel opts out of the
    # group-state algebra and keeps the sequential fold
    return engine.ChunkKernel(f"sojourn_times[{a},{impl}]", init, update,
                              engine.tree_sum, finalize,
                              columns=(ACTIVITY, CASE, TIMESTAMP))


# ------------------------------------------------- whole-log entry points
def case_sizes(frame: EventFrame, num_cases: int,
               backend: str | None = None) -> jax.Array:
    return engine.run_single(case_sizes_kernel(num_cases, backend), frame)


def case_durations(frame: EventFrame, num_cases: int,
                   backend: str | None = None) -> jax.Array:
    """max(ts) - min(ts) per case (sorted frame)."""
    return engine.run_single(case_durations_kernel(num_cases, backend), frame)


def activity_counts(frame: EventFrame, num_activities: int,
                    backend: str | None = None) -> jax.Array:
    return engine.run_single(activity_counts_kernel(num_activities, backend),
                             frame)


def sojourn_times(frame: EventFrame, num_activities: int,
                  backend: str | None = None) -> jax.Array:
    """Mean inter-event time by *source* activity (bottleneck analysis)."""
    return engine.run_single(sojourn_times_kernel(num_activities, backend),
                             frame)


def stats_kernel(num_activities: int, num_cases: int,
                 backend: str | None = None) -> engine.ChunkKernel:
    """All four statistics fused into one pass over the stream (one disk
    scan serves a whole dashboard panel)."""
    return engine.compose({
        "activity_counts": activity_counts_kernel(num_activities, backend),
        "case_sizes": case_sizes_kernel(num_cases, backend),
        "case_durations": case_durations_kernel(num_cases, backend),
        "sojourn_times": sojourn_times_kernel(num_activities, backend),
    })


engine.register_kernel(engine.KernelSpec(
    "activity_counts",
    make=lambda dims, backend=None: activity_counts_kernel(
        dims.num_activities, backend),
    columns=(ACTIVITY, CASE),
    doc="per-activity event histogram"))
engine.register_kernel(engine.KernelSpec(
    "case_sizes",
    make=lambda dims, backend=None: case_sizes_kernel(dims.num_cases, backend),
    columns=(ACTIVITY, CASE),
    doc="valid-event count per case"))
engine.register_kernel(engine.KernelSpec(
    "case_durations",
    make=lambda dims, backend=None: case_durations_kernel(
        dims.num_cases, backend),
    columns=(ACTIVITY, CASE, TIMESTAMP),
    doc="max(ts) - min(ts) per case"))
engine.register_kernel(engine.KernelSpec(
    "sojourn_times",
    make=lambda dims, backend=None: sojourn_times_kernel(
        dims.num_activities, backend),
    columns=(ACTIVITY, CASE, TIMESTAMP),
    doc="mean inter-event time by source activity"))
engine.register_kernel(engine.KernelSpec(
    "stats",
    make=lambda dims, backend=None: stats_kernel(
        dims.num_activities, dims.num_cases, backend),
    columns=(ACTIVITY, CASE, TIMESTAMP),
    doc="activity_counts + case_sizes + case_durations + sojourn_times, "
        "one fused pass"))
