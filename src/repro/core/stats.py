"""Case/event statistics on EventFrames (segment reductions, all O(N)).

Each statistic is a mergeable chunk-kernel (``core.engine``): the public
whole-log jitted functions are the single-chunk special case, and the same
update streams over EDF row groups for logs larger than device memory.
Cases split across chunk boundaries are stitched by the carry (global
segment id + last-row halo), so any chunking of a (case,time)-sorted log
matches the whole-log result.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from . import engine, ops

_FBIG = jnp.float32(3.4028235e38)   # finfo(float32).max, as a literal


# ------------------------------------------------------------ chunk kernels
@lru_cache(maxsize=None)
def case_sizes_kernel(num_cases: int) -> engine.ChunkKernel:
    """Valid-event count per case, indexed by global segment id."""

    def init():
        return (jnp.zeros((num_cases,), jnp.int32),
                engine.init_row_carry(seg=jnp.int32(-1)))

    @jax.jit
    def update(state, carry, chunk):
        adj = engine.adjacent(chunk, carry)
        seg = engine.global_segments(adj, carry)
        state = state.at[seg].add(adj.rv.astype(jnp.int32), mode="drop")
        return state, engine.next_row_carry(carry, chunk, seg=seg[-1])

    return engine.ChunkKernel(f"case_sizes[{num_cases}]", init, update,
                              engine.tree_sum, lambda s, c: s)


@lru_cache(maxsize=None)
def case_durations_kernel(num_cases: int) -> engine.ChunkKernel:
    """max(ts) - min(ts) per case; state = (tmin, tmax) accumulators."""

    def init():
        state = (jnp.full((num_cases,), _FBIG),
                 jnp.full((num_cases,), -_FBIG))
        return state, engine.init_row_carry(seg=jnp.int32(-1))

    @jax.jit
    def update(state, carry, chunk):
        tmin, tmax = state
        adj = engine.adjacent(chunk, carry, need_ts=True)
        seg = engine.global_segments(adj, carry)
        tmin = tmin.at[seg].min(jnp.where(adj.rv, adj.ts, _FBIG), mode="drop")
        tmax = tmax.at[seg].max(jnp.where(adj.rv, adj.ts, -_FBIG), mode="drop")
        return (tmin, tmax), engine.next_row_carry(carry, chunk, seg=seg[-1])

    def merge(a, b):
        return (jnp.minimum(a[0], b[0]), jnp.maximum(a[1], b[1]))

    @jax.jit
    def finalize(state, carry):
        tmin, tmax = state
        return jnp.where(tmax >= tmin, tmax - tmin, 0.0)

    return engine.ChunkKernel(f"case_durations[{num_cases}]", init, update,
                              merge, finalize)


@lru_cache(maxsize=None)
def activity_counts_kernel(num_activities: int) -> engine.ChunkKernel:
    """Per-activity histogram — stateless per chunk, carry only pro forma."""
    a = num_activities

    def init():
        return jnp.zeros((a,), jnp.int32), engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        act = jnp.where(chunk.rows_valid(), chunk[ACTIVITY], a)
        state = state + ops.value_counts(act, a + 1)[:-1]
        return state, engine.next_row_carry(carry, chunk)

    return engine.ChunkKernel(f"activity_counts[{a}]", init, update,
                              engine.tree_sum, lambda s, c: s)


@lru_cache(maxsize=None)
def sojourn_times_kernel(num_activities: int) -> engine.ChunkKernel:
    """Mean inter-event time by *source* activity; boundary pairs stitched
    by the carry's (case, act, ts) halo."""
    a = num_activities

    def init():
        state = (jnp.zeros((a + 1,), jnp.float32), jnp.zeros((a + 1,), jnp.int32))
        return state, engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        tot, cnt = state
        adj = engine.adjacent(chunk, carry, need_ts=True)
        dt = jnp.where(adj.pair, adj.ts - adj.prev_ts, 0.0)
        src = jnp.where(adj.pair, adj.prev_act, a)
        tot = tot.at[src].add(dt)
        cnt = cnt.at[src].add(adj.pair.astype(jnp.int32))
        return (tot, cnt), engine.next_row_carry(carry, chunk)

    @jax.jit
    def finalize(state, carry):
        tot, cnt = state
        return (tot / jnp.maximum(cnt, 1))[:-1]

    return engine.ChunkKernel(f"sojourn_times[{a}]", init, update,
                              engine.tree_sum, finalize)


# ------------------------------------------------- whole-log entry points
@partial(jax.jit, static_argnames=("num_cases",))
def case_sizes(frame: EventFrame, num_cases: int) -> jax.Array:
    return engine.run_single(case_sizes_kernel(num_cases), frame)


@partial(jax.jit, static_argnames=("num_cases",))
def case_durations(frame: EventFrame, num_cases: int) -> jax.Array:
    """max(ts) - min(ts) per case (sorted frame)."""
    return engine.run_single(case_durations_kernel(num_cases), frame)


@partial(jax.jit, static_argnames=("num_activities",))
def activity_counts(frame: EventFrame, num_activities: int) -> jax.Array:
    return engine.run_single(activity_counts_kernel(num_activities), frame)


@partial(jax.jit, static_argnames=("num_activities",))
def sojourn_times(frame: EventFrame, num_activities: int) -> jax.Array:
    """Mean inter-event time by *source* activity (bottleneck analysis)."""
    return engine.run_single(sojourn_times_kernel(num_activities), frame)
