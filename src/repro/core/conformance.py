"""DFG-footprint conformance checking (lightweight, dataframe-native).

The paper positions DFGs as the basis for discovery (IMDF [13]) and for
conversion to Petri nets for conformance [14]. We implement the dataframe-
native check: given a *model* DFG (allowed directly-follows relations), score
a log by the fraction of observed directly-follows pairs that the model
allows — computed entirely as masked matrix ops on the dense count matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .dfg import DFG


@jax.jit
def footprint_fitness(log_dfg: DFG, model_allowed: jax.Array) -> jax.Array:
    """Fraction of observed pair occurrences permitted by ``model_allowed``
    (A, A) bool. 1.0 == perfectly conformant."""
    c = log_dfg.counts.astype(jnp.float32)
    tot = jnp.maximum(c.sum(), 1.0)
    ok = jnp.where(model_allowed, c, 0.0).sum()
    return ok / tot


@jax.jit
def footprint_deviations(log_dfg: DFG, model_allowed: jax.Array) -> jax.Array:
    """Count matrix restricted to disallowed pairs (where deviations happen)."""
    return jnp.where(model_allowed, 0, log_dfg.counts)


def discover_model(log_dfg: DFG, noise_threshold: float = 0.0) -> jax.Array:
    """IMDF-style noise filtering: keep edges with count > threshold * max
    outgoing count of their source (the DFG-cleaning step of [13])."""
    c = log_dfg.counts.astype(jnp.float32)
    row_max = jnp.maximum(c.max(axis=1, keepdims=True), 1.0)
    return c > noise_threshold * row_max
