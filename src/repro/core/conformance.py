"""Conformance checking against DFG footprints and discovered models.

The paper positions DFGs as the basis for discovery (IMDF [13]) and for
conversion to Petri nets for conformance [14]. Three dataframe-native
checks, all masked matrix ops on dense count/relation matrices:

* **footprint fitness** — given a *model* DFG (allowed directly-follows
  relations), the fraction of observed pair occurrences the model allows;
* **footprint conformance** — cell-wise agreement between a log's footprint
  relations and a discovered :class:`~repro.core.discovery.AlphaModel`'s
  footprint (the classic footprint-matrix comparison);
* **heuristics fitness** — replay of the observed pair mass against a
  :class:`~repro.core.discovery.HeuristicsNet`'s dependency graph.

Every check consumes only the mergeable DFG state, so it scores streamed,
sharded, and whole-log accumulations identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .dfg import DFG


@jax.jit
def footprint_fitness(log_dfg: DFG, model_allowed: jax.Array) -> jax.Array:
    """Fraction of observed pair occurrences permitted by ``model_allowed``
    (A, A) bool. 1.0 == perfectly conformant.

    An empty (or fully-filtered) log observes nothing, so it deviates from
    nothing: vacuous conformance scores 1.0, not 0.0.
    """
    c = log_dfg.counts.astype(jnp.float32)
    tot = c.sum()
    ok = jnp.where(model_allowed, c, 0.0).sum()
    return jnp.where(tot > 0.0, ok / jnp.maximum(tot, 1.0), 1.0)


@jax.jit
def footprint_deviations(log_dfg: DFG, model_allowed: jax.Array) -> jax.Array:
    """Count matrix restricted to disallowed pairs (where deviations happen)."""
    return jnp.where(model_allowed, 0, log_dfg.counts)


def discover_model(log_dfg: DFG, noise_threshold: float = 0.0) -> jax.Array:
    """IMDF-style noise filtering: keep edges with count > threshold * max
    outgoing count of their source (the DFG-cleaning step of [13])."""
    c = log_dfg.counts.astype(jnp.float32)
    row_max = jnp.maximum(c.max(axis=1, keepdims=True), 1.0)
    return c > noise_threshold * row_max


# ------------------------------------------------ discovered-model replay
@jax.jit
def _footprint_agreement(log_direct: jax.Array, model_direct: jax.Array):
    agree = (log_direct == model_direct) & (log_direct.T == model_direct.T)
    return agree, agree.mean()


def footprint_conformance(log_dfg: DFG, model) -> jax.Array:
    """Footprint-matrix conformance of a log against an alpha model (or any
    object with a ``.footprint``, or a raw :class:`Footprint`).

    Every (a, b) cell carries one of the alpha relation classes (causal /
    reverse-causal / parallel / choice), fully determined by the ordered
    pair ``(direct[a, b], direct[b, a])``; the score is the fraction of
    cells whose class in the log matches the model.  1.0 == the log's
    footprint is exactly the model's.
    """
    from .discovery import footprint

    fp = getattr(model, "footprint", model)
    log_fp = footprint(log_dfg)
    _, score = _footprint_agreement(log_fp.direct, fp.direct)
    return score


def footprint_disagreements(log_dfg: DFG, model) -> jax.Array:
    """(A, A) bool matrix of footprint cells where log and model disagree."""
    from .discovery import footprint

    fp = getattr(model, "footprint", model)
    log_fp = footprint(log_dfg)
    agree, _ = _footprint_agreement(log_fp.direct, fp.direct)
    return ~agree


def alpha_fitness(log_dfg: DFG, model) -> jax.Array:
    """Replay fitness of a log against an alpha model: the fraction of
    observed directly-follows mass on relations the model's footprint
    permits (causal or parallel — i.e. its ``direct`` matrix)."""
    fp = getattr(model, "footprint", model)
    return footprint_fitness(log_dfg, fp.direct)


def heuristics_fitness(log_dfg: DFG, net) -> jax.Array:
    """Dependency-graph fitness of a log against a heuristics net: the
    fraction of observed directly-follows mass that travels kept edges of
    ``net.graph`` (L1 loops are diagonal entries and count as kept)."""
    return footprint_fitness(log_dfg, net.graph)
