"""Performance-annotated DFG + eventually-follows graph (bottleneck analysis).

The paper's motivating analyses ("bottleneck analysis, remaining time
prediction, logical-temporal checking", §1) need *timed* relations, not just
counts. Both structures below are single-pass columnar reductions, keeping
the Table-3/4 complexity story:

* ``performance_dfg`` — mean/total inter-event waiting time per
  directly-follows edge (the classic performance overlay);
* ``eventually_follows`` — counts of (a ... b) pairs within a case, the
  relation used by LTL-style checks; computed with a per-case suffix-count
  trick: for each event, the number of *later* events of each activity in
  the same case, O(N·A) via reversed segmented cumsum.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from . import ops


@partial(jax.jit, static_argnames=("num_activities",))
def performance_dfg(frame: EventFrame, num_activities: int):
    """(counts, mean_wait) per edge; frame sorted by (case, time)."""
    a = num_activities
    case = frame[CASE]
    act = frame[ACTIVITY]
    ts = frame[TIMESTAMP].astype(jnp.float32)
    rv = frame.rows_valid()
    same = (case[1:] == case[:-1]) & rv[1:] & rv[:-1]
    key = jnp.where(same, act[:-1] * a + act[1:], a * a)
    dt = jnp.where(same, ts[1:] - ts[:-1], 0.0)
    counts = jnp.zeros((a * a + 1,), jnp.int32).at[key].add(1)[:-1].reshape(a, a)
    total = jnp.zeros((a * a + 1,), jnp.float32).at[key].add(dt)[:-1].reshape(a, a)
    mean = total / jnp.maximum(counts, 1)
    return counts, mean


@partial(jax.jit, static_argnames=("num_activities",))
def eventually_follows(frame: EventFrame, num_activities: int) -> jax.Array:
    """EFG counts: efg[a, b] = #(event pairs i<j, same case, act_i=a, act_j=b).

    Reversed segmented cumulative one-hot: suffix[i, b] = number of events of
    activity b after i within the case; then efg[a] += suffix[i] for each
    event i of activity a. O(N*A) work, one scan.
    """
    a = num_activities
    case = frame[CASE]
    act = frame[ACTIVITY]
    rv = frame.rows_valid()
    onehot = (jax.nn.one_hot(act, a, dtype=jnp.float32)
              * rv[:, None].astype(jnp.float32))
    is_case_end = jnp.concatenate([case[1:] != case[:-1], jnp.ones((1,), bool)])

    def step(suffix, xs):
        oh, end = xs
        # reversed scan: a forward case-END is the first element of its case
        # we meet — the carry belongs to the previous (different) case.
        suffix = jnp.where(end, jnp.zeros_like(suffix), suffix)
        out = suffix                     # later-events count, exclusive of i
        suffix = suffix + oh
        return suffix, out

    # scan right-to-left
    _, suffixes = jax.lax.scan(
        step, jnp.zeros((a,), jnp.float32),
        (onehot[::-1], is_case_end[::-1]))
    suffixes = suffixes[::-1]          # suffixes[i, b] = later-b count (excl.)
    efg = jnp.einsum("ia,ib->ab", onehot, suffixes)
    return efg.astype(jnp.int32)


def remaining_time_targets(frame: EventFrame) -> jax.Array:
    """Per-event remaining time to case end (regression targets for the
    'remaining time prediction' analysis; feeds the LM pipeline as labels)."""
    case = frame[CASE]
    ts = frame[TIMESTAMP].astype(jnp.float32)
    seg, _ = ops.segment_ids_sorted(case)
    n = int(seg.shape[0])
    big = jnp.float32(-3.4e38)
    tmax = jnp.full((n,), big).at[seg].max(ts)
    return tmax[seg] - ts
