"""Performance-annotated DFG + eventually-follows graph (bottleneck analysis).

The paper's motivating analyses ("bottleneck analysis, remaining time
prediction, logical-temporal checking", §1) need *timed* relations, not just
counts. Both structures below are single-pass columnar reductions, keeping
the Table-3/4 complexity story, and both are expressed as mergeable
chunk-kernels (``core.engine``) so they stream over logs larger than device
memory:

* ``performance_dfg`` — mean/total inter-event waiting time per
  directly-follows edge (the classic performance overlay); the boundary
  pair of two chunks is stitched by the carry's (case, act, ts) halo.
* ``eventually_follows`` — counts of (a ... b) pairs within a case, the
  relation used by LTL-style checks.  Computed with a per-case *prefix*
  count vector: for each event of activity b, add the count of earlier
  same-case events of every activity a — O(N·A) via one forward segmented
  scan whose carry (the open case's prefix vector) streams across chunks.
  Counts stay < 2^24 per cell in float32, so the accumulation is exact.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from . import engine, ops


# ------------------------------------------------------------ chunk kernels
@lru_cache(maxsize=None)
def performance_dfg_kernel(num_activities: int) -> engine.ChunkKernel:
    """(counts, total wait) per directly-follows edge; mean at finalize."""
    a = num_activities

    def init():
        state = (jnp.zeros((a * a + 1,), jnp.int32),
                 jnp.zeros((a * a + 1,), jnp.float32))
        return state, engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        counts, total = state
        adj = engine.adjacent(chunk, carry, need_ts=True)
        key = jnp.where(adj.pair, adj.prev_act * a + adj.act, a * a)
        dt = jnp.where(adj.pair, adj.ts - adj.prev_ts, 0.0)
        counts = counts.at[key].add(1)
        total = total.at[key].add(dt)
        return (counts, total), engine.next_row_carry(carry, chunk)

    @jax.jit
    def finalize(state, carry):
        counts = state[0][:-1].reshape(a, a)
        total = state[1][:-1].reshape(a, a)
        return counts, total / jnp.maximum(counts, 1)

    return engine.ChunkKernel(f"performance_dfg[{a}]", init, update,
                              engine.tree_sum, finalize)


@lru_cache(maxsize=None)
def eventually_follows_kernel(num_activities: int) -> engine.ChunkKernel:
    """EFG as a forward segmented scan; carry = open case's prefix vector."""
    a = num_activities

    def init():
        state = jnp.zeros((a, a), jnp.float32)
        return state, engine.init_row_carry(prefix=jnp.zeros((a,), jnp.float32))

    @jax.jit
    def update(state, carry, chunk):
        adj = engine.adjacent(chunk, carry)
        onehot = (jax.nn.one_hot(adj.act, a, dtype=jnp.float32)
                  * adj.rv[:, None].astype(jnp.float32))

        def step(prefix, xs):
            oh, is_start = xs
            prefix = jnp.where(is_start, jnp.zeros_like(prefix), prefix)
            out = prefix                 # earlier-events count, exclusive
            return prefix + oh, out

        last, prefixes = jax.lax.scan(step, carry["prefix"],
                                      (onehot, adj.new_seg))
        state = state + jnp.einsum("ia,ib->ab", prefixes, onehot)
        return state, engine.next_row_carry(carry, chunk, prefix=last)

    @jax.jit
    def finalize(state, carry):
        return state.astype(jnp.int32)

    return engine.ChunkKernel(f"eventually_follows[{a}]", init, update,
                              engine.tree_sum, finalize)


# ------------------------------------------------- whole-log entry points
@partial(jax.jit, static_argnames=("num_activities",))
def performance_dfg(frame: EventFrame, num_activities: int):
    """(counts, mean_wait) per edge; frame sorted by (case, time)."""
    return engine.run_single(performance_dfg_kernel(num_activities), frame)


@partial(jax.jit, static_argnames=("num_activities",))
def eventually_follows(frame: EventFrame, num_activities: int) -> jax.Array:
    """EFG counts: efg[a, b] = #(event pairs i<j, same case, act_i=a, act_j=b);
    the single-chunk special case of :func:`eventually_follows_kernel`."""
    return engine.run_single(eventually_follows_kernel(num_activities), frame)


def remaining_time_targets(frame: EventFrame) -> jax.Array:
    """Per-event remaining time to case end (regression targets for the
    'remaining time prediction' analysis; feeds the LM pipeline as labels)."""
    case = frame[CASE]
    ts = frame[TIMESTAMP].astype(jnp.float32)
    seg, _ = ops.segment_ids_sorted(case)
    n = int(seg.shape[0])
    big = jnp.float32(-3.4e38)
    tmax = jnp.full((n,), big).at[seg].max(ts)
    return tmax[seg] - ts
