"""Performance-annotated DFG + eventually-follows graph (bottleneck analysis).

The paper's motivating analyses ("bottleneck analysis, remaining time
prediction, logical-temporal checking", §1) need *timed* relations, not just
counts. Both structures below are single-pass columnar reductions, keeping
the Table-3/4 complexity story, and both are expressed as mergeable
chunk-kernels (``core.engine``) so they stream over logs larger than device
memory — with inner loops on the ``repro.kernels.segment_ops`` primitives:

* ``performance_dfg`` — mean/total inter-event waiting time per
  directly-follows edge (the classic performance overlay).  Edge counts are
  one ``pair_count`` (backend-dispatched, integer-exact on any lowering);
  the float wait totals are a second ``pair_count`` that the dispatch layer
  keeps on the row-order XLA scatter (order-sensitive float accumulation —
  see ``segment_ops.ops``).  The boundary pair of two chunks is stitched by
  the carry's (case, act, ts) halo.
* ``eventually_follows`` — counts of (a ... b) pairs within a case, the
  relation used by LTL-style checks.  The per-case *prefix* count vector is
  a ``segmented_scan`` over one-hot rows (prefix counts are integer-valued
  float32, sums < 2^24, so the scan is exact and ``assume_exact=True``
  unlocks the Pallas lowering); the contraction into the (A, A) matrix is
  an einsum — already MXU-native, no scatter.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops import pair_count, segment_reduce, segmented_scan

from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from . import backend as _backend
from . import engine, ops


# ------------------------------------------------------------ chunk kernels
def performance_dfg_kernel(num_activities: int, backend: str | None = None) -> engine.ChunkKernel:
    """(counts, total wait) per directly-follows edge; mean at finalize."""
    return _performance_dfg_kernel(num_activities, _backend.resolve(backend))


@lru_cache(maxsize=None)
def _performance_dfg_kernel(num_activities: int, impl: str) -> engine.ChunkKernel:
    a = num_activities

    def init():
        state = (jnp.zeros((a, a), jnp.int32),
                 jnp.zeros((a, a), jnp.float32))
        return state, engine.init_row_carry()

    @jax.jit
    def update(state, carry, chunk):
        counts, total = state
        adj = engine.adjacent(chunk, carry, need_ts=True)
        dt = jnp.where(adj.pair, adj.ts - adj.prev_ts, 0.0)
        counts = counts + pair_count(adj.prev_act, adj.act, a,
                                     weights=adj.pair, impl=impl)
        # float wait totals: order-sensitive — dispatch keeps them on the
        # XLA scatter, and into= accumulates in row order onto the state
        total = pair_count(adj.prev_act, adj.act, a,
                           weights=dt, into=total, impl=None)
        return (counts, total), engine.next_row_carry(carry, chunk)

    @jax.jit
    def finalize(state, carry):
        counts, total = state
        return counts, total / jnp.maximum(counts, 1)

    # stitch=None: the f32 wait totals accumulate in row order, so the
    # kernel opts out of the group-state algebra (sequential fold only)
    return engine.ChunkKernel(f"performance_dfg[{a},{impl}]", init, update,
                              engine.tree_sum, finalize,
                              columns=(ACTIVITY, CASE, TIMESTAMP))


def eventually_follows_kernel(num_activities: int, backend: str | None = None) -> engine.ChunkKernel:
    """EFG as a forward segmented scan; carry = open case's prefix vector."""
    return _eventually_follows_kernel(num_activities, _backend.resolve(backend))


@lru_cache(maxsize=None)
def _eventually_follows_kernel(num_activities: int, impl: str) -> engine.ChunkKernel:
    a = num_activities

    def init():
        state = jnp.zeros((a, a), jnp.float32)
        return state, engine.init_row_carry(prefix=jnp.zeros((a,), jnp.float32))

    @jax.jit
    def update(state, carry, chunk):
        adj = engine.adjacent(chunk, carry)
        onehot = (jax.nn.one_hot(adj.act, a, dtype=jnp.float32)
                  * adj.rv[:, None].astype(jnp.float32))
        # inclusive segmented prefix counts (integer-valued f32 -> exact)
        incl, last = segmented_scan(onehot, adj.new_seg, carry["prefix"],
                                    "sum", impl=impl, assume_exact=True)
        prefixes = incl - onehot            # exclusive: earlier-events count
        state = state + jnp.einsum("ia,ib->ab", prefixes, onehot)
        return state, engine.next_row_carry(carry, chunk, prefix=last)

    @jax.jit
    def finalize(state, carry):
        return state.astype(jnp.int32)

    def stitch(ctx):
        import numpy as np

        # b's lead-run rows scanned from a zero prefix; the concatenation
        # threads a's open prefix through them, adding exactly
        # outer(a.prefix, lead-run valid-activity histogram).  All values
        # are integer-valued f32 < 2^24, so the cross term is exact.
        state = ctx.a.state + ctx.b.state
        overrides = {}
        if ctx.straddle:
            hist = np.zeros((a,), np.float32)
            for act, cnt in ctx.b.head["hist"].items():
                if 0 <= act < a:
                    hist[act] = cnt
            state = state + jnp.outer(ctx.a.carry["prefix"],
                                      jnp.asarray(hist))
            if ctx.b.segments == 1:
                # the straddling case is still open: its true prefix is
                # both halves' counts
                overrides["prefix"] = (ctx.a.carry["prefix"]
                                       + ctx.b.carry["prefix"])
        return state, overrides

    return engine.ChunkKernel(f"eventually_follows[{a},{impl}]", init, update,
                              engine.tree_sum, finalize,
                              columns=(ACTIVITY, CASE), stitch=stitch)


# ------------------------------------------------- whole-log entry points
def performance_dfg(frame: EventFrame, num_activities: int,
                    backend: str | None = None):
    """(counts, mean_wait) per edge; frame sorted by (case, time)."""
    return engine.run_single(performance_dfg_kernel(num_activities, backend),
                             frame)


def eventually_follows(frame: EventFrame, num_activities: int,
                       backend: str | None = None) -> jax.Array:
    """EFG counts: efg[a, b] = #(event pairs i<j, same case, act_i=a, act_j=b);
    the single-chunk special case of :func:`eventually_follows_kernel`."""
    return engine.run_single(eventually_follows_kernel(num_activities, backend),
                             frame)


def remaining_time_targets(frame: EventFrame, backend: str | None = None) -> jax.Array:
    """Per-event remaining time to case end (regression targets for the
    'remaining time prediction' analysis; feeds the LM pipeline as labels).

    ``segment_reduce(op="max")`` over the case segments (exact — min/max is
    order-insensitive), broadcast back through the segment ids.
    """
    case = frame[CASE]
    ts = frame[TIMESTAMP].astype(jnp.float32)
    seg, _ = ops.segment_ids_sorted(case)
    n = int(seg.shape[0])
    tmax = segment_reduce(ts, seg, n, "max", impl=_backend.resolve(backend))
    return tmax[seg] - ts


engine.register_kernel(engine.KernelSpec(
    "performance_dfg",
    make=lambda dims, backend=None: performance_dfg_kernel(
        dims.num_activities, backend),
    columns=(ACTIVITY, CASE, TIMESTAMP),
    doc="mean/total waiting time per directly-follows edge"))
engine.register_kernel(engine.KernelSpec(
    "eventually_follows",
    make=lambda dims, backend=None: eventually_follows_kernel(
        dims.num_activities, backend),
    columns=(ACTIVITY, CASE),
    doc="eventually-follows pair counts within cases"))
