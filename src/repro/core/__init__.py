"""Core library: the paper's event-dataframe abstraction and algorithms."""
from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from .classic_log import ClassicEventLog, make_classic_log
from .dfg import DFG, dfg, dfg_matmul, dfg_segment, dfg_shift_count
from . import conformance, filtering, ops, stats, variants

__all__ = [
    "ACTIVITY", "CASE", "TIMESTAMP", "EventFrame", "ClassicEventLog",
    "make_classic_log", "DFG", "dfg", "dfg_matmul", "dfg_segment",
    "dfg_shift_count", "conformance", "filtering", "ops", "stats", "variants",
]
