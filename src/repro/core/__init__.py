"""Core library: the paper's event-dataframe abstraction and algorithms."""
from .eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame
from .classic_log import ClassicEventLog, make_classic_log
from .backend import get_backend, set_backend, use_backend
from .dfg import DFG, dfg, dfg_kernel, dfg_matmul, dfg_segment, dfg_shift_count
from .engine import ChunkKernel, compose, run_streaming
from .chunked import ChunkedEventFrame
from .discovery import (AlphaModel, DiscoveryState, Footprint, HeuristicsNet,
                        discover_alpha, discover_heuristics)
from . import (backend, conformance, discovery, engine, filtering, ops, stats,
               variants)

__all__ = [
    "ACTIVITY", "CASE", "TIMESTAMP", "EventFrame", "ClassicEventLog",
    "make_classic_log", "DFG", "dfg", "dfg_kernel", "dfg_matmul",
    "dfg_segment", "dfg_shift_count", "ChunkKernel", "ChunkedEventFrame",
    "AlphaModel", "DiscoveryState", "Footprint", "HeuristicsNet",
    "discover_alpha", "discover_heuristics", "compose", "run_streaming",
    "backend", "get_backend", "set_backend", "use_backend", "conformance",
    "discovery", "engine", "filtering", "ops", "stats", "variants",
]
