"""Backend dispatch for the segmented-primitive layer (``kernels.segment_ops``).

Every core algorithm's inner loop is one of four named columnar primitives
(``segment_reduce`` / ``histogram`` / ``pair_count`` / ``segmented_scan``),
and each primitive has two interchangeable lowerings:

* ``"pallas"`` — the Pallas TPU kernel (MXU one-hot matmul or VPU tiled
  reduction over the sorted stream).  On non-TPU backends the kernel body
  runs in interpret mode, so CPU-only CI validates the exact code the TPU
  executes.
* ``"xla"``    — the reference scatter/scan lowering (the paper's direct
  translation).  Row-order accumulation, used as the parity oracle and as
  the mandatory path for order-sensitive float accumulations.

Selection, most specific wins:

1. an explicit ``impl=`` argument at a primitive call site;
2. :func:`set_backend` / the :func:`use_backend` context manager;
3. the ``REPRO_SEGMENT_BACKEND`` environment variable (read at import);
4. ``"auto"``: pallas on TPU, xla elsewhere.

Backend choice is resolved when a kernel factory / primitive is *built*
(trace time).  The core factories include the resolved backend in their
cache keys, so ``use_backend("pallas")`` reliably rebuilds kernels inside
a process; plain jitted closures that dispatched at trace time keep their
original backend until retraced — CI therefore runs the pallas pass as a
separate process with ``REPRO_SEGMENT_BACKEND=pallas``.
"""
from __future__ import annotations

import contextlib
import os

import jax

ENV_VAR = "REPRO_SEGMENT_BACKEND"
BACKENDS = ("auto", "pallas", "xla")

_state = {"backend": os.environ.get(ENV_VAR, "auto")}


def get_backend() -> str:
    """The currently selected backend name (may be ``"auto"``)."""
    return _state["backend"]


def set_backend(name: str) -> None:
    if name not in BACKENDS:
        raise ValueError(f"unknown segment-ops backend {name!r}; "
                         f"expected one of {BACKENDS}")
    _state["backend"] = name


@contextlib.contextmanager
def use_backend(name: str):
    """Temporarily select a backend (tests: parity on both lowerings)."""
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def resolve(impl: str | None = None) -> str:
    """Concrete lowering for a primitive call: ``"pallas"`` or ``"xla"``."""
    b = impl if impl is not None else _state["backend"]
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if b not in ("pallas", "xla"):
        raise ValueError(f"unknown segment-ops impl {b!r}")
    return b


def interpret_mode() -> bool:
    """Pallas kernels run in interpret mode off-TPU (CPU CI validation)."""
    return jax.default_backend() != "tpu"
