"""Event- and case-level filters on EventFrames (paper §6 / PM4Py parity).

Event-level filtering is the paper's O(N) columnar op. Case-level filtering
("keep every event of any case that has property P") is the operation the
paper calls out as needing custom dataframe techniques — here it is a
two-phase mask broadcast: per-case predicate via segment reduction, then
expansion back to events through the case segment ids.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .eventframe import ACTIVITY, CASE, EventFrame
from . import ops


def filter_attr_values(frame: EventFrame, name: str, values, keep: bool = True) -> EventFrame:
    """Keep (or drop) events whose ``name`` is in ``values`` (event-level)."""
    col = frame[name]
    vals = jnp.asarray(values)
    m = (col[:, None] == vals[None, :]).any(axis=-1)
    return ops.proj(frame, m if keep else ~m)


def filter_time_range(frame: EventFrame, name: str, lo, hi) -> EventFrame:
    col = frame[name]
    return ops.proj(frame, (col >= lo) & (col <= hi))


@partial(jax.jit, static_argnames=("num_cases",))
def _case_mask_to_event_mask(case_seg: jax.Array, case_keep: jax.Array, num_cases: int) -> jax.Array:
    return case_keep[case_seg]


def filter_cases_containing(frame: EventFrame, activity: int, num_cases: int) -> EventFrame:
    """Case-level: keep all events of cases that contain ``activity``.

    Requires frame sorted by (case, time); uses segment ids + scatter-or.
    """
    seg, _ = ops.segment_ids_sorted(frame[CASE])
    hit = (frame[ACTIVITY] == activity) & frame.rows_valid()
    case_keep = jnp.zeros((num_cases,), bool).at[seg].max(hit)
    return ops.proj(frame, _case_mask_to_event_mask(seg, case_keep, num_cases))


def filter_case_size(frame: EventFrame, min_events: int, max_events: int, num_cases: int) -> EventFrame:
    """Case-level: keep cases whose (valid-)event count is within bounds."""
    seg, _ = ops.segment_ids_sorted(frame[CASE])
    sizes = jnp.zeros((num_cases,), jnp.int32).at[seg].add(frame.rows_valid().astype(jnp.int32))
    case_keep = (sizes >= min_events) & (sizes <= max_events)
    return ops.proj(frame, case_keep[seg])


def most_common_activity(frame: EventFrame, num_activities: int) -> jax.Array:
    """The paper's Table-5 filter target: the most frequent activity."""
    act = jnp.where(frame.rows_valid(), frame[ACTIVITY], num_activities)
    counts = ops.value_counts(act, num_activities + 1)[:-1]
    return jnp.argmax(counts)
