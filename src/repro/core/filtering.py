"""Event- and case-level filters on EventFrames (paper §6 / PM4Py parity).

Event-level filtering is the paper's O(N) columnar op — stateless, so it
chunks trivially. Case-level filtering ("keep every event of any case that
has property P") is the operation the paper calls out as needing custom
dataframe techniques — a two-phase mask broadcast: per-case predicate via
segment reduction, then expansion back to events through the case segment
ids.  Both phases are expressed over the chunk-kernels of ``core.engine``:
phase one is a mergeable scatter-or/size reduction (streams over EDF row
groups), phase two is a second pass that re-derives global segment ids per
chunk from a carry and narrows each chunk's ``row_valid``.
"""
from __future__ import annotations

import warnings
from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops import histogram, segment_reduce

from .eventframe import ACTIVITY, CASE, EventFrame
from . import backend as _backend
from . import engine, ops
from .stats import case_sizes_kernel


def isin_mask(col: jax.Array, values) -> jax.Array:
    """Membership mask by sorted binary search — O(N log V) time, O(N + V)
    memory.  (The obvious ``col[:, None] == vals[None, :]`` broadcast
    materializes an (N, V) boolean: an O(N*V) blowup that OOMs when
    filtering a big log on a high-cardinality value set.)

    The single implementation behind ``filter_attr_values`` *and* the
    query layer's ``isin`` predicate — one algorithm, one bitwise parity.
    """
    vals = jnp.sort(jnp.asarray(values).ravel())
    if vals.size == 0:
        return jnp.zeros(col.shape, bool)
    slot = jnp.clip(jnp.searchsorted(vals, col), 0, vals.size - 1)
    return vals[slot] == col


def time_range_mask(frame: EventFrame, name: str, lo, hi) -> jax.Array:
    """``lo <= frame[name] <= hi`` on valid cells (shared by
    ``filter_time_range`` and the query layer's ``between`` predicate)."""
    col = frame[name]
    return (col >= lo) & (col <= hi) & frame.cell_valid(name)


def _warn_deprecated(old: str, verb: str) -> None:
    """The eager ``filter_*`` entry points are deprecated shims over the
    same masks the ``repro.dataset`` facade pushes down — behavior is
    unchanged (bitwise), but new code should go through the facade so the
    planner can skip I/O and pick the engine."""
    warnings.warn(
        f"repro.core.filtering.{old} is deprecated; use the Dataset facade: "
        f"repro.open(...).{verb}", DeprecationWarning, stacklevel=3)


def filter_attr_values(frame: EventFrame, name: str, values, keep: bool = True) -> EventFrame:
    """Keep (or drop) events whose ``name`` is in ``values`` (event-level).

    .. deprecated:: use ``repro.open(...).filter(col(name).isin(values))``.
    """
    _warn_deprecated("filter_attr_values",
                     "filter(col(name).isin(values))  # ~ for keep=False")
    m = isin_mask(frame[name], values)
    return ops.proj(frame, m if keep else ~m)


def filter_time_range(frame: EventFrame, name: str, lo, hi) -> EventFrame:
    """Keep events with ``lo <= frame[name] <= hi`` (event-level).

    A cell whose epsilon (validity) flag is off never matches: the stored
    sentinel value of a missing timestamp happening to fall inside
    ``[lo, hi]`` must not resurrect the row, so the range mask is ANDed
    with ``cell_valid`` (column epsilon mask + row projection mask).

    .. deprecated:: use ``repro.open(...).filter(col(name).between(lo, hi))``.
    """
    _warn_deprecated("filter_time_range", "filter(col(name).between(lo, hi))")
    return ops.proj(frame, time_range_mask(frame, name, lo, hi))


@partial(jax.jit, static_argnames=("num_cases",))
def _case_mask_to_event_mask(case_seg: jax.Array, case_keep: jax.Array, num_cases: int) -> jax.Array:
    return case_keep[case_seg]


# --------------------------------------------------- case-level, phase one
def cases_with_value_kernel(column: str, value: int, num_cases: int,
                            backend: str | None = None) -> engine.ChunkKernel:
    """Per-case predicate "case has an event with ``column == value``" as a
    chunk-kernel; state is the (num_cases,) keep mask, merged by logical
    or.  ``cases_containing_kernel`` is the activity-column special case."""
    return _cases_with_value_kernel(str(column), int(value), int(num_cases),
                                    _backend.resolve(backend))


def cases_containing_kernel(activity: int, num_cases: int,
                            backend: str | None = None) -> engine.ChunkKernel:
    """Per-case predicate "case contains ``activity``" as a chunk-kernel."""
    return cases_with_value_kernel(ACTIVITY, activity, num_cases, backend)


@lru_cache(maxsize=None)
def _cases_with_value_kernel(column: str, value: int, num_cases: int,
                             impl: str) -> engine.ChunkKernel:

    def init():
        return (jnp.zeros((num_cases,), bool),
                engine.init_row_carry(seg=jnp.int32(-1)))

    @jax.jit
    def update(state, carry, chunk):
        adj = engine.adjacent(chunk, carry)
        seg = engine.global_segments(adj, carry)
        hit = (chunk[column] == value) & adj.rv
        # or-reduce per case == segment max over the boolean hit column
        state = state | segment_reduce(hit, seg, num_cases, "max", impl=impl)
        return state, engine.next_row_carry(carry, chunk, seg=seg[-1])

    return engine.ChunkKernel(f"cases_with_value[{column}={value},{impl}]",
                              init, update, jnp.logical_or, lambda s, c: s)


def streaming_cases_containing(chunks, activity: int, num_cases: int) -> jax.Array:
    """Phase one over a chunk stream: the per-case keep mask."""
    return engine.run_streaming(cases_containing_kernel(activity, num_cases),
                                chunks)


def streaming_case_size_keep(chunks, min_events: int, max_events: int,
                             num_cases: int) -> jax.Array:
    sizes = engine.run_streaming(case_sizes_kernel(num_cases), chunks)
    return (sizes >= min_events) & (sizes <= max_events)


# --------------------------------------------------- case-level, phase two
def stream_apply_case_mask(chunks, case_keep: jax.Array):
    """Second pass: narrow each chunk's ``row_valid`` by its case's verdict.

    Re-derives global segment ids with the same carry logic as phase one, so
    a case split across chunks is consistently kept or dropped.  Yields
    chunks lazily — peak residency stays one chunk.
    """
    @jax.jit
    def one(carry, chunk):
        adj = engine.adjacent(chunk, carry)
        seg = engine.global_segments(adj, carry)
        keep = case_keep[jnp.clip(seg, 0, case_keep.shape[0] - 1)] & (seg < case_keep.shape[0])
        return engine.next_row_carry(carry, chunk, seg=seg[-1]), keep

    carry = engine.init_row_carry(seg=jnp.int32(-1))
    for chunk in chunks:
        if chunk.nrows == 0:
            yield chunk
            continue
        carry, keep = one(carry, chunk)
        yield ops.proj(chunk, keep)


# ------------------------------------------------- whole-log entry points
def filter_cases_containing(frame: EventFrame, activity: int, num_cases: int) -> EventFrame:
    """Case-level: keep all events of cases that contain ``activity``.

    Requires frame sorted by (case, time); the single-chunk special case of
    ``cases_containing_kernel`` + mask broadcast.

    .. deprecated:: use ``repro.open(...).filter(cases_containing(activity))``.
    """
    _warn_deprecated("filter_cases_containing",
                     "filter(cases_containing(activity))")
    kernel = cases_containing_kernel(activity, num_cases)
    state, carry = kernel.init()
    case_keep, _ = kernel.update(state, carry, frame)
    seg, _ = ops.segment_ids_sorted(frame[CASE])
    return ops.proj(frame, _case_mask_to_event_mask(seg, case_keep, num_cases))


def filter_case_size(frame: EventFrame, min_events: int, max_events: int, num_cases: int) -> EventFrame:
    """Case-level: keep cases whose (valid-)event count is within bounds.

    .. deprecated:: use ``repro.open(...).filter(case_size(lo, hi))``.
    """
    _warn_deprecated("filter_case_size", "filter(case_size(lo, hi))")
    from .stats import case_sizes

    sizes = case_sizes(frame, num_cases)
    case_keep = (sizes >= min_events) & (sizes <= max_events)
    seg, _ = ops.segment_ids_sorted(frame[CASE])
    return ops.proj(frame, case_keep[seg])


def most_common_activity(frame: EventFrame, num_activities: int) -> jax.Array:
    """The paper's Table-5 filter target: the most frequent activity."""
    counts = histogram(frame[ACTIVITY], num_activities,
                       weights=frame.rows_valid())
    return jnp.argmax(counts)


def streaming_most_common_activity(chunks, num_activities: int) -> int:
    from .stats import activity_counts_kernel

    counts = engine.run_streaming(activity_counts_kernel(num_activities), chunks)
    return int(jnp.argmax(counts))
