"""Process discovery on the columnar substrate — alpha + heuristics miners.

The paper positions DFGs as the basis for discovery; PM4Py-GPU (arXiv
2204.04898) shows discovery is the payoff workload for columnar event
structures, and the Apache-Phoenix study (arXiv 1703.05481) maps the alpha
miner onto column-oriented scans.  Both miners here consume nothing but the
dense matrices the chunk-kernel engine already accumulates:

* **alpha miner** — footprint relations (``a -> b`` causality, ``a || b``
  parallelism, ``a # b`` choice) derived as masked matrix ops over the
  ``pair_count``-built DFG plus start/end histograms; places are the maximal
  (A, B) pairs of the classic algorithm (host-side set search over the
  boolean footprint — the only non-vectorized step, O(places), not O(N)).
* **heuristics miner** — dependency measure ``(a->b − b->a)/(a->b + b->a + 1)``
  with L1-loop (``a,a``) and L2-loop (``a,b,a``) handling, all dense (A, A)
  array math; AND/XOR split bindings as one (A, A, A) broadcast.

Both are the *finalize* step of a chunk kernel (``core.engine``): the alpha
miner finalizes the existing ``dfg_kernel`` state verbatim, the heuristics
miner finalizes :func:`discovery_kernel` — the DFG state extended with the
(A, A) L2-loop triple counts, stitched across chunk boundaries by a two-row
carry.  Discovery therefore works out-of-core over ``ChunkedEventFrame``
streams with bitwise whole-log parity (integer counting is order-exact) and,
via the same ``tree_sum`` merge, under the ``psum`` of
``repro.distributed.discovery`` — the third streaming-exact workload after
DFG and variants.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_ops import pair_count

from .eventframe import ACTIVITY, CASE, EventFrame
from .dfg import DFG, dfg_kernel, stitch_dfg_state, _method_impl
from . import engine


# ----------------------------------------------------------- footprint
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Footprint:
    """The alpha relations as dense (A, A) boolean matrices.

    Every cell is classified by ``(direct[a, b], direct[b, a])``:
    ``causal`` = ``(1, 0)``, ``parallel`` = ``(1, 1)``, ``choice`` =
    ``(0, 0)`` — a partition, so two footprints agree on a cell iff their
    ``direct`` matrices agree in both orientations.
    """

    direct: jax.Array    # a > b  (b directly follows a at least min_count times)
    causal: jax.Array    # a -> b
    parallel: jax.Array  # a || b
    choice: jax.Array    # a # b

    def tree_flatten(self):
        return (self.direct, self.causal, self.parallel, self.choice), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_activities(self) -> int:
        return self.direct.shape[-1]


@jax.jit
def _footprint(counts: jax.Array, min_count: jax.Array) -> Footprint:
    d = counts >= min_count
    return Footprint(direct=d, causal=d & ~d.T, parallel=d & d.T,
                     choice=~d & ~d.T)


def footprint(source: DFG | jax.Array, min_count: int = 1) -> Footprint:
    """Alpha relations of a DFG (or a raw (A, A) count matrix); edges with
    fewer than ``min_count`` observations are treated as absent (noise)."""
    counts = source.counts if isinstance(source, DFG) else source
    return _footprint(counts, jnp.int32(min_count))


# ---------------------------------------------------------- alpha miner
@dataclasses.dataclass(frozen=True)
class AlphaModel:
    """Result of the alpha miner: a Petri net in (A, B)-pair form.

    ``places`` are the maximal pairs of activity sets ``(A, B)`` with every
    ``a in A`` causal to every ``b in B`` and both sets internally in
    choice; plus the implicit source place (into ``start_activities``) and
    sink place (out of ``end_activities``).  ``footprint`` keeps the
    relation matrices the model was built from — the footprint-matrix
    conformance object (``core.conformance.footprint_conformance``).
    """

    num_activities: int
    places: tuple[tuple[frozenset[int], frozenset[int]], ...]
    start_activities: frozenset[int]
    end_activities: frozenset[int]
    footprint: Footprint

    @property
    def num_places(self) -> int:
        return len(self.places) + 2  # + source/sink


def _maximal_pairs(causal: np.ndarray, choice: np.ndarray):
    """Classic alpha steps 3–4: the maximal (A, B) pairs.

    Any valid pair decomposes into valid singleton pairs (sub-pairs of a
    valid pair are valid), so the closure of singleton pairs under
    pairwise union reaches every element of X_L; Y_L is its maximal
    antichain.  Host-side over the boolean footprint — the alphabet is
    small and fixed, the log size never enters here.
    """
    a_n = causal.shape[0]
    base = [(frozenset((a,)), frozenset((b,)))
            for a in range(a_n) for b in range(a_n)
            if causal[a, b] and choice[a, a] and choice[b, b]]

    def ok(aa, bb):
        al, bl = sorted(aa), sorted(bb)
        return (causal[np.ix_(al, bl)].all()
                and choice[np.ix_(al, al)].all()
                and choice[np.ix_(bl, bl)].all())

    seen = set(base)
    frontier = list(base)
    while frontier:
        fresh = []
        for a1, b1 in frontier:
            for a2, b2 in base:
                cand = (a1 | a2, b1 | b2)
                if cand not in seen and ok(*cand):
                    seen.add(cand)
                    fresh.append(cand)
        frontier = fresh

    maximal = [p for p in seen
               if not any(q != p and p[0] <= q[0] and p[1] <= q[1]
                          for q in seen)]
    return tuple(sorted(maximal, key=lambda p: (sorted(p[0]), sorted(p[1]))))


def discover_alpha(d: DFG, min_count: int = 1) -> AlphaModel:
    """Alpha miner over an accumulated DFG state (whole-log, streamed, or
    psum-merged — the miner is pure finalize, it never sees events)."""
    fp = footprint(d, min_count)
    causal = np.asarray(fp.causal)
    choice = np.asarray(fp.choice)
    places = _maximal_pairs(causal, choice)
    starts = frozenset(int(i) for i in np.nonzero(np.asarray(d.starts))[0])
    ends = frozenset(int(i) for i in np.nonzero(np.asarray(d.ends))[0])
    return AlphaModel(num_activities=d.num_activities, places=places,
                      start_activities=starts, end_activities=ends,
                      footprint=fp)


# ----------------------------------------------------- heuristics miner
@dataclasses.dataclass(frozen=True)
class HeuristicsNet:
    """Result of the heuristics miner — all dense (A, A)/(A, A, A) arrays.

    ``dependency``'s off-diagonal is ``(a->b − b->a)/(a->b + b->a + 1)``;
    its diagonal is the L1-loop measure ``a->a / (a->a + 1)``.  ``l2`` is
    the symmetric L2-loop measure over ``a,b,a`` triple counts.  ``graph``
    is the thresholded dependency graph (L2 edges added in both directions
    for loop pairs where neither side already has an L1 loop).
    ``and_bindings[a, b1, b2]`` marks successor pairs of ``a`` that split
    as AND (concurrent) rather than XOR.
    """

    dependency: jax.Array     # (A, A) float32
    l2: jax.Array             # (A, A) float32
    graph: jax.Array          # (A, A) bool
    and_bindings: jax.Array   # (A, A, A) bool
    start_activities: frozenset[int]
    end_activities: frozenset[int]

    @property
    def num_activities(self) -> int:
        return self.graph.shape[-1]

    def edges(self):
        """Host-side sparse view of the dependency graph."""
        g = np.asarray(self.graph)
        dep = np.asarray(self.dependency)
        return [((int(a), int(b)), float(dep[a, b]))
                for a, b in zip(*np.nonzero(g))]


@jax.jit
def _heuristics_measures(counts: jax.Array, l2_counts: jax.Array):
    c = counts.astype(jnp.float32)
    dep = (c - c.T) / (c + c.T + 1.0)
    l1 = jnp.diag(c) / (jnp.diag(c) + 1.0)
    a = c.shape[0]
    eye = jnp.eye(a, dtype=bool)
    dep = jnp.where(eye, l1[:, None], dep)
    c2 = l2_counts.astype(jnp.float32)
    l2 = jnp.where(eye, 0.0, (c2 + c2.T) / (c2 + c2.T + 1.0))
    # AND-split measure m[a, b1, b2] = (b1<->b2 mass) / (a's output mass)
    and_m = (c + c.T)[None, :, :] / (c[:, :, None] + c[:, None, :] + 1.0)
    return dep, l2, and_m


@jax.jit
def _heuristics_graph(counts, l2_counts, dep, l2, and_m, dependency_threshold,
                      l2_threshold, min_count, and_threshold):
    a = counts.shape[0]
    eye = jnp.eye(a, dtype=bool)
    keep = (dep >= dependency_threshold) & ~eye & (counts >= min_count)
    loops1 = (jnp.diag(dep) >= dependency_threshold) & \
        (jnp.diag(counts) >= min_count)
    no_l1 = ~loops1[:, None] & ~loops1[None, :]
    sym2 = l2_counts + l2_counts.T
    keep2 = (l2 >= l2_threshold) & (sym2 >= min_count) & no_l1 & ~eye
    graph = keep | (eye & loops1[:, None]) | keep2 | keep2.T
    both = graph[:, :, None] & graph[:, None, :] & \
        ~jnp.eye(a, dtype=bool)[None, :, :]
    and_b = both & (and_m >= and_threshold)
    return graph, and_b


def discover_heuristics(state: "DiscoveryState | DFG",
                        l2_counts: jax.Array | None = None, *,
                        dependency_threshold: float = 0.5,
                        l2_threshold: float = 0.5,
                        and_threshold: float = 0.65,
                        min_count: int = 1) -> HeuristicsNet:
    """Heuristics miner over an accumulated :class:`DiscoveryState` (or a
    bare DFG plus its ``l2_counts``) — pure finalize, dense array math."""
    if isinstance(state, DiscoveryState):
        d, l2c = state.dfg, state.l2_counts
    else:
        d = state
        l2c = (jnp.zeros_like(d.counts) if l2_counts is None
               else jnp.asarray(l2_counts))
    dep, l2, and_m = _heuristics_measures(d.counts, l2c)
    graph, and_b = _heuristics_graph(
        d.counts, l2c, dep, l2, and_m,
        jnp.float32(dependency_threshold), jnp.float32(l2_threshold),
        jnp.int32(min_count), jnp.float32(and_threshold))
    starts = frozenset(int(i) for i in np.nonzero(np.asarray(d.starts))[0])
    ends = frozenset(int(i) for i in np.nonzero(np.asarray(d.ends))[0])
    return HeuristicsNet(dependency=dep, l2=l2, graph=graph,
                         and_bindings=and_b, start_activities=starts,
                         end_activities=ends)


# ------------------------------------------------------------ chunk kernel
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DiscoveryState:
    """Mergeable discovery accumulator: DFG + (A, A) L2-loop triple counts
    (``l2_counts[a, b]`` = #occurrences of the pattern ``a, b, a`` within a
    case).  ``merge`` is leafwise addition — the distributed merge is one
    psum of this pytree."""

    dfg: DFG
    l2_counts: jax.Array

    def tree_flatten(self):
        return (self.dfg, self.l2_counts), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_l2_carry(carry: engine.Carry) -> engine.Carry:
    """Extend a row carry with the two-back halo row (``exists2=False``
    masks every triple that would straddle the stream start)."""
    carry.update(case2=jnp.int32(-1), act2=jnp.int32(0),
                 rv2=jnp.bool_(False), exists2=jnp.bool_(False))
    return carry


def l2_triple_hits(chunk: engine.Chunk, carry: engine.Carry):
    """Per-row ``a, b, a`` detection with a two-row halo.

    Returns ``(prev2_act, prev_act, hit)``: row ``i`` contributes one
    ``l2_counts[act[i-2], act[i-1]]`` when all three rows share a case, are
    valid, and ``act[i] == act[i-2]`` — the carry supplies rows ``-1``/``-2``
    so any chunking yields the whole-log counts.  The one-row halo comes
    from ``engine.adjacent`` (the shared boundary semantics); only the
    two-back arrays are derived here.
    """
    adj = engine.adjacent(chunk, carry)
    case, act, rv = adj.case, adj.act, adj.rv
    n = case.shape[0]
    prev2_case = jnp.concatenate([carry["case2"][None].astype(case.dtype),
                                  carry["case"][None].astype(case.dtype),
                                  case[:-2]])[:n]
    prev2_act = jnp.concatenate([carry["act2"][None].astype(act.dtype),
                                 carry["act"][None].astype(act.dtype),
                                 act[:-2]])[:n]
    prev2_rv = jnp.concatenate([carry["rv2"][None], carry["rv"][None],
                                rv[:-2]])[:n]
    prev2_exists = jnp.concatenate([carry["exists2"][None],
                                    carry["exists"][None],
                                    jnp.ones((max(n - 2, 0),), bool)])[:n]
    hit = (adj.pair & (case == prev2_case)
           & prev2_rv & prev2_exists & (act == prev2_act))
    return prev2_act, adj.prev_act, hit


def next_l2_carry(carry: engine.Carry, old: engine.Carry,
                  chunk: engine.Chunk) -> engine.Carry:
    """Slide the two-back halo: the new two-back row is this chunk's
    second-to-last row (or, for a one-row chunk, the previous one-back)."""
    case = chunk[CASE]
    act = chunk[ACTIVITY]
    rv = chunk.rows_valid()
    if case.shape[0] >= 2:
        carry.update(case2=case[-2].astype(jnp.int32),
                     act2=act[-2].astype(jnp.int32),
                     rv2=rv[-2], exists2=jnp.bool_(True))
    else:
        carry.update(case2=old["case"], act2=old["act"], rv2=old["rv"],
                     exists2=old["exists"])
    return carry


def discovery_kernel(num_activities: int,
                     method: str = "auto") -> engine.ChunkKernel:
    """DFG + L2-loop counts as one mergeable chunk-kernel.

    The state is :class:`DiscoveryState`; the carry is the DFG kernel's
    one-row halo extended with the two-back row, so ``a, b, a`` triples
    split across chunk (or shard) boundaries are counted exactly once.
    ``method`` resolves through ``core.backend`` at factory time, like
    ``dfg_kernel``.
    """
    return _discovery_kernel(num_activities, _method_impl(method))


@lru_cache(maxsize=None)
def _discovery_kernel(num_activities: int, impl: str) -> engine.ChunkKernel:
    a = num_activities
    dk = _dfg_kernel_for(a, impl)

    def init():
        state, carry = dk.init()
        return ({"dfg": state, "l2": jnp.zeros((a, a), jnp.int32)},
                init_l2_carry(carry))

    @jax.jit
    def update(state, carry, chunk):
        p2, p1, hit = l2_triple_hits(chunk, carry)
        l2 = state["l2"] + pair_count(p2, p1, a, weights=hit, impl=impl)
        dfg_state, ncarry = dk.update(state["dfg"], carry, chunk)
        return ({"dfg": dfg_state, "l2": l2},
                next_l2_carry(ncarry, carry, chunk))

    def finalize(state, carry):
        return DiscoveryState(dk.finalize(state["dfg"], carry), state["l2"])

    def stitch(ctx):
        # the DFG half shares the one-row-halo stitch; the L2 half needs
        # the *two*-row halo: triples landing on b's first two rows were
        # invisible to b's fresh fold (its two-back carry had exists=False)
        at = ctx.a.tail
        ac = ctx.a.carry
        rows_b = ctx.b.head["rows"]
        b0 = rows_b[0]
        dfg_s = stitch_dfg_state(ctx.a.state["dfg"], ctx.b.state["dfg"],
                                 at, b0, ctx.straddle)
        l2 = ctx.a.state["l2"] + ctx.b.state["l2"]
        if ctx.straddle and at["rv"] and b0["rv"]:
            # triple (a[-2], a[-1], b0): a's two-back halo is in its carry
            if (bool(ac["exists2"]) and bool(ac["rv2"])
                    and int(ac["case2"]) == b0["case"]
                    and int(ac["act2"]) == b0["act"]):
                l2 = l2.at[int(ac["act2"]), at["act"]].add(1, mode="drop")
            # triple (a[-1], b0, b1): needs b's second leading row
            if ctx.b.rows >= 2:
                b1 = rows_b[1]
                if (b1["case"] == b0["case"] and b1["rv"]
                        and b1["case"] == at["case"]
                        and b1["act"] == at["act"]):
                    l2 = l2.at[at["act"], b0["act"]].add(1, mode="drop")
        overrides = {}
        if ctx.b.rows == 1:
            # the merged two-back row is a's last row, which b's one-row
            # fold could not know
            overrides = {"case2": jnp.int32(at["case"]),
                         "act2": jnp.int32(at["act"]),
                         "rv2": jnp.bool_(at["rv"]),
                         "exists2": jnp.bool_(True)}
        return {"dfg": dfg_s, "l2": l2}, overrides

    return engine.ChunkKernel(f"discovery[{impl}]", init, update,
                              engine.tree_sum, finalize,
                              columns=(ACTIVITY, CASE), stitch=stitch)


def _dfg_kernel_for(num_activities: int, impl: str) -> engine.ChunkKernel:
    # reuse the cached DFG kernel for the already-resolved impl
    method = {"xla": "segment", "matmul": "matmul", "pallas": "kernel"}[impl]
    return dfg_kernel(num_activities, method)


def alpha_kernel(num_activities: int, min_count: int = 1,
                 method: str = "auto") -> engine.ChunkKernel:
    """The alpha miner as the finalize of the *existing* DFG kernel state."""
    dk = dfg_kernel(num_activities, method)
    return engine.ChunkKernel(
        f"alpha[{dk.name}]", dk.init, dk.update, dk.merge,
        lambda s, c: discover_alpha(dk.finalize(s, c), min_count),
        mask_exact=dk.mask_exact, columns=dk.columns, stitch=dk.stitch)


def heuristics_kernel(num_activities: int, method: str = "auto",
                      **thresholds) -> engine.ChunkKernel:
    """The heuristics miner as the finalize of the discovery kernel state."""
    k = discovery_kernel(num_activities, method)
    return engine.ChunkKernel(
        f"heuristics[{k.name}]", k.init, k.update, k.merge,
        lambda s, c: discover_heuristics(k.finalize(s, c), **thresholds),
        mask_exact=k.mask_exact, columns=k.columns, stitch=k.stitch)


# ------------------------------------------------- whole-log entry points
def discovery_state(frame: EventFrame, num_activities: int,
                    method: str = "auto") -> DiscoveryState:
    """DFG + L2 counts of a (case,time)-sorted frame: the single-chunk
    special case of :func:`discovery_kernel`."""
    return engine.run_single(discovery_kernel(num_activities, method), frame)


def alpha(frame: EventFrame, num_activities: int, min_count: int = 1,
          method: str = "auto") -> AlphaModel:
    """Whole-log alpha miner (single-chunk special case)."""
    return engine.run_single(
        alpha_kernel(num_activities, min_count, method), frame)


def heuristics(frame: EventFrame, num_activities: int, method: str = "auto",
               **thresholds) -> HeuristicsNet:
    """Whole-log heuristics miner (single-chunk special case)."""
    return engine.run_single(
        heuristics_kernel(num_activities, method, **thresholds), frame)


# --------------------------------------------------------- streaming API
def streaming_discovery_state(chunks, num_activities: int,
                              method: str = "auto") -> DiscoveryState:
    """Out-of-core DFG + L2 accumulation: one pass, O(chunk) residency."""
    return engine.run_streaming(discovery_kernel(num_activities, method),
                                chunks)


def streaming_alpha(chunks, num_activities: int, min_count: int = 1,
                    method: str = "auto") -> AlphaModel:
    """Out-of-core alpha miner — bitwise-identical to the whole-log pass
    for any chunking (integer counting is order-exact)."""
    return engine.run_streaming(
        alpha_kernel(num_activities, min_count, method), chunks)


def streaming_heuristics(chunks, num_activities: int, method: str = "auto",
                         **thresholds) -> HeuristicsNet:
    """Out-of-core heuristics miner — bitwise-identical to whole-log."""
    return engine.run_streaming(
        heuristics_kernel(num_activities, method, **thresholds), chunks)


engine.register_kernel(engine.KernelSpec(
    "discovery",
    make=lambda dims, method="auto": discovery_kernel(
        dims.num_activities, method),
    columns=(ACTIVITY, CASE),
    sharded_state="discovery",
    from_sharded=lambda state, **_: state,
    doc="DFG + L2-loop triple counts (feeds alpha/heuristics host-side)"))
engine.register_kernel(engine.KernelSpec(
    "alpha",
    make=lambda dims, min_count=1, method="auto": alpha_kernel(
        dims.num_activities, min_count, method),
    columns=(ACTIVITY, CASE),
    sharded_state="dfg",
    from_sharded=lambda state, min_count=1, **_: discover_alpha(
        state, min_count),
    doc="alpha miner (finalize of the DFG state)"))
engine.register_kernel(engine.KernelSpec(
    "heuristics",
    make=lambda dims, method="auto", **thresholds: heuristics_kernel(
        dims.num_activities, method, **thresholds),
    columns=(ACTIVITY, CASE),
    sharded_state="discovery",
    from_sharded=lambda state, method="auto", **thresholds:
        discover_heuristics(state, **thresholds),
    doc="heuristics miner (finalize of the discovery state)"))
