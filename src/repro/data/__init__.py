from . import pipeline, synthetic, tokenizer

__all__ = ["pipeline", "synthetic", "tokenizer"]
