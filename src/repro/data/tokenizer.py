"""Activity tokenizer: dictionary encoding at the host boundary.

Maps activity ids of an EventFrame into a model vocabulary with reserved
specials. This is where the paper's "dictionary-encoded string columns" meet
the LM side of the framework: traces become token sequences for
next-activity prediction.
"""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 0, 1, 2
NUM_SPECIALS = 3


class ActivityTokenizer:
    def __init__(self, activity_table: list[str]):
        self.table = list(activity_table)

    @property
    def vocab_size(self) -> int:
        return len(self.table) + NUM_SPECIALS

    def encode(self, activity_ids: np.ndarray) -> np.ndarray:
        return activity_ids.astype(np.int32) + NUM_SPECIALS

    def decode(self, tokens: np.ndarray) -> list[str]:
        out = []
        for t in np.asarray(tokens).ravel():
            if t >= NUM_SPECIALS:
                out.append(self.table[int(t) - NUM_SPECIALS])
            else:
                out.append(["<pad>", "<bos>", "<eos>"][int(t)])
        return out
