"""EventFrame -> packed next-activity-prediction batches.

The bridge between the paper's data substrate and the training runtime:
cases (traces) become token sequences ``<bos> a1 .. an <eos>`` packed
back-to-back into fixed (batch, seq) buffers (no padding waste), with a loss
mask that excludes pad positions. Packing, like everything else here, is a
columnar operation: one pass over the case-sorted activity column.

Multi-host sharding: each data-parallel host keeps cases with
``case_id % num_hosts == host_id`` — deterministic, stateless, resumable
(the FT story needs the pipeline to re-seek after restart, which a pure
function of (epoch, step) gives us for free).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from .tokenizer import ActivityTokenizer, BOS, EOS, PAD


@dataclasses.dataclass
class Batch:
    tokens: np.ndarray      # (B, S) int32 — model input
    targets: np.ndarray     # (B, S) int32 — next-token labels
    loss_mask: np.ndarray   # (B, S) float32


def frame_to_token_stream(frame: EventFrame, tok: ActivityTokenizer,
                          host_id: int = 0, num_hosts: int = 1) -> np.ndarray:
    """Flatten the case-sorted frame into one token stream with BOS/EOS."""
    case = np.asarray(frame[CASE])
    act = np.asarray(frame[ACTIVITY])
    rv = np.asarray(frame.rows_valid())
    case, act = case[rv], act[rv]
    if num_hosts > 1:
        keep = (case % num_hosts) == host_id
        case, act = case[keep], act[keep]
    if len(case) == 0:
        return np.zeros((0,), np.int32)
    starts = np.concatenate([[True], case[1:] != case[:-1]])
    toks = tok.encode(act)
    # splice BOS before each case and EOS after: build via offsets
    n = len(toks)
    ncases = int(starts.sum())
    out = np.empty(n + 2 * ncases, np.int32)
    case_idx = np.cumsum(starts) - 1            # which case each event is in
    pos = np.arange(n) + 2 * case_idx + 1       # +1 BOS per case started
    out[pos] = toks
    ends = np.concatenate([case[1:] != case[:-1], [True]])
    bos_pos = pos[starts] - 1
    eos_pos = pos[ends] + 1
    out[bos_pos] = BOS
    out[eos_pos] = EOS
    return out


def batches(stream: np.ndarray, batch_size: int, seq_len: int,
            drop_last: bool = True) -> Iterator[Batch]:
    """Pack the stream into (B, S) with next-token targets."""
    per = batch_size * seq_len
    n_full = (len(stream) - 1) // per
    for i in range(n_full):
        chunk = stream[i * per: i * per + per + 1]
        x = chunk[:-1].reshape(batch_size, seq_len)
        y = chunk[1:].reshape(batch_size, seq_len)
        mask = ((x != PAD) & (y != PAD)).astype(np.float32)
        yield Batch(x.copy(), y.copy(), mask)


class Prefetcher:
    """Double-buffered background prefetch (host-side input pipeline)."""

    def __init__(self, it: Iterator[Batch], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        for b in self._it:
            self._q.put(b)
        self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        b = self._q.get()
        if b is None:
            raise StopIteration
        return b
