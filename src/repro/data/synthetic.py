"""Synthetic event-log generator (the paper's Table-6 L1..L5 family).

Cases are sampled from a random first-order process model (a Markov chain
over activities with designated start/end distributions), vectorized across
cases: step t draws the t-th event of *every* still-active case at once, so
generating 10^7 events takes seconds, not minutes. Output is an EventFrame
sorted by (case, time) plus the activity dictionary.
"""
from __future__ import annotations

import numpy as np

from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP, EventFrame


def random_process_model(num_activities: int, seed: int = 0, sparsity: float = 0.3):
    """(start_probs, trans_probs, end_probs) of a random process model."""
    rng = np.random.default_rng(seed)
    a = num_activities
    start = rng.dirichlet(np.ones(min(a, 3)))
    start = np.concatenate([start, np.zeros(a - len(start))])
    mask = rng.random((a, a)) < sparsity
    mask |= np.eye(a, k=1, dtype=bool)          # ensure a path forward
    trans = rng.random((a, a)) * mask
    trans /= np.maximum(trans.sum(1, keepdims=True), 1e-9)
    end = rng.beta(1, 6, size=a)                # per-activity stop probability
    return start, trans, end


def generate(num_cases: int, num_activities: int = 26, seed: int = 0,
             max_len: int = 64, extra_numeric_attrs: int = 2,
             mean_len_target: float = 7.0) -> tuple[EventFrame, dict[str, list]]:
    """Markov-chain log. Mean case length ~= mean_len_target (via end probs)."""
    rng = np.random.default_rng(seed)
    start, trans, end = random_process_model(num_activities, seed)
    # calibrate stop probability to hit the target mean length
    end = np.full(num_activities, 1.0 / mean_len_target)

    cur = rng.choice(num_activities, size=num_cases, p=start)
    active = np.ones(num_cases, bool)
    acts_steps = [cur.copy()]
    active_steps = [active.copy()]
    cum_trans = trans.cumsum(axis=1)
    for t in range(1, max_len):
        stop = rng.random(num_cases) < end[cur]
        active = active & ~stop
        if not active.any():
            break
        u = rng.random(num_cases)
        nxt = (u[:, None] > cum_trans[cur]).sum(axis=1).clip(0, num_activities - 1)
        cur = np.where(active, nxt, cur)
        acts_steps.append(cur.copy())
        active_steps.append(active.copy())

    acts = np.stack(acts_steps, axis=1)          # (cases, T)
    alive = np.stack(active_steps, axis=1)
    lengths = alive.sum(axis=1).astype(np.int64)

    case_ids = np.repeat(np.arange(num_cases, dtype=np.int64), lengths)
    flat_mask = alive.reshape(-1)
    flat_acts = acts.reshape(-1)[flat_mask].astype(np.int32)
    # timestamps: case start + unit gaps (position within case)
    pos = _positions(lengths)
    t0 = rng.random(num_cases) * 1e6
    ts = (t0[case_ids] + pos).astype(np.float32)

    cols = {CASE: case_ids, ACTIVITY: flat_acts, TIMESTAMP: ts}
    for k in range(extra_numeric_attrs):
        cols[f"attr{k}"] = rng.integers(0, 1000, size=len(case_ids)).astype(np.int32)
    tables = {ACTIVITY: [f"act_{i:03d}" for i in range(num_activities)]}
    return EventFrame.from_numpy(cols), tables


def _positions(lengths: np.ndarray) -> np.ndarray:
    """Vectorized concatenate([arange(l) for l in lengths])."""
    total = int(lengths.sum())
    starts = np.repeat(np.cumsum(lengths) - lengths, lengths)
    return np.arange(total, dtype=np.int64) - starts


def paper_table6_config(level: int) -> dict:
    """L1..L5 scaling points of Table 6 (cases; events follow ~7x)."""
    return {"num_cases": level * 1_000_000, "num_activities": 26,
            "mean_len_target": 7.0, "seed": level}
