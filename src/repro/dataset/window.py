"""Sliding windows over a dataset: re-merge cached group states per window.

``Dataset.window(by=..., size=..., step=...)`` turns the one-shot facade
into the paper's "online scenario" without a second mining machinery:

* ``by="groups"`` — the window unit is one nonempty row group (the
  storage layout's natural chunk).  A window is a contiguous span of
  units; mining it is ``finalize(merge_tree(states[lo:hi]))`` over the
  *same* per-group :class:`~repro.core.engine.GroupState` values the
  streaming engine folds and caches (``query.statecache``) — so sliding
  by ``step`` re-decodes **nothing**: the ring of states is already
  resident and each slide only re-merges, at a cost proportional to the
  window's unit count (and after the first window the fold cost is
  proportional to the *delta* units entering the ring, since every other
  unit state is a cache hit).
* ``by="time"`` — windows are ``[t, t + size]`` intervals stepped by
  ``step`` across the dataset's timestamp extent (header zone maps; both
  edges inclusive, so with ``step == size`` a boundary row belongs to
  both adjacent windows).  Each window is an ordinary
  ``filter(col(timestamp).between(...)).collect(...)``: zone maps refute
  the groups outside the interval, and the groups *inside* it fold with
  an empty residual fingerprint — the same cache entries the unfiltered
  collect uses, so successive overlapping windows share state.

Every window's result is **bitwise equal** to mining the same rows from
scratch — the merge reconstructs the fresh fold exactly (``core.engine``
invariant), and verbs without a mergeable state (``sojourn_times`` /
``performance_dfg`` / ``stats``) transparently re-mine each window
sequentially instead.

On top of the windowed collects:

* :meth:`Windows.drift` scores each window's DFG footprint against the
  previous window's (or a fixed reference) — concept-drift detection as
  one merge + one footprint comparison per slide;
* :meth:`Windows.conformance` replays every window against a discovered
  model (same dispatch as ``Dataset.conformance``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping

import numpy as np

from repro.core import engine as _engine
from repro.core.eventframe import TIMESTAMP, EventFrame

from . import engines


@dataclasses.dataclass(frozen=True)
class WindowResult:
    """Per-window results of one windowed collect.

    ``results[i]`` is the verb's result over window ``bounds[i]`` —
    bitwise equal to collecting the same rows from scratch.  ``report``
    aggregates the scan accounting of the underlying group-state
    resolution (None for in-memory datasets); its ``groups_cached`` /
    ``groups_folded`` counters show how much the window ring reused.
    """

    results: tuple
    bounds: tuple               # (lo, hi) unit spans or (t_lo, t_hi) times
    by: str
    verb: Any
    report: Any | None = None

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, i):
        return self.results[i]


def _check_row_level(steps) -> None:
    from repro.query.expr import CasePredicate

    if any(isinstance(s, CasePredicate) for s in steps):
        raise ValueError("window() supports row-level filters only — "
                         "case-level predicates are global (their keep "
                         "masks span windows); apply them per window "
                         "instead")


def _time_extent(dataset) -> tuple[float, float]:
    """The dataset's [min, max] timestamp from header zone maps (files) or
    the frame column (in-memory)."""
    if not dataset.is_files:
        ts = np.asarray(dataset.frame[TIMESTAMP])
        if not ts.size:
            raise ValueError("window(by='time') over an empty dataset")
        return float(ts.min()), float(ts.max())
    lo = hi = None
    for r in dataset._readers:
        for g in range(r.num_groups):
            if r.group_nrows(g) == 0:
                continue
            z = r.group_meta(g)["zones"].get(TIMESTAMP)
            if z is None or "min" not in z:
                raise ValueError(
                    f"window(by='time') needs {TIMESTAMP!r} zone maps in "
                    f"every file (rewrite as EDFV0003)")
            lo = float(z["min"]) if lo is None else min(lo, float(z["min"]))
            hi = float(z["max"]) if hi is None else max(hi, float(z["max"]))
    if lo is None:
        raise ValueError("window(by='time') over an empty dataset")
    return lo, hi


@dataclasses.dataclass(frozen=True)
class Windows:
    """A sliding-window view built by :meth:`Dataset.window` (see module
    docstring).  Immutable; every method re-derives from the dataset."""

    dataset: Any
    by: str
    size: float
    step: float

    def __post_init__(self):
        if self.by not in ("groups", "time"):
            raise ValueError(f"window by={self.by!r}; one of 'groups', "
                             f"'time'")
        if self.size <= 0 or self.step <= 0:
            raise ValueError("window size and step must be positive")
        if self.by == "groups":
            if self.size != int(self.size) or self.step != int(self.step):
                raise ValueError("window(by='groups') takes integer "
                                 "size/step (units are row groups)")
            if not self.dataset.is_files:
                raise ValueError("window(by='groups') needs a file-backed "
                                 "dataset (the unit is one row group)")
        _check_row_level(self.dataset.steps)

    # ------------------------------------------------------------ geometry
    def _num_units(self) -> int:
        return sum(1 for r in self.dataset._readers
                   for g in range(r.num_groups) if r.group_nrows(g) > 0)

    def bounds(self) -> tuple:
        """The window extents: ``(lo, hi)`` unit spans (``by="groups"``,
        half-open) or ``(t_lo, t_hi)`` time intervals (inclusive)."""
        if self.by == "groups":
            n = self._num_units()
            size, step = int(self.size), int(self.step)
            return tuple((off, min(off + size, n))
                         for off in range(0, max(n, 1), step)
                         if off < n or off == 0)
        lo, hi = _time_extent(self.dataset)
        out = []
        start = lo
        while True:
            out.append((start, start + self.size))
            if start + self.size >= hi:
                break
            start += self.step
        return tuple(out)

    # ------------------------------------------------------------ collects
    def collect(self, verb: str, **kwargs) -> WindowResult:
        """Run a registered verb over every window."""
        if self.by == "time":
            return self._collect_time(verb, kwargs)
        return self._collect_groups(verb, kwargs)

    def collect_many(self, verbs: Iterable[str], *,
                     verb_kwargs: Mapping[str, dict] | None = None,
                     **common) -> WindowResult:
        """Fused windowed collection: each window yields the per-verb
        result dict of one :func:`~repro.core.engine.compose_specs` pass
        (merge-tree over fused group states when every member stitches)."""
        verbs = tuple(verbs)
        vk = dict(verb_kwargs or {})
        if self.by == "time":
            common.setdefault("engine", "streaming")
            results, reports, bounds = [], [], self.bounds()
            for t_lo, t_hi in bounds:
                res = self._window_ds(t_lo, t_hi).collect_many(
                    verbs, verb_kwargs=vk, **common)
                results.append(dict(res.results))
                reports.append(res.report)
            return WindowResult(tuple(results), bounds, self.by, verbs,
                                _merge_optional(reports))
        specs = {v: engines.spec_for(v) for v in verbs}
        fused = _engine.compose_specs(specs)
        dims = _engine.Dims(self.dataset.num_activities,
                            self.dataset.num_cases)
        kernel = fused.make(dims, verb_kwargs=vk, **common)
        fp = engines._spec_fp("+".join(verbs), dims,
                              {"verb_kwargs": sorted(vk.items()), **common})
        results, bounds, report = self._grouped_results(
            kernel, fp, post=dict)
        return WindowResult(tuple(results), bounds, self.by, verbs, report)

    def _window_ds(self, t_lo: float, t_hi: float):
        from repro.query.expr import col

        return self.dataset.filter(col(TIMESTAMP).between(t_lo, t_hi))

    def _collect_time(self, verb: str, kwargs) -> WindowResult:
        # default to streaming: the grouped path lets overlapping windows
        # share cached interior-group states (auto might pick eager)
        kwargs.setdefault("engine", "streaming")
        results, reports, bounds = [], [], self.bounds()
        for t_lo, t_hi in bounds:
            res = engines.collect(self._window_ds(t_lo, t_hi), verb,
                                  **kwargs)
            results.append(res.result)
            reports.append(res.report)
        return WindowResult(tuple(results), bounds, self.by, verb,
                            _merge_optional(reports))

    def _collect_groups(self, verb: str, kwargs) -> WindowResult:
        spec = engines.spec_for(verb)
        dims = _engine.Dims(self.dataset.num_activities,
                            self.dataset.num_cases)
        kernel = spec.make(dims, **kwargs)
        fp = engines._spec_fp(verb, dims, kwargs)
        results, bounds, report = self._grouped_results(kernel, fp)
        return WindowResult(tuple(results), bounds, self.by, verb, report)

    def _grouped_results(self, kernel, spec_fp, post=None):
        """Fold once, merge per window — or re-mine each window from
        scratch when the kernel has no mergeable state."""
        from repro.query.exec import group_states

        bounds = self.bounds()
        if _engine.mergeable(kernel):
            states, report = group_states(
                self.dataset.plan(columns=kernel.columns), kernel, spec_fp)
            results = []
            for lo, hi in bounds:
                merged = _engine.merge_tree(kernel, states[lo:hi])
                out = _engine.finalize_group(kernel, merged)
                results.append(post(out) if post else out)
            return results, bounds, report
        # no stitch: each window folds its rows sequentially from scratch
        units, physicals = self._units(kernel.columns)
        results = []
        for lo, hi in bounds:
            state, carry = kernel.init()
            for chunk in _unit_chunks(units[lo:hi]):
                if chunk.nrows:
                    state, carry = kernel.update(state, carry, chunk)
            out = kernel.finalize(state, carry)
            results.append(post(out) if post else out)
        return results, bounds, None

    def _units(self, columns):
        """The global unit list [(physical, group)] in stream order."""
        from repro.query.optimize import compile_plan

        plan = self.dataset.plan(columns=columns)
        physicals = [compile_plan(p, True) for p in plan.per_file()]
        units = [(ph, g) for ph in physicals for g in ph._nonempty()]
        return units, physicals

    # ------------------------------------------------------------ analyses
    def drift(self, reference=None, *, min_count: int = 1,
              **kwargs) -> list[float]:
        """Per-window footprint-drift scores in [0, 1].

        Each window's DFG footprint (alpha relation classes) is compared
        to the *previous* window's — 1.0 means the behavioural relations
        are unchanged, lower means drift — or to a fixed ``reference``
        (a DFG, a :class:`~repro.core.discovery.Footprint`, or any model
        with one) when given.  The first window scores 1.0 against
        ``reference=None`` (nothing to drift from).
        """
        from repro.core.conformance import footprint_conformance
        from repro.core.dfg import DFG
        from repro.core.discovery import footprint

        dfgs = self.collect("dfg", **kwargs).results
        ref = footprint(reference, min_count) \
            if isinstance(reference, DFG) else reference
        scores: list[float] = []
        prev = None
        for d in dfgs:
            model = ref if ref is not None else prev
            scores.append(1.0 if model is None
                          else float(footprint_conformance(d, model)))
            if ref is None:
                prev = footprint(d, min_count)
        return scores

    def conformance(self, model, **kwargs) -> list[float]:
        """Replay every window's DFG against a discovered model (same
        dispatch as :meth:`Dataset.conformance`): per-window fitness."""
        import jax.numpy as jnp

        from repro.core import conformance as _conformance
        from repro.core.discovery import AlphaModel, HeuristicsNet

        dfgs = self.collect("dfg", **kwargs).results
        if isinstance(model, HeuristicsNet):
            return [float(_conformance.heuristics_fitness(d, model))
                    for d in dfgs]
        if isinstance(model, AlphaModel):
            return [float(_conformance.alpha_fitness(d, model))
                    for d in dfgs]
        allowed = jnp.asarray(model)
        return [float(_conformance.footprint_fitness(d, allowed))
                for d in dfgs]


def _merge_optional(reports):
    from repro.query.exec import merge_reports

    reports = [r for r in reports if r is not None]
    return merge_reports(reports) if reports else None


def _unit_chunks(units):
    """Masked chunks of a unit span — the scratch path's stream (reads
    every unit; residual masks refute rows exactly like the pruned scan)."""
    import jax.numpy as jnp

    from repro.query.expr import ALL, Expr

    for ph, g in units:
        frame = ph.reader.read_group(g, ph.read_columns)
        exprs = [i for i, s in enumerate(ph.steps) if isinstance(s, Expr)]
        residual = [i for i in exprs if ph.proves[i][g] != ALL] \
            if ph.prune else exprs
        mask = np.ones(frame.nrows, bool)
        for i in residual:
            mask &= np.asarray(ph.steps[i].mask(frame), bool)
        sel = frame.select(ph.chunk_columns)
        yield EventFrame(sel.columns, sel.valid, jnp.asarray(mask))
