"""Unified ``Dataset`` facade: one fluent API over eager, lazy, multi-log,
and distributed mining (see ``repro.dataset.dataset`` for the full story).

    import repro
    ds = repro.open(["jan.edf", "feb.edf"])
    ds.filter(repro.col("concept:name") == 3).dfg()
"""
from .dataset import Dataset, open_dataset  # noqa: F401
from .engines import (ENGINES, CollectResult, CostEstimate,  # noqa: F401
                      choose, clear_result_cache, estimate)
from .window import Windows, WindowResult  # noqa: F401

open = open_dataset  # the facade's entry point: ``repro.open(...)``

__all__ = [
    "CollectResult", "CostEstimate", "Dataset", "ENGINES", "WindowResult",
    "Windows", "choose", "clear_result_cache", "estimate", "open",
    "open_dataset",
]
