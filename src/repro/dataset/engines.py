"""Engine dispatch for the ``Dataset`` facade — the first cost-based plan.

Three *schedules over one merge algebra* (``core.engine``'s group
states): a verb whose kernel defines a ``stitch`` folds work units
independently and ``merge_tree``-s the unit states, so the engines below
differ only in how they cut the stream into units —

* **eager** — one unit: ``edf.read`` every file whole, apply the filter
  chain in memory (the same masks the planner pushes down), fold once.
  No per-group overhead: the fastest path when the surviving data is
  small and pruning would not skip much.
* **streaming** — one unit per row group: ``repro.query`` pruned scans
  refute groups from zone maps before any I/O, and ``execute_grouped``
  folds each surviving group into a cacheable
  :class:`~repro.core.engine.GroupState` (``query.statecache``) — a
  re-collect after appending a file only decodes the *fresh* groups and
  re-merges the rest from the cache.  Kernels without a stitch (the
  order-sensitive float accumulators: ``sojourn_times`` /
  ``performance_dfg`` / ``stats``) and plans with case-level predicates
  keep the sequential carry-threaded scan — same results, no caching.
* **sharded** — one unit per shard: verbs with a hand-written
  distributed lowering (``KernelSpec.sharded_state``) keep the
  ppermute-halo + psum drivers; every *other* mergeable verb shards as a
  literal merge-tree instance (``distributed.query.merge_tree_sharded``
  — contiguous spans of the pruned stream folded independently, states
  merged, finalized once).

Whole :class:`CollectResult`/:class:`CollectManyResult` values are also
memoized per process, keyed by the plan fingerprint and each file's
``(st_mtime_ns, st_size)`` signature: re-collecting an untouched dataset
performs **zero** reads; touching any file invalidates only its entry
(``REPRO_RESULT_CACHE=0`` disables).

``engine="auto"`` picks between them from *header metadata only*: total
on-disk bytes per ``edf.file_sizes``-style group accounting, the
fraction of groups/bytes the zone maps already refute, and — for
case-level predicates — the per-group dictionary presence bitsets of
EDFV0003 zones (a group whose bitset lacks the wanted activity
contributes no phase-one hits, so its bytes are *estimated* skipped).
The eager/streaming decision is a **calibrated cost model** rather than
a static byte threshold: per-byte and per-group costs are fitted by
least squares to the ``benchmarks/bench_dataset.py`` dispatch-regret
sweep (``fit_calibration``); the built-in coefficients come from the
committed ``BENCH_dataset.json`` and can be refitted to the local
machine via ``REPRO_DATASET_CALIBRATION=/path/to/BENCH_dataset.json``.
The sharded decision keeps one environment-tunable threshold:

* ``REPRO_DATASET_SHARD_ROWS`` (default 2M) — above this many surviving
  rows, shard when more than one device is attached.

Every lowering returns bitwise-identical results, so a wrong guess costs
time, never correctness.

**Fused collection** (:func:`collect_many`) resolves several verbs into
one :func:`~repro.core.engine.compose_specs` fused spec and drives the
chosen engine ONCE: one pruned scan (columns = the union of the member
requirements, ``mask_exact`` = their conjunction), one eager load, or
one sharded pass over the distinct distributed states — each verb's
result bitwise equal to its separate ``collect`` call.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from collections import OrderedDict
from typing import Any, Iterable, Mapping

import numpy as np

from repro.core import engine as _engine
from repro.core import backend as _backend
from repro.core.eventframe import CASE, EventFrame

SHARD_ROWS = int(os.environ.get("REPRO_DATASET_SHARD_ROWS", 2_000_000))

ENGINES = ("auto", "eager", "streaming", "sharded")


def spec_for(verb: str) -> _engine.KernelSpec:
    return _engine.kernel_spec(verb)


def _spec_fp(verb: str, dims: _engine.Dims, kwargs: Mapping) -> tuple:
    from repro.query.statecache import spec_fingerprint

    return spec_fingerprint(verb, dims, dict(kwargs))


# ------------------------------------------------------- result memoization
RESULT_CACHE_ENV = "REPRO_RESULT_CACHE"
_RESULT_CAP = 128
_RESULTS: OrderedDict = OrderedDict()
_RESULTS_LOCK = threading.Lock()


def file_signatures(paths) -> tuple:
    """Per-file ``(path, st_mtime_ns, st_size, header_tag, num_groups)`` —
    the invalidation unit of both the result memo and the reader pool.

    The stat pair is the cheap fast-moving part; the header content tag
    (``storage.edf.file_sig``) plus the row-group count close the
    pathological hole: a same-size rewrite landing within one mtime tick
    can no longer alias the signature of the file it replaced, so a
    memoized result can never be served for bytes that were never read.
    """
    from repro.storage.edf import pooled_reader

    sigs = []
    for p in paths:
        r = pooled_reader(p)
        sigs.append((p, *r._sig, r.num_groups))
    return tuple(sigs)


def _memo_key(dataset, extra) -> tuple | None:
    """Content key of one collect over a file-backed dataset, or ``None``
    when memoization does not apply (in-memory frame, disabled, or a file
    is unreadable).  ``extra`` carries the verb + engine + kwargs."""
    if not dataset.is_files or os.environ.get(RESULT_CACHE_ENV, "1") == "0":
        return None
    try:
        sigs = file_signatures(dataset.paths)
    except OSError:
        return None
    return (sigs, repr(dataset.steps), dataset.projection,
            dataset.hint_activities, dataset.hint_cases,
            _backend.resolve(None), extra)


def _memo_get(key):
    if key is None:
        return None
    with _RESULTS_LOCK:
        hit = _RESULTS.get(key)
        if hit is not None:
            _RESULTS.move_to_end(key)
        return hit


def _memo_put(key, value):
    if key is None:
        return
    with _RESULTS_LOCK:
        _RESULTS[key] = value
        _RESULTS.move_to_end(key)
        while len(_RESULTS) > _RESULT_CAP:
            _RESULTS.popitem(last=False)


def clear_result_cache() -> None:
    """Drop every memoized collect result (tests; the per-group state
    cache is separate — ``repro.query.statecache.state_cache().clear()``)."""
    with _RESULTS_LOCK:
        _RESULTS.clear()


# ------------------------------------------------------------ cost model
@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Plan-time I/O estimate from zone maps (no data bytes touched for
    EDFV0003 files; v1/v2 files pay their one-off metadata synthesis)."""

    bytes_total: int
    bytes_est: int          # bytes the pruned scan would read
    rows_total: int
    rows_est: int
    groups_total: int
    groups_est: int

    @property
    def selectivity(self) -> float:
        """Estimated surviving-bytes fraction (1.0 = nothing refuted)."""
        return self.bytes_est / self.bytes_total if self.bytes_total else 1.0


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted dispatch costs, in microseconds (see module docstring).

    ``eager ~= eager_a + eager_b * bytes_total`` (the whole projected
    extent — eager decodes everything), ``streaming ~= stream_a +
    stream_b * bytes_est + stream_g * groups_est`` (only surviving
    bytes/groups; the intercept is the planner's fixed cost).
    """

    eager_a: float
    eager_b: float      # us per byte of the full projected extent
    stream_a: float
    stream_b: float     # us per surviving byte the pruned scan reads
    stream_g: float     # us per surviving row group (per-group overhead)
    source: str = "builtin"

    def eager_us(self, est: CostEstimate) -> float:
        return self.eager_a + self.eager_b * est.bytes_total

    def streaming_us(self, est: CostEstimate) -> float:
        return (self.stream_a + self.stream_b * est.bytes_est
                + self.stream_g * est.groups_est)


# least squares over the committed BENCH_dataset.json sweep (cpu backend);
# refit to the local machine via REPRO_DATASET_CALIBRATION
DEFAULT_CALIBRATION = Calibration(
    eager_a=0.0, eager_b=0.792,
    stream_a=10367.2, stream_b=0.7532, stream_g=0.0)


def fit_calibration(bench: Mapping) -> Calibration:
    """Least-squares fit of the dispatch cost model to a
    ``benchmarks/bench_dataset.py`` result dict (its ``sweep`` points
    carry measured ``us_eager`` / ``us_streaming`` against the bytes and
    groups each engine touched).

    The sweep varies selectivity over one dataset, so ``bytes_total`` is
    constant and the eager fit is rank-deficient; the min-norm solution
    puts the cost on the slope — eager cost extrapolates with file size,
    which is the behaviour dispatch needs.  The streaming fit tries
    ``a + b*bytes + g*groups`` and falls back to bytes-only when
    collinearity drives any coefficient negative (a negative per-byte
    cost would invert decisions off-sweep)."""
    pts = [p for p in bench.get("sweep", ())
           if "us_eager" in p and "us_streaming" in p]
    if not pts:
        raise ValueError("no usable sweep points to fit a calibration from")
    bt = np.array([p["bytes_total"] for p in pts], float)
    br = np.array([p["bytes_read"] for p in pts], float)
    gr = np.array([p.get("groups_total", 0) - p.get("groups_skipped", 0)
                   for p in pts], float)
    ue = np.array([p["us_eager"] for p in pts], float)
    us = np.array([p["us_streaming"] for p in pts], float)
    one = np.ones_like(br)
    ea, eb = np.linalg.lstsq(np.stack([one, bt], 1), ue, rcond=None)[0]
    coef = np.linalg.lstsq(np.stack([one, br, gr], 1), us, rcond=None)[0]
    if len(pts) < 3 or (coef < 0).any():
        sa, sb = np.linalg.lstsq(np.stack([one, br], 1), us, rcond=None)[0]
        coef = np.array([sa, sb, 0.0])
    return Calibration(max(float(ea), 0.0), max(float(eb), 0.0),
                       max(float(coef[0]), 0.0), max(float(coef[1]), 0.0),
                       max(float(coef[2]), 0.0), source="fit")


_CALIBRATION: Calibration | None = None


def calibration() -> Calibration:
    """The active calibration: fitted from the JSON file named by
    ``REPRO_DATASET_CALIBRATION`` if set, else the built-in coefficients
    (cached after first resolution)."""
    global _CALIBRATION
    if _CALIBRATION is None:
        path = os.environ.get("REPRO_DATASET_CALIBRATION", "")
        if path:
            import json

            with open(path) as f:
                fitted = fit_calibration(json.load(f))
            _CALIBRATION = dataclasses.replace(fitted, source=path)
        else:
            _CALIBRATION = DEFAULT_CALIBRATION
    return _CALIBRATION


def estimate(dataset) -> CostEstimate:
    """Zone-map selectivity estimate for the dataset's current plan.

    Row-level predicates skip groups their zone proofs refute; case-level
    predicates skip groups whose dictionary presence bitsets show the
    wanted value cannot occur (``phase1_prove == NONE``) — an *estimate*:
    a kept case straddling such a group still forces the real scan to
    read it, so the scan may read slightly more than estimated, never
    less correctly."""
    from repro.query.expr import NONE, CasePredicate
    from repro.query.optimize import compile_plan

    bt = be = rt = re_ = gt = ge = 0
    for plan in dataset.plan().per_file():
        ph = compile_plan(plan, True)
        exprs = list(ph.proves)
        preds = [s for s in ph.steps if isinstance(s, CasePredicate)]
        for g in range(ph.reader.num_groups):
            n = ph.reader.group_nrows(g)
            if n == 0:
                continue
            nbytes = ph.reader.group_nbytes(g, ph.read_columns)
            gt += 1
            rt += n
            bt += nbytes
            if any(ph.proves[i][g] == NONE for i in exprs):
                continue            # provably refuted: the scan skips it
            if preds and ph.metas is not None and any(
                    p.phase1_prove(ph.metas[g]) == NONE for p in preds):
                continue            # presence bitsets: no case hit here
            ge += 1
            re_ += n
            be += nbytes
    return CostEstimate(bt, be, rt, re_, gt, ge)


def choose(dataset, spec: _engine.KernelSpec,
           est: CostEstimate | None, n_devices: int | None = None) -> str:
    """The cost-based engine decision (see module docstring)."""
    if not dataset.is_files:
        return "eager"
    if est is None:
        est = estimate(dataset)
    if n_devices is None:
        import jax

        n_devices = len(jax.devices())
    if (spec.sharded_state is not None and n_devices > 1
            and est.rows_est >= SHARD_ROWS):
        return "sharded"
    cal = calibration()
    if cal.streaming_us(est) <= cal.eager_us(est):
        return "streaming"
    return "eager"


# --------------------------------------------------------------- engines
def eager_frame(dataset) -> EventFrame:
    """Load everything, apply the filter chain in memory.

    Uses the *same* predicate masks and phase-one kernels the planner
    pushes down, so eager == streaming bitwise by construction.
    """
    import jax.numpy as jnp

    from repro.core import ops
    from repro.query.expr import CasePredicate, bind_schema
    from repro.storage import edf

    if dataset.is_files:
        from repro.core.eventframe import concat_frames
        from repro.query.exec import check_homogeneous

        check_homogeneous(dataset._readers)     # fail like streaming would
        frame = concat_frames([edf.read(p)[0] for p in dataset.paths])
    else:
        frame = dataset.frame
    tables = dataset.tables
    for step in dataset.steps:
        if isinstance(step, CasePredicate):
            resolved = step.resolve(tables)
            kernel = resolved.phase1_kernel(dataset.num_cases)
            keep = resolved.finalize_keep(_engine.run_single(kernel, frame))
            seg, _ = ops.segment_ids_sorted(frame[CASE])
            frame = ops.proj(frame, jnp.asarray(np.asarray(keep))[seg])
        else:
            bound = bind_schema(step, dataset.schema)
            frame = ops.proj(frame, bound.mask(frame))
    if dataset.projection is not None:
        frame = frame.select(dataset.projection)
    return frame


def _mesh(num_shards):
    import jax

    devs = jax.devices()
    num_shards = len(devs) if num_shards is None else int(num_shards)
    return jax.sharding.Mesh(np.array(devs[:num_shards]), ("data",))


def _num_shards(num_shards) -> int:
    if num_shards is not None:
        return max(int(num_shards), 1)
    import jax

    return len(jax.devices())


def _sharded(dataset, spec: _engine.KernelSpec, dims, num_shards, **kwargs):
    from repro.distributed.query import query_sharded_multi

    if not dataset.is_files:
        raise ValueError("engine='sharded' needs a file-backed dataset")
    if spec.sharded_state is None:
        # no bespoke distributed state — but a mergeable kernel shards as
        # a merge-tree instance over contiguous spans of the pruned stream
        from repro.distributed.query import merge_tree_sharded

        kernel = spec.make(dims, **kwargs)
        if not _engine.mergeable(kernel):
            raise ValueError(
                f"verb {spec.name!r} has no exact distributed lowering "
                f"(order-sensitive state, no stitch); use "
                f"engine='streaming' or 'eager'")
        return merge_tree_sharded(dataset.plan(columns=spec.columns),
                                  kernel, _num_shards(num_shards))
    # same projection/column validation as the other engines (the driver
    # re-projects the scan to its own (activity, case) columns anyway)
    plan = dataset.plan(columns=spec.columns)
    out, report = query_sharded_multi(plan, (spec.sharded_state,),
                                      dims.num_activities, _mesh(num_shards),
                                      method=kwargs.get("method", "auto"),
                                      num_cases=dims.num_cases)
    return spec.from_sharded(out[spec.sharded_state], **kwargs), report


def _sharded_many(dataset, specs: Mapping[str, _engine.KernelSpec],
                  fused: _engine.KernelSpec, dims, num_shards,
                  verb_kwargs: Mapping[str, dict], common: dict):
    from repro.distributed.query import query_sharded_multi

    if not dataset.is_files:
        raise ValueError("engine='sharded' needs a file-backed dataset")
    if fused.sharded_state is None:
        # same merge-tree fallback as single-verb collects: a fused kernel
        # stitches iff every member does
        from repro.distributed.query import merge_tree_sharded

        kernel = fused.make(dims, verb_kwargs=dict(verb_kwargs), **common)
        if not _engine.mergeable(kernel):
            bad = sorted(v for v, s in specs.items()
                         if s.sharded_state is None and
                         not _engine.mergeable(s.make(dims, **{
                             **common, **dict(verb_kwargs.get(v, {}))})))
            raise ValueError(
                f"fused collection has no exact distributed lowering: verbs "
                f"{bad} (order-sensitive state, no stitch); drop them or "
                f"use engine='streaming' or 'eager'")
        results, report = merge_tree_sharded(
            dataset.plan(columns=fused.columns), kernel,
            _num_shards(num_shards))
        return dict(results), report
    # verbs sharing a distributed state (dfg + alpha, discovery +
    # heuristics) dedupe: each distinct state is mined once from the one
    # gathered stream, then every verb finalizes host-side from its state
    states = tuple(dict.fromkeys(s.sharded_state for s in specs.values()))
    plan = dataset.plan(columns=fused.columns)
    out, report = query_sharded_multi(plan, states, dims.num_activities,
                                      _mesh(num_shards),
                                      method=common.get("method", "auto"),
                                      num_cases=dims.num_cases)
    results = {v: s.from_sharded(out[s.sharded_state],
                                 **{**common, **dict(verb_kwargs.get(v, {}))})
               for v, s in specs.items()}
    return results, report


# ------------------------------------------------------------- front door
@dataclasses.dataclass(frozen=True)
class CollectResult:
    """A verb's result plus how it ran (I/O report is None for eager)."""

    result: Any
    report: Any | None
    engine: str
    verb: str
    estimate: CostEstimate | None = None


def _fold_eager(kernel, frame):
    """Eager = the one-unit schedule of the merge algebra: fold the whole
    in-memory frame as a single group state and finalize it.  For kernels
    without a stitch this degenerates to ``run_single`` — both are
    ``finalize(update(init, frame))``, bitwise."""
    if _engine.mergeable(kernel):
        chunks = [frame] if frame.nrows else []
        return _engine.finalize_group(
            kernel, _engine.fold_group(kernel, chunks))
    # a zero-row dataset still finalizes cleanly (like run_streaming)
    return (_engine.run_single(kernel, frame) if frame.nrows
            else kernel.finalize(*kernel.init()))


def collect(dataset, verb: str, *, engine: str = "auto",
            num_shards: int | None = None, prefetch: int | None = None,
            **kwargs) -> CollectResult:
    """Resolve the verb through the kernel registry, pick an engine, run."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    memo_key = _memo_key(dataset, ("collect", verb, engine, num_shards,
                                   # auto's choice moves with the fitted
                                   # costs — key them so a recalibration
                                   # is never served a stale decision
                                   calibration() if engine == "auto"
                                   else None,
                                   tuple(sorted((k, repr(v))
                                                for k, v in kwargs.items()))))
    hit = _memo_get(memo_key)
    if hit is not None:
        return hit
    out = _collect(dataset, verb, engine, num_shards, prefetch, kwargs)
    _memo_put(memo_key, out)
    return out


def _collect(dataset, verb, engine, num_shards, prefetch, kwargs
             ) -> CollectResult:
    spec = spec_for(verb)
    dims = _engine.Dims(dataset.num_activities, dataset.num_cases)
    est = None
    if engine == "auto":
        est = estimate(dataset) if dataset.is_files else None
        engine = choose(dataset, spec, est)
    if engine == "eager":
        if dataset.is_files:
            dataset.plan(columns=spec.columns)  # same projection/column
            # validation (and error) the streaming engine would raise
        kernel = spec.make(dims, **kwargs)
        result = _fold_eager(kernel, eager_frame(dataset))
        return CollectResult(result, None, "eager", verb, est)
    if engine == "sharded":
        result, report = _sharded(dataset, spec, dims, num_shards, **kwargs)
        return CollectResult(result, report, "sharded", verb, est)
    # streaming: per-group states through the cache when the kernel
    # stitches (and the plan is row-level), else the sequential scan
    from repro.query.exec import execute, execute_grouped, grouped_eligible

    kernel = spec.make(dims, **kwargs)
    plan = dataset.plan(columns=spec.columns)
    if grouped_eligible(kernel, dataset.steps):
        result, report = execute_grouped(plan, kernel,
                                         _spec_fp(verb, dims, kwargs))
    else:
        result, report = execute(plan, kernel, prefetch=prefetch)
    return CollectResult(result, report, "streaming", verb, est)


@dataclasses.dataclass(frozen=True)
class CollectManyResult:
    """Per-verb results of one fused pass, plus how it ran.

    ``results[verb]`` is bitwise equal to ``collect(dataset, verb).result``
    under the same engine; ``report`` is the single scan's I/O accounting
    (None for eager).  Indexable: ``res["dfg"]``.
    """

    results: dict
    report: Any | None
    engine: str
    verbs: tuple
    estimate: CostEstimate | None = None

    def __getitem__(self, verb: str):
        return self.results[verb]


def collect_many(dataset, verbs: Iterable[str], *, engine: str = "auto",
                 num_shards: int | None = None, prefetch: int | None = None,
                 verb_kwargs: Mapping[str, dict] | None = None,
                 **common) -> CollectManyResult:
    """Run several registered verbs in ONE pass over the dataset.

    The verbs fuse into a single :func:`~repro.core.engine.compose_specs`
    spec — one kernel, one scan whose projection is the union of the
    member column requirements — and dispatch like any other verb:
    ``engine="auto"`` applies the calibrated cost model to the fused
    spec, ``"sharded"`` mines each distinct distributed state once from
    one gathered stream.  Every registered verb is pruning-exact
    (``variants`` replays skipped groups from header sketches), so the
    fused scan always skips refuted groups whatever the member mix.

    ``verb_kwargs={"alpha": {"min_count": 2}}`` routes per-verb options;
    other keyword arguments (e.g. ``method=``) apply to every member.
    """
    verbs = tuple(verbs)
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    if len(set(verbs)) != len(verbs):
        raise ValueError(f"duplicate verbs in collect_many: {list(verbs)}")
    vk = dict(verb_kwargs or {})
    memo_key = _memo_key(dataset, (
        "collect_many", verbs, engine, num_shards,
        calibration() if engine == "auto" else None,
        tuple(sorted((v, tuple(sorted((k, repr(x)) for k, x in kw.items())))
                     for v, kw in vk.items())),
        tuple(sorted((k, repr(v)) for k, v in common.items()))))
    hit = _memo_get(memo_key)
    if hit is not None:
        return hit
    out = _collect_many(dataset, verbs, engine, num_shards, prefetch, vk,
                        common)
    _memo_put(memo_key, out)
    return out


def _collect_many(dataset, verbs, engine, num_shards, prefetch, vk, common
                  ) -> CollectManyResult:
    specs = {v: spec_for(v) for v in verbs}
    fused = _engine.compose_specs(specs)
    dims = _engine.Dims(dataset.num_activities, dataset.num_cases)
    est = None
    if engine == "auto":
        est = estimate(dataset) if dataset.is_files else None
        engine = choose(dataset, fused, est)
    if engine == "eager":
        if dataset.is_files:
            dataset.plan(columns=fused.columns)
        kernel = fused.make(dims, verb_kwargs=vk, **common)
        results = _fold_eager(kernel, eager_frame(dataset))
        return CollectManyResult(dict(results), None, "eager", verbs, est)
    if engine == "sharded":
        results, report = _sharded_many(dataset, specs, fused, dims,
                                        num_shards, vk, common)
        return CollectManyResult(results, report, "sharded", verbs, est)
    from repro.query.exec import execute, execute_grouped, grouped_eligible

    kernel = fused.make(dims, verb_kwargs=vk, **common)
    plan = dataset.plan(columns=fused.columns)
    if grouped_eligible(kernel, dataset.steps):
        fp = _spec_fp("+".join(verbs), dims,
                      {"verb_kwargs": sorted(vk.items()), **common})
        results, report = execute_grouped(plan, kernel, fp)
    else:
        results, report = execute(plan, kernel, prefetch=prefetch)
    return CollectManyResult(dict(results), report, "streaming", verbs, est)


def group_states_for(dataset, verb: str, **kwargs):
    """The per-unit material ``Dataset.window`` re-merges: ``(kernel,
    states, report)`` with one :class:`~repro.core.engine.GroupState` per
    nonempty row group of the dataset's plan, resolved through the state
    cache.  Raises for non-mergeable verbs or case-level plans (windows
    then fall back to scratch mining)."""
    from repro.query.exec import group_states

    spec = spec_for(verb)
    dims = _engine.Dims(dataset.num_activities, dataset.num_cases)
    kernel = spec.make(dims, **kwargs)
    states, report = group_states(dataset.plan(columns=spec.columns),
                                  kernel, _spec_fp(verb, dims, kwargs))
    return kernel, states, report


def cache_probe(dataset, verb: str = "dfg", **kwargs) -> dict | None:
    """State-cache accounting for a would-be grouped collect, header-only
    (see ``repro.query.exec.grouped_cache_probe``); None when the verb or
    plan is not grouped-eligible or the dataset is in-memory."""
    from repro.query.exec import grouped_cache_probe

    if not dataset.is_files:
        return None
    spec = spec_for(verb)
    dims = _engine.Dims(dataset.num_activities, dataset.num_cases)
    kernel = spec.make(dims, **kwargs)
    return grouped_cache_probe(dataset.plan(columns=spec.columns), kernel,
                               _spec_fp(verb, dims, kwargs))


def to_frame(dataset) -> EventFrame:
    """Materialize the filtered, projected events (engine-agnostic: files
    stream through ``execute_frame``, frames compact in place)."""
    if dataset.is_files:
        from repro.query.exec import execute_frame

        frame, _tables, _report = execute_frame(dataset.plan())
        return frame
    return eager_frame(dataset).compact()
