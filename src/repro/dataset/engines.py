"""Engine dispatch for the ``Dataset`` facade — the first cost-based plan.

Three interchangeable lowerings of one logical plan:

* **eager** — ``edf.read`` every file whole, apply the filter chain in
  memory (the same masks the planner pushes down), run the kernel once.
  No per-group overhead: the fastest path when the surviving data is
  small and pruning would not skip much.
* **streaming** — ``repro.query`` pruned scans: zone maps refute row
  groups before any I/O, one chunk resident at a time, ghost carries keep
  case-indexed kernels exact.  Wins when the predicate is selective or
  the data outgrows memory.
* **sharded** — the same pruned stream split over devices
  (``repro.distributed.query``): one kernel update per shard, ppermute
  halo, psum merge.  Available for verbs whose mergeable state has an
  exact distributed lowering (``KernelSpec.sharded_state``).

``engine="auto"`` picks between them from *header metadata only*: total
on-disk bytes per ``edf.file_sizes``-style group accounting, and the
fraction of groups/bytes the zone maps already refute (case predicates
are conservatively assumed to keep everything).  The thresholds are
deliberately simple and environment-tunable:

* ``REPRO_DATASET_EAGER_BYTES`` (default 64 MiB) — above this total, never
  load eagerly;
* ``REPRO_DATASET_PRUNE_RATIO`` (default 0.5) — below this surviving-bytes
  fraction, stream (pruning pays even for small files);
* ``REPRO_DATASET_SHARD_ROWS`` (default 2M) — above this many surviving
  rows, shard when more than one device is attached.

Every lowering returns bitwise-identical results, so a wrong guess costs
time, never correctness.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import numpy as np

from repro.core import engine as _engine
from repro.core.eventframe import CASE, EventFrame

EAGER_BYTES = int(os.environ.get("REPRO_DATASET_EAGER_BYTES", 64 * 2**20))
PRUNE_RATIO = float(os.environ.get("REPRO_DATASET_PRUNE_RATIO", 0.5))
SHARD_ROWS = int(os.environ.get("REPRO_DATASET_SHARD_ROWS", 2_000_000))

ENGINES = ("auto", "eager", "streaming", "sharded")


def spec_for(verb: str) -> _engine.KernelSpec:
    return _engine.kernel_spec(verb)


# ------------------------------------------------------------ cost model
@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Plan-time I/O estimate from zone maps (no data bytes touched for
    EDFV0003 files; v1/v2 files pay their one-off metadata synthesis)."""

    bytes_total: int
    bytes_est: int          # bytes the pruned scan would read
    rows_total: int
    rows_est: int
    groups_total: int
    groups_est: int

    @property
    def selectivity(self) -> float:
        """Estimated surviving-bytes fraction (1.0 = nothing refuted)."""
        return self.bytes_est / self.bytes_total if self.bytes_total else 1.0


def estimate(dataset) -> CostEstimate:
    """Zone-map selectivity estimate for the dataset's current plan."""
    from repro.query.expr import NONE
    from repro.query.optimize import compile_plan

    bt = be = rt = re_ = gt = ge = 0
    for plan in dataset.plan().per_file():
        ph = compile_plan(plan, True)
        exprs = list(ph.proves)
        for g in range(ph.reader.num_groups):
            n = ph.reader.group_nrows(g)
            if n == 0:
                continue
            nbytes = ph.reader.group_nbytes(g, ph.read_columns)
            gt += 1
            rt += n
            bt += nbytes
            if any(ph.proves[i][g] == NONE for i in exprs):
                continue            # provably refuted: the scan skips it
            ge += 1
            re_ += n
            be += nbytes
    return CostEstimate(bt, be, rt, re_, gt, ge)


def choose(dataset, spec: _engine.KernelSpec,
           est: CostEstimate | None, n_devices: int | None = None) -> str:
    """The cost-based engine decision (see module docstring)."""
    if not dataset.is_files:
        return "eager"
    if est is None:
        est = estimate(dataset)
    if n_devices is None:
        import jax

        n_devices = len(jax.devices())
    if (spec.sharded_state is not None and n_devices > 1
            and est.rows_est >= SHARD_ROWS):
        return "sharded"
    if est.selectivity < PRUNE_RATIO:
        return "streaming"          # pruning pays: read under half the bytes
    if est.bytes_total <= EAGER_BYTES:
        return "eager"
    return "streaming"              # too big to hold; stream it


# --------------------------------------------------------------- engines
def eager_frame(dataset) -> EventFrame:
    """Load everything, apply the filter chain in memory.

    Uses the *same* predicate masks and phase-one kernels the planner
    pushes down, so eager == streaming bitwise by construction.
    """
    import jax.numpy as jnp

    from repro.core import ops
    from repro.query.expr import CasePredicate, bind_schema
    from repro.storage import edf

    if dataset.is_files:
        from repro.core.eventframe import concat_frames
        from repro.query.exec import check_homogeneous

        check_homogeneous(dataset._readers)     # fail like streaming would
        frame = concat_frames([edf.read(p)[0] for p in dataset.paths])
    else:
        frame = dataset.frame
    tables = dataset.tables
    for step in dataset.steps:
        if isinstance(step, CasePredicate):
            resolved = step.resolve(tables)
            kernel = resolved.phase1_kernel(dataset.num_cases)
            keep = resolved.finalize_keep(_engine.run_single(kernel, frame))
            seg, _ = ops.segment_ids_sorted(frame[CASE])
            frame = ops.proj(frame, jnp.asarray(np.asarray(keep))[seg])
        else:
            bound = bind_schema(step, dataset.schema)
            frame = ops.proj(frame, bound.mask(frame))
    if dataset.projection is not None:
        frame = frame.select(dataset.projection)
    return frame


def _sharded(dataset, spec: _engine.KernelSpec, dims, num_shards, **kwargs):
    import jax

    from repro.distributed.query import (query_sharded_dfg,
                                         query_sharded_discovery)

    if spec.sharded_state is None:
        raise ValueError(
            f"verb {spec.name!r} has no exact distributed lowering "
            f"(order-sensitive or validity-blind state); use "
            f"engine='streaming' or 'eager'")
    if not dataset.is_files:
        raise ValueError("engine='sharded' needs a file-backed dataset")
    devs = jax.devices()
    num_shards = len(devs) if num_shards is None else int(num_shards)
    mesh = jax.sharding.Mesh(np.array(devs[:num_shards]), ("data",))
    driver = {"dfg": query_sharded_dfg,
              "discovery": query_sharded_discovery}[spec.sharded_state]
    # same projection/column validation as the other engines (the driver
    # re-projects the scan to its own (activity, case) columns anyway)
    plan = dataset.plan(columns=spec.columns)
    state, report = driver(plan, dims.num_activities, mesh,
                           method=kwargs.get("method", "auto"))
    return spec.from_sharded(state, **kwargs), report


# ------------------------------------------------------------- front door
@dataclasses.dataclass(frozen=True)
class CollectResult:
    """A verb's result plus how it ran (I/O report is None for eager)."""

    result: Any
    report: Any | None
    engine: str
    verb: str
    estimate: CostEstimate | None = None


def collect(dataset, verb: str, *, engine: str = "auto",
            num_shards: int | None = None, **kwargs) -> CollectResult:
    """Resolve the verb through the kernel registry, pick an engine, run."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    spec = spec_for(verb)
    dims = _engine.Dims(dataset.num_activities, dataset.num_cases)
    est = None
    if engine == "auto":
        est = estimate(dataset) if dataset.is_files else None
        engine = choose(dataset, spec, est)
    if engine == "eager":
        if dataset.is_files:
            dataset.plan(columns=spec.columns)  # same projection/column
            # validation (and error) the streaming engine would raise
        kernel = spec.make(dims, **kwargs)
        frame = eager_frame(dataset)
        # a zero-row dataset still finalizes cleanly (like run_streaming)
        result = (_engine.run_single(kernel, frame) if frame.nrows
                  else kernel.finalize(*kernel.init()))
        return CollectResult(result, None, "eager", verb, est)
    if engine == "sharded":
        result, report = _sharded(dataset, spec, dims, num_shards, **kwargs)
        return CollectResult(result, report, "sharded", verb, est)
    # streaming: the pruned multi-scan
    from repro.query.exec import execute

    kernel = spec.make(dims, **kwargs)
    result, report = execute(dataset.plan(columns=spec.columns), kernel)
    return CollectResult(result, report, "streaming", verb, est)


def to_frame(dataset) -> EventFrame:
    """Materialize the filtered, projected events (engine-agnostic: files
    stream through ``execute_frame``, frames compact in place)."""
    if dataset.is_files:
        from repro.query.exec import execute_frame

        frame, _tables, _report = execute_frame(dataset.plan())
        return frame
    return eager_frame(dataset).compact()
