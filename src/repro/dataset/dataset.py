"""The ``Dataset`` facade: one fluent API over every execution engine.

``repro.open(...)`` accepts a path, an ordered list of paths (the
partitions of one (case,time)-sorted log), or an in-memory
:class:`~repro.core.eventframe.EventFrame`, and returns an immutable
:class:`Dataset`.  Transformations (``filter`` / ``project`` / ``union``)
return new datasets and never touch data; terminal verbs (``dfg`` /
``variants`` / ``stats`` / ``alpha`` / ``heuristics`` / ``conformance`` /
``to_frame``) compile the accumulated steps into one logical plan over the
whole file set and hand it to an execution engine::

    import repro
    from repro import col, cases_containing

    ds = repro.open(["jan.edf", "feb.edf", "mar.edf"])
    graph = ds.filter(col("org:resource") == 7).dfg()     # cold groups unread
    net   = ds.filter(cases_containing("pay")).heuristics()

Every verb resolves through the :class:`~repro.core.engine.KernelSpec`
registry (verbs are data, not if-chains) and accepts ``engine=``:

* ``"eager"``      — load everything, filter in memory, mine once (the
  paper's baseline; fastest for small survivors);
* ``"streaming"``  — zone-map-pruned scans, one chunk resident at a time
  (``repro.query``); refuted row groups are never read;
* ``"sharded"``    — the pruned stream sharded over devices
  (``repro.distributed.query``; DFG/discovery-backed verbs);
* ``"auto"``       — cost-based choice from header metadata only (file
  sizes + zone-map selectivity; see ``repro.dataset.engines``).

Whatever the engine, the result is bitwise equal to mining the eagerly
filtered concatenation of the files — the engines are interchangeable
lowerings of one logical plan, which is what makes the choice safe to
automate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping

import numpy as np

from repro.core.eventframe import (ACTIVITY, CASE, EventFrame,
                                   concat_frames)
from repro.query.plan import MultiPlan, check_predicate

from . import engines


def _is_pathlike(x) -> bool:
    import os

    return isinstance(x, (str, os.PathLike))


@dataclasses.dataclass(frozen=True, eq=False)
class Dataset:
    """Immutable, fluent view over a set of EDF files or one in-memory
    frame (see module docstring).  Construct with :func:`repro.open`."""

    paths: tuple = ()
    frame: EventFrame | None = None
    frame_tables: dict = dataclasses.field(default_factory=dict)
    steps: tuple = ()
    projection: tuple | None = None
    hint_activities: int | None = None
    hint_cases: int | None = None

    # -------------------------------------------------------- transforms
    def filter(self, predicate) -> "Dataset":
        """Append a predicate (row-level ``Expr`` or two-pass
        ``CasePredicate``); composes like the eager filter chain."""
        check_predicate(predicate)
        return dataclasses.replace(self, steps=self.steps + (predicate,))

    def project(self, columns: Iterable[str]) -> "Dataset":
        """Restrict the columns the dataset exposes (and the scans read)."""
        return dataclasses.replace(self, projection=tuple(columns))

    def union(self, other: "Dataset") -> "Dataset":
        """Concatenate another dataset's files (or frame rows) after this
        one's.  Both sides must be in the same filter/projection state —
        union the raw opens first, then filter the union."""
        if not isinstance(other, Dataset):
            raise TypeError(f"union() takes a Dataset, got "
                            f"{type(other).__name__}")
        if self.steps != other.steps or self.projection != other.projection:
            raise ValueError(
                "union() requires identical filter/projection state on both "
                "sides; build the union first, then filter it")
        # capacity hints never carry over: num_cases of a union is the sum
        # (minus straddles) and must be re-derived; num_activities only
        # survives when both sides agree
        acts = (self.hint_activities
                if self.hint_activities == other.hint_activities else None)
        if self.is_files and other.is_files:
            return dataclasses.replace(self, paths=self.paths + other.paths,
                                       hint_activities=acts, hint_cases=None)
        if not self.is_files and not other.is_files:
            if self.frame_tables != other.frame_tables:
                raise ValueError("union() of frames with different "
                                 "dictionary tables")
            out = concat_frames([self.frame, other.frame])
            return dataclasses.replace(self, frame=out,
                                       hint_activities=acts, hint_cases=None)
        raise ValueError("union() cannot mix file-backed and in-memory "
                         "datasets; write the frame to EDF first")

    def append(self, frame: EventFrame, *, path: str | None = None,
               tables: Mapping[str, list] | None = None,
               row_group_rows: int | None = None) -> "Dataset":
        """Append ``frame``'s rows to the dataset's last file, atomically.

        The rows become new row groups of that file
        (``storage.edf.append``): old groups' bytes — and their content
        signatures, and therefore the group-state cache — are untouched,
        and the header rewrite is atomic (temp file + ``os.replace``), so
        concurrent readers see either the old snapshot or the new one,
        never a torn mix.  The frame must match the file's schema, be
        case-sorted, and start at/after the file's tail case (the log
        stays (case, time)-sorted case-major across the whole set, which
        is why only the *last* file may grow — earlier partitions are
        sealed).  Dictionary ``tables`` may extend the file's.

        Returns a dataset over the same paths (shape accessors are live,
        so this handle sees the new rows too; the return value exists for
        fluent chaining).  ``row_group_rows=None`` appends one group.
        """
        from repro.storage.edf import append as edf_append

        if not self.is_files:
            raise ValueError("append() needs a file-backed dataset; write "
                             "the frame to EDF first")
        target = str(path) if path is not None else self.paths[-1]
        if target != self.paths[-1]:
            raise ValueError(
                f"append() only extends the last file of the set "
                f"({self.paths[-1]!r}); earlier partitions are sealed")
        edf_append(target, frame, tables=tables,
                   row_group_rows=row_group_rows)
        return dataclasses.replace(self)

    # ------------------------------------------------------------- shape
    # Shape accessors are *live* properties, not cached: files can grow
    # underneath a Dataset via :meth:`append` (this handle or another),
    # and a collect must size its kernels for the groups it will actually
    # scan.  The reads are header-only through pooled readers, so the
    # recompute is cheap; pin capacities explicitly via
    # ``repro.open(..., num_cases=N)`` when kernel-shape stability matters
    # (the mining service does — that is what keeps its state cache warm
    # across appends).
    @property
    def is_files(self) -> bool:
        return bool(self.paths)

    @property
    def _readers(self) -> tuple:
        from repro.storage.edf import pooled_reader

        return tuple(pooled_reader(p) for p in self.paths)

    @property
    def tables(self) -> dict:
        """Dictionary tables, merged across the file set.  Each file's
        table must be a *prefix* of the longest one for its column —
        appends may extend a table (old ids keep their meaning), never
        reorder it — so partitions written before an alphabet grew stay
        unioned with ones written after."""
        if not self.is_files:
            return dict(self.frame_tables)
        merged: dict[str, list] = {}
        for r in self._readers:
            for name, table in r.tables.items():
                cur = merged.get(name)
                if cur is None:
                    merged[name] = list(table)
                    continue
                short, long_ = sorted((cur, list(table)), key=len)
                if long_[:len(short)] != short:
                    raise ValueError(
                        f"dataset files disagree on the dictionary table "
                        f"of {name!r} (not a prefix extension): "
                        f"{self.paths[0]!r} vs {r.path!r}")
                merged[name] = long_
        return merged

    @property
    def schema(self) -> dict:
        """Column name -> {"dtype": ...} (from the files, or synthesized
        from the frame's arrays) — what predicate constants bind against."""
        if self.is_files:
            return dict(self._readers[0].schema)
        return {k: {"dtype": str(np.asarray(v).dtype)}
                for k, v in self.frame.columns.items()}

    @property
    def num_activities(self) -> int:
        if self.hint_activities is not None:
            return int(self.hint_activities)
        table = self.tables.get(ACTIVITY)
        if table is not None:
            return len(table)
        if self.is_files:
            hi = -1
            for r in self._readers:
                for g in range(r.num_groups):
                    if r.group_nrows(g) == 0:
                        continue
                    z = r.group_meta(g)["zones"].get(ACTIVITY)
                    if z is None or "max" not in z:
                        raise ValueError(
                            "cannot infer num_activities (no dictionary "
                            "table, no zone maps); pass "
                            "repro.open(..., num_activities=N)")
                    hi = max(hi, int(z["max"]))
            return hi + 1
        acts = np.asarray(self.frame[ACTIVITY])
        return int(acts.max()) + 1 if acts.size else 0

    @property
    def num_cases(self) -> int:
        if self.hint_cases is not None:
            return int(self.hint_cases)
        if self.is_files:
            from repro.query.exec import count_cases

            total = count_cases(MultiPlan(self.paths))
            if total is None:
                raise ValueError(
                    "cannot infer num_cases (a file lacks segment "
                    "metadata); pass repro.open(..., num_cases=N)")
            return total
        case = np.asarray(self.frame[CASE])
        return int((case[1:] != case[:-1]).sum()) + 1 if case.size else 0

    def file_sizes(self) -> dict:
        """Summed ``storage.edf.file_sizes`` accounting over the file set."""
        from repro.storage.edf import file_sizes

        if not self.is_files:
            raise ValueError("file_sizes() needs a file-backed dataset")
        sizes = [file_sizes(p) for p in self.paths]
        return {"total": sum(s["total"] for s in sizes),
                "raw": sum(s["raw"] for s in sizes),
                "per_file": sizes}

    def plan(self, columns: Iterable[str] | None = None) -> MultiPlan:
        """The logical plan the streaming/sharded engines execute.

        ``columns`` is the verb's column requirement: used as the scan
        projection when the user has not projected explicitly (predicates
        add their own columns at compile time).
        """
        if not self.is_files:
            raise ValueError("in-memory datasets have no scan plan")
        proj = self.projection
        if proj is not None and columns is not None:
            missing = set(columns) & set(self.schema) - set(proj)
            if missing:
                raise ValueError(
                    f"verb needs columns {sorted(missing)} but the dataset "
                    f"is projected to {list(proj)}")
        if proj is None and columns is not None:
            proj = tuple(c for c in columns if c in self.schema)
        return MultiPlan(self.paths, self.steps, proj)

    def describe(self) -> str:
        """One line per logical node, dataset-level."""
        if self.is_files:
            lines = [f"open({list(self.paths)!r})"]
        else:
            lines = [f"open(<frame: {self.frame.nrows} rows>)"]
        lines += [f"  filter {s!r}" for s in self.steps]
        if self.projection is not None:
            lines.append(f"  project {list(self.projection)}")
        return "\n".join(lines)

    def explain(self, verb: str | None = "dfg",
                verbs: Iterable[str] | None = None) -> str:
        """The plan, the engine the calibrated cost model would pick, and
        — for a fused collection (``verbs=[...]``) — the fused plan: the
        member verbs, the shared scan columns, whether pruning survives
        the ``mask_exact`` intersection, and the prefetch depth."""
        from repro.core.engine import compose_specs
        from repro.query.exec import prefetch_depth

        if verbs is not None:
            spec = compose_specs({v: engines.spec_for(v) for v in verbs})
        else:
            spec = engines.spec_for(verb)
        est = engines.estimate(self) if self.is_files else None
        choice = engines.choose(self, spec, est)
        lines = [self.describe(), f"  engine {choice} (auto)"]
        if verb in ("graph", "reachability", "bottleneck_paths",
                    "node_centrality") and verbs is None:
            n = self.num_activities + 2
            lines.append(f"  graph query: semiring closure over the "
                         f"({n}, {n}) compiled ProcessGraph — finalize of "
                         f"the merged dfg state, not a second scan")
        if est is not None:
            cal = engines.calibration()
            lines.append(f"  estimate {est.bytes_est}/{est.bytes_total} "
                         f"bytes, {est.groups_est}/{est.groups_total} groups")
            lines.append(f"  cost eager~{cal.eager_us(est):.0f}us "
                         f"streaming~{cal.streaming_us(est):.0f}us "
                         f"(calibration: {cal.source})")
        if verbs is not None:
            lines.append(f"  fused [{', '.join(spec.members)}] -> one "
                         f"pruned scan of {list(spec.columns)}")
            lines.append(f"  prefetch {prefetch_depth()} group(s) ahead")
        probe = None if verbs is not None else engines.cache_probe(self, verb)
        if probe is not None:
            from repro.query.statecache import state_cache

            lines.append(
                f"  state-cache {probe['units']} group units: "
                f"{probe['cached']} merged-from-cache, {probe['fresh']} "
                f"freshly decoded, {probe['ghosted']} ghosted "
                f"({state_cache().bytes >> 10} KiB resident)")
        sketch_refuted = self._sketch_refutations()
        if sketch_refuted is not None:
            lines.append(f"  sketch keeps refute {sketch_refuted[0]}/"
                         f"{sketch_refuted[1]} groups (header-only, "
                         f"no phase-one I/O)")
        return "\n".join(lines)

    def _sketch_refutations(self) -> tuple | None:
        """(groups refuted by sketch-derived keep masks, nonempty groups)
        when the plan carries a :class:`~repro.query.expr.SketchPredicate`
        and every file's variant sketches resolve it header-only; None
        otherwise (no such predicate, or sketches unavailable)."""
        from repro.query.exec import (_multi_offsets, _sketch_keeps)
        from repro.query.expr import SketchPredicate
        from repro.query.optimize import compile_plan

        if not self.is_files or not any(isinstance(s, SketchPredicate)
                                        for s in self.steps):
            return None
        physicals = [compile_plan(p, True) for p in self.plan().per_file()]
        offsets, total = _multi_offsets(physicals)
        keeps = _sketch_keeps(physicals, total, physicals[0].steps)
        if not keeps:
            return None
        refuted = groups = 0
        for ph, off in zip(physicals, offsets):
            for g in ph._nonempty():
                groups += 1
                lo = off + int(ph.seg_start[g])
                hi = lo + int(ph.seg_count[g])
                if any(not k[lo:hi].any() for k in keeps.values()):
                    refuted += 1
        return refuted, groups

    # ------------------------------------------------------------- verbs
    def collect(self, verb: str, *, engine: str = "auto",
                num_shards: int | None = None,
                **kwargs) -> "engines.CollectResult":
        """Run a registered terminal verb; returns result + I/O report +
        the engine that ran (the named verbs below are sugar over this)."""
        return engines.collect(self, verb, engine=engine,
                               num_shards=num_shards, **kwargs)

    def collect_many(self, verbs: Iterable[str], *, engine: str = "auto",
                     num_shards: int | None = None,
                     prefetch: int | None = None,
                     verb_kwargs: Mapping[str, dict] | None = None,
                     **common) -> "engines.CollectManyResult":
        """Run several verbs in ONE pass — one fused kernel over one scan
        (or one eager load / one sharded gather), each verb's result
        bitwise equal to its separate :meth:`collect`::

            res = ds.collect_many(["dfg", "stats", "variants"])
            res["dfg"], res["stats"], res["variants"]

        ``verb_kwargs={"alpha": {"min_count": 2}}`` routes per-verb
        options; remaining keyword arguments apply to every member.
        Results are the verbs' raw kernel outputs (``variants`` yields the
        fingerprint triple — post-process with
        ``repro.core.variants._counts_from_fps`` as :meth:`variants` does).
        """
        return engines.collect_many(self, verbs, engine=engine,
                                    num_shards=num_shards, prefetch=prefetch,
                                    verb_kwargs=verb_kwargs, **common)

    def profile(self, *, engine: str = "auto",
                verb_kwargs: Mapping[str, dict] | None = None,
                **common) -> "engines.CollectManyResult":
        """Every registered verb, one pass: the whole-dashboard collection
        (``collect_many`` over the full kernel registry).  Needs the full
        event schema (timed verbs read ``time:timestamp``)."""
        from repro.core.engine import kernel_specs

        verbs = tuple(n for n, s in kernel_specs().items() if not s.members)
        return self.collect_many(verbs, engine=engine,
                                 verb_kwargs=verb_kwargs, **common)

    def dfg(self, *, engine: str = "auto", method: str = "auto", **kw):
        """Directly-follows graph (counts + start/end histograms)."""
        return self.collect("dfg", engine=engine, method=method, **kw).result

    def stats(self, *, engine: str = "auto", **kw) -> dict:
        """Activity counts, case sizes, case durations, sojourn times —
        one fused pass over the stream."""
        return self.collect("stats", engine=engine, **kw).result

    def variants(self, *, engine: str = "auto", **kw) -> dict:
        """{variant fingerprint: number of cases} (the paper's Variants).

        Pruning-exact like every other verb: refuted row groups are
        skipped and their hash contribution replayed from the per-group
        affine sketch maps persisted in EDFV0003 headers (synthesized
        on open for older files), so pruned == eager == sharded bitwise.
        Filter by result with :func:`repro.variant_in` /
        :func:`repro.variant_of` — those predicates resolve from the same
        sketches with zero phase-one I/O.
        """
        from repro.core.variants import _counts_from_fps

        fp1, fp2, ncases = self.collect("variants", engine=engine,
                                        **kw).result
        return _counts_from_fps(fp1, fp2, min(int(ncases), self.num_cases))

    def alpha(self, *, engine: str = "auto", min_count: int = 1,
              method: str = "auto", **kw):
        """Alpha miner (places + start/end activities) over the dataset."""
        return self.collect("alpha", engine=engine, min_count=min_count,
                            method=method, **kw).result

    def heuristics(self, *, engine: str = "auto", method: str = "auto",
                   **thresholds):
        """Heuristics miner (dependency graph + AND/XOR bindings)."""
        return self.collect("heuristics", engine=engine, method=method,
                            **thresholds).result

    # ------------------------------------------------------- graph verbs
    def _activity_labels(self):
        try:
            tables = self.tables
        except Exception:
            return None
        lab = tables.get(ACTIVITY)
        if lab is not None and len(lab) == self.num_activities:
            return lab
        return None

    def graph(self, *, engine: str = "auto", timed: bool = False,
              method: str = "auto", **kw):
        """Compile the dataset's DFG state into a
        :class:`~repro.graph.ir.ProcessGraph` — dense weighted adjacency
        over the activity alphabet plus artificial start (``▶``) / end
        (``■``) nodes.  ``timed=True`` overlays mean waiting times per
        edge (streaming/eager only: f32 waits are order-sensitive).
        Activity labels from the dictionary tables are attached when
        available."""
        g = self.collect("graph", engine=engine, timed=timed,
                         method=method, **kw).result
        lab = self._activity_labels()
        return g if lab is None else g.with_labels(lab)

    def reachability(self, k: int | None = None, *, engine: str = "auto",
                     **kw):
        """k-step reachability closure of the process graph (``k=None`` =
        full transitive closure); exact and bitwise engine-invariant."""
        return self.collect("reachability", engine=engine, k=k, **kw).result

    def bottlenecks(self, weights: str = "frequency", *,
                    engine: str = "auto", **kw):
        """All-pairs shortest (min-plus) + widest (max-min) paths over the
        process graph, plus the source→sink bottleneck corridor.
        ``weights="performance"`` uses mean waiting times (streaming/eager
        only)."""
        return self.collect("bottleneck_paths", engine=engine,
                            weights=weights, **kw).result

    def centrality(self, iters: int = 16, *, engine: str = "auto", **kw):
        """Per-node in/out degree + power-method flow centrality."""
        return self.collect("node_centrality", engine=engine, iters=iters,
                            **kw).result

    def to_xes(self, path: str) -> None:
        """Export the filtered events as XES (ISO-8601 timestamps;
        dictionary columns decoded through the string tables).  Re-imported
        and re-mined, the XES reproduces this dataset's DFG state bitwise."""
        from repro.graph.export import frame_to_xes

        frame_to_xes(path, self.to_frame(), self.tables)

    def conformance(self, model, *, engine: str = "auto",
                    method: str = "auto", **kw):
        """Replay the dataset's DFG against a discovered model.

        Dispatches on the model type: :class:`HeuristicsNet` -> heuristics
        fitness, :class:`AlphaModel` -> alpha fitness, anything array-like
        -> footprint fitness against an allowed-relation matrix.
        """
        import jax.numpy as jnp

        from repro.core import conformance as _conformance
        from repro.core.discovery import AlphaModel, HeuristicsNet

        d = self.collect("dfg", engine=engine, method=method, **kw).result
        if isinstance(model, HeuristicsNet):
            return _conformance.heuristics_fitness(d, model)
        if isinstance(model, AlphaModel):
            return _conformance.alpha_fitness(d, model)
        return _conformance.footprint_fitness(d, jnp.asarray(model))

    def window(self, by: str = "groups", *, size, step=None):
        """Sliding windows over the dataset (``repro.dataset.window``).

        ``by="groups"`` windows span ``size`` row groups stepped by
        ``step`` (mined by re-merging cached per-group states — a slide
        re-decodes nothing); ``by="time"`` windows span ``[t, t + size]``
        timestamp intervals stepped by ``step`` (inclusive edges).
        ``step`` defaults to ``size`` (tumbling windows)::

            w = ds.window(by="time", size=86400.0, step=3600.0)
            w.collect("dfg")              # per-window DFGs
            w.drift()                     # footprint drift per slide
            w.conformance(ds.alpha())     # per-window replay fitness
        """
        from .window import Windows

        return Windows(self, by, size, size if step is None else step)

    def to_frame(self) -> EventFrame:
        """Materialize the filtered, projected events as one compact frame
        (refuted rows dropped; multi-file datasets concatenate in order)."""
        return engines.to_frame(self)


def open_dataset(source, *, tables: Mapping[str, list] | None = None,
                 num_activities: int | None = None,
                 num_cases: int | None = None) -> Dataset:
    """Open an event dataset: the single entry point of the facade.

    ``source`` is an EDF path, an ordered iterable of EDF paths (the
    partitions of one (case,time)-sorted log — any mix of v1/v2/v3 files
    with one schema), or an in-memory ``EventFrame`` (pass its dictionary
    ``tables`` alongside).  ``num_activities`` / ``num_cases`` override the
    inferred capacity dimensions (useful for files without dictionary
    tables or segment metadata).
    """
    if isinstance(source, EventFrame):
        return Dataset(frame=source, frame_tables=dict(tables or {}),
                       hint_activities=num_activities, hint_cases=num_cases)
    if tables is not None:
        raise ValueError("tables= is only for in-memory frames (files carry "
                         "their own dictionary tables)")
    if _is_pathlike(source):
        paths: tuple = (str(source),)
    else:
        paths = tuple(str(p) for p in source)
    if not paths:
        raise ValueError("open() needs at least one path")
    return Dataset(paths=paths, hint_activities=num_activities,
                   hint_cases=num_cases)
