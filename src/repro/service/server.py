"""The query server: snapshot-consistent mining over live-ingested files.

:class:`MiningService` answers mining requests over a growing set of EDF
partitions.  Every request mines a *snapshot*: the per-file content
signatures (``storage.edf.file_sig``) captured at request start, pinned
via :meth:`EDFReader.pin` (appends replace the *path*, never the inode,
so a pinned handle keeps reading its consistent pre-append view), and
re-validated after the mine.  If an append raced the request — the only
way a multi-round collect could have mixed two file generations — the
request retries against the new snapshot; the final attempt takes the
per-path append locks, briefly holding writers off, so a request can
never livelock under continuous ingest.  Each response carries the claim
(``snapshot``): exactly which file states the result was mined from,
which is what the parity tests re-mine.

Kernel capacity dims are *pinned*: the service sizes ``num_cases`` to a
power-of-two high-water mark (``case_capacity``), not the live case
count.  Per-case result arrays carry identity values past the live
count, and — because the state-cache spec fingerprint includes the
capacity dims — cached per-group folds stay valid across appends: a
re-collect after an append only decodes the fresh groups.

HTTP layer: a ``ThreadingHTTPServer`` JSON API —

=============  ====  ====================================================
``/health``    GET   liveness + file set + cache counters
``/collect``   both  one verb (``verb=``, ``engine=``, verb kwargs)
``/profile``   both  every registered verb, one fused pass
``/window``    both  sliding windows (``by=``, ``size=``, ``step=``,
                     ``verb=``)
``/explain``   both  the plan + engine choice + cache probe, as text
=============  ====  ====================================================

GET query parameters are JSON-coerced (``min_count=2`` arrives as an
int); POST bodies are JSON objects with the same keys.  Env knobs:
``REPRO_SERVICE_DIR`` ``REPRO_SERVICE_HOST`` ``REPRO_SERVICE_PORT``
``REPRO_SERVICE_CASE_CAPACITY`` ``REPRO_SERVICE_ATTEMPTS`` (see
:func:`main`).
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import os
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Mapping

import numpy as np

from repro.storage import edf as _edf


class ServiceError(Exception):
    """A request-level failure with an HTTP status."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status


def to_jsonable(obj):
    """Recursively convert a mining result (jax/numpy arrays, namedtuple
    models, dataclass reports, fingerprint-keyed dicts) into plain JSON
    types.  Floats pass through Python's repr round-trip, so
    ``json.dumps(to_jsonable(a)) == json.dumps(to_jsonable(b))`` is a
    bitwise-faithful equality on numeric payloads."""
    import jax

    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, (np.ndarray, jax.Array)):
        return np.asarray(obj).tolist()
    if hasattr(obj, "_asdict"):                         # namedtuple models
        return {"_type": type(obj).__name__,
                **{k: to_jsonable(v) for k, v in obj._asdict().items()}}
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"_type": type(obj).__name__,
                **{f.name: to_jsonable(getattr(obj, f.name))
                   for f in dataclasses.fields(obj)}}
    if isinstance(obj, Mapping):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [to_jsonable(v) for v in obj]
    return repr(obj)


def _round_capacity(n: int, floor: int = 1024) -> int:
    cap = max(int(floor), 1)
    while cap < n:
        cap *= 2
    return cap


class MiningService:
    """Snapshot-consistent mining over a live file set (module docstring).

    ``source`` is a directory of ``part_*.edf`` partitions (re-listed per
    request, so partitions appearing later are picked up), an explicit
    path list, or an :class:`~repro.service.ingest.Ingestor` (its output
    partitions are served).
    """

    def __init__(self, source, case_capacity: int | None = None,
                 max_attempts: int | None = None):
        from .ingest import Ingestor

        self._ingestor = source if isinstance(source, Ingestor) else None
        self._dir = source if isinstance(source, str) else None
        self._fixed = (tuple(str(p) for p in source)
                       if not (self._ingestor or self._dir) else None)
        self.case_floor = (case_capacity if case_capacity is not None
                           else int(os.environ.get(
                               "REPRO_SERVICE_CASE_CAPACITY") or 1024))
        self.max_attempts = (max_attempts if max_attempts is not None
                             else int(os.environ.get(
                                 "REPRO_SERVICE_ATTEMPTS") or 4))
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self._case_cap = 0
        self._cap_lock = threading.Lock()
        self.started = time.time()
        self.requests = 0
        self.retries = 0

    # ---------------------------------------------------------- snapshot
    def paths(self) -> list[str]:
        if self._ingestor is not None:
            return self._ingestor.paths
        if self._dir is not None:
            try:
                names = sorted(n for n in os.listdir(self._dir)
                               if n.startswith("part_") and
                               n.endswith(".edf"))
            except FileNotFoundError:
                return []
            return [os.path.join(self._dir, n) for n in names]
        return list(self._fixed)

    def _capacity(self, actual: int) -> int:
        with self._cap_lock:
            if actual > self._case_cap:
                self._case_cap = _round_capacity(actual, self.case_floor)
            return self._case_cap

    def _mine(self, fn):
        """Run ``fn(dataset)`` against one consistent snapshot.

        Optimistic attempts pin the pooled readers (holding the snapshot's
        inodes open) and re-validate every file signature afterwards; a
        raced append triggers a retry.  The last attempt holds the
        per-path append locks instead — guaranteed consistent, so
        continuous ingest can delay a request but never starve it.
        Returns ``(payload, claim)`` where the claim names the exact file
        states mined.
        """
        import repro

        last_exc = None
        for attempt in range(self.max_attempts):
            if attempt:
                self.retries += 1
            locked = attempt == self.max_attempts - 1
            paths = self.paths()
            if not paths:
                raise ServiceError(503, "no partitions available yet")
            try:
                with contextlib.ExitStack() as stack:
                    if locked:
                        for p in sorted(paths):
                            stack.enter_context(_edf._append_lock(p))
                    readers = [_edf.pooled_reader(p) for p in paths]
                    for r in readers:
                        stack.enter_context(r.pin())
                    sig0 = tuple(r._sig for r in readers)
                    cap = self._capacity(repro.open(paths).num_cases)
                    ds = repro.open(paths, num_cases=cap)
                    claim = {
                        "files": [{"path": p, "nrows": r.nrows,
                                   "groups": r.num_groups, "tag": r._sig[2]}
                                  for p, r in zip(paths, readers)],
                        "rows": sum(r.nrows for r in readers),
                        "num_cases": cap,
                        "num_activities": ds.num_activities,
                    }
                    try:
                        payload = fn(ds)
                    except _edf.StaleFileError as e:
                        last_exc = e
                        continue
                    except Exception:
                        # re-raise real errors; swallow only failures that
                        # raced an append (the snapshot moved underneath)
                        if locked or self._sigs(paths) == sig0:
                            raise
                        last_exc = RuntimeError(
                            "an append raced the mine")
                        continue
                    if locked or self._sigs(paths) == sig0:
                        return payload, claim
                    last_exc = RuntimeError(
                        "the snapshot advanced during the mine")
            except (_edf.StaleFileError, FileNotFoundError) as e:
                last_exc = e            # reader resolution raced an append
                continue
        raise ServiceError(503, "could not mine a consistent snapshot after "
                                f"{self.max_attempts} attempts: {last_exc}")

    @staticmethod
    def _sigs(paths):
        try:
            return tuple(_edf.file_sig(p) for p in paths)
        except (OSError, ValueError):
            return None

    # ---------------------------------------------------------- requests
    def collect(self, verb: str | None = None, engine: str = "auto",
                **kwargs) -> dict:
        """One verb over the current snapshot (per-request engine)."""
        if not verb:
            raise ServiceError(400, "collect needs verb=<registered verb>")
        self.requests += 1
        (res, claim) = self._mine(
            lambda ds: ds.collect(verb, engine=engine, **kwargs))
        return {"verb": verb, "engine": res.engine, "snapshot": claim,
                "report": to_jsonable(res.report),
                "result": to_jsonable(res.result)}

    def profile(self, engine: str = "auto", **kwargs) -> dict:
        """Every registered verb in one fused pass (the dashboard call)."""
        self.requests += 1
        (res, claim) = self._mine(
            lambda ds: ds.profile(engine=engine, **kwargs))
        return {"verbs": list(res.verbs), "engine": res.engine,
                "snapshot": claim, "report": to_jsonable(res.report),
                "results": to_jsonable(res.results)}

    def window(self, verb: str | None = None, by: str = "groups",
               size=None, step=None, engine: str = "auto", **kwargs) -> dict:
        """Sliding-window mining over the snapshot (``Dataset.window``)."""
        if not verb or size is None:
            raise ServiceError(400, "window needs verb= and size= "
                                    "(by=groups|time, optional step=)")
        self.requests += 1
        size_v = float(size) if by == "time" else int(size)
        step_v = None if step is None else (
            float(step) if by == "time" else int(step))
        (res, claim) = self._mine(
            lambda ds: ds.window(by, size=size_v, step=step_v)
                         .collect(verb, **kwargs))
        return {"verb": verb, "by": by, "size": size_v,
                "step": step_v if step_v is not None else size_v,
                "snapshot": claim, "bounds": to_jsonable(res.bounds),
                "report": to_jsonable(res.report),
                "results": to_jsonable(res.results)}

    def graph(self, query: str | None = None, engine: str = "auto",
              **kwargs) -> dict:
        """The compiled process graph, optionally with one graph query
        (``query=reachability|bottleneck_paths|node_centrality``) answered
        over the *same* snapshot — graph and query come from one ``_mine``
        so the pair is guaranteed consistent."""
        self.requests += 1
        queries = ("reachability", "bottleneck_paths", "node_centrality")
        if query is not None and query not in queries:
            raise ServiceError(400, f"unknown graph query {query!r}; "
                                    f"one of {list(queries)}")
        timed = bool(kwargs.pop("timed", False))

        def fn(ds):
            res = ds.collect("graph", engine=engine, timed=timed)
            g = res.result
            lab = ds._activity_labels()
            if lab is not None:
                g = g.with_labels(lab)
            out = {"graph": {"freq": to_jsonable(g.freq),
                             "perf": to_jsonable(g.perf),
                             "labels": list(g.node_labels()),
                             "source": g.source, "sink": g.sink},
                   "engine": res.engine}
            if query is not None:
                out["query"] = to_jsonable(
                    ds.collect(query, engine=engine, **kwargs).result)
            return out

        payload, claim = self._mine(fn)
        payload["snapshot"] = claim
        return payload

    def explain(self, verb: str = "dfg", **_ignored) -> dict:
        """The facade's ``explain`` text for one verb, plus the claim."""
        self.requests += 1
        (text, claim) = self._mine(lambda ds: ds.explain(verb))
        return {"verb": verb, "snapshot": claim, "explain": text}

    def health(self) -> dict:
        """Liveness: the current file set and cache counters (never 503)."""
        from repro.query.statecache import state_cache

        files = []
        for p in self.paths():
            try:
                header, _ = _edf.read_header(p)
                files.append({"path": p, "nrows": header["nrows"],
                              "groups": len(header.get("groups", ()))})
            except (OSError, AssertionError):
                files.append({"path": p, "nrows": None, "groups": None})
        sc = state_cache()
        out = {"ok": True, "files": files,
               "rows": sum(f["nrows"] or 0 for f in files),
               "uptime_s": time.time() - self.started,
               "requests": self.requests, "retries": self.retries,
               "case_capacity": self._case_cap,
               "state_cache": {"entries": len(sc), "bytes": sc.bytes,
                               "hits": sc.hits, "misses": sc.misses}}
        if self._ingestor is not None:
            out["ingested"] = self._ingestor.ingested
        return out


# ------------------------------------------------------------- HTTP layer
def _coerce(value: str):
    """JSON-coerce one query-string value (numbers, bools, lists pass
    through as their JSON types; everything else stays a string)."""
    try:
        return json.loads(value)
    except (json.JSONDecodeError, TypeError):
        return value


class _Handler(BaseHTTPRequestHandler):
    """Routes requests to the bound :class:`MiningService` (see serve())."""

    service: MiningService              # bound by serve()
    protocol_version = "HTTP/1.1"

    def log_message(self, *args) -> None:       # keep the server quiet
        pass

    def do_GET(self) -> None:
        self._route()

    def do_POST(self) -> None:
        self._route()

    def _route(self) -> None:
        parsed = urllib.parse.urlparse(self.path)
        params = {k: _coerce(v[-1])
                  for k, v in urllib.parse.parse_qs(parsed.query).items()}
        if self.command == "POST":
            length = int(self.headers.get("Content-Length") or 0)
            body = self.rfile.read(length) if length else b""
            if body:
                try:
                    payload = json.loads(body)
                    if not isinstance(payload, dict):
                        raise ValueError("body must be a JSON object")
                    params.update(payload)
                except (json.JSONDecodeError, ValueError) as e:
                    return self._send(400, {"ok": False, "error": str(e)})
        route = parsed.path.rstrip("/") or "/health"
        handlers = {"/health": self.service.health,
                    "/collect": self.service.collect,
                    "/profile": self.service.profile,
                    "/window": self.service.window,
                    "/graph": self.service.graph,
                    "/explain": self.service.explain}
        fn = handlers.get(route)
        if fn is None:
            return self._send(404, {"ok": False, "error":
                                    f"unknown endpoint {route!r}; one of "
                                    f"{sorted(handlers)}"})
        t0 = time.perf_counter()
        try:
            out = fn(**params) if route != "/health" else fn()
        except ServiceError as e:
            return self._send(e.status, {"ok": False, "error": str(e)})
        except (ValueError, KeyError, TypeError) as e:
            return self._send(400, {"ok": False, "error":
                                    f"{type(e).__name__}: {e}"})
        except Exception as e:          # pragma: no cover - defensive
            return self._send(500, {"ok": False, "error":
                                    f"{type(e).__name__}: {e}"})
        out = {"ok": True, **out}
        out["elapsed_us"] = (time.perf_counter() - t0) * 1e6
        self._send(200, out)

    def _send(self, status: int, body: dict) -> None:
        blob = json.dumps(body).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(blob)))
        self.end_headers()
        self.wfile.write(blob)


def serve(source, host: str | None = None, port: int | None = None,
          **service_kwargs) -> ThreadingHTTPServer:
    """Bind the JSON API over ``source`` (dir | paths | Ingestor |
    MiningService).  Returns the bound threaded server — call
    ``serve_forever()`` (or run it on a thread; handler threads are
    daemons).  ``port=0`` picks a free port (``server_address[1]``)."""
    service = (source if isinstance(source, MiningService)
               else MiningService(source, **service_kwargs))
    handler = type("BoundHandler", (_Handler,), {"service": service})
    host = host if host is not None else \
        os.environ.get("REPRO_SERVICE_HOST", "127.0.0.1")
    port = port if port is not None else \
        int(os.environ.get("REPRO_SERVICE_PORT") or 8099)
    httpd = ThreadingHTTPServer((host, port), handler)
    httpd.daemon_threads = True
    return httpd


def main(argv=None) -> None:
    """CLI: serve a partition directory, optionally ingesting a batch
    directory on a background thread while serving::

        python -m repro.service.server --dir /data/parts \\
            --ingest-from /data/batches --port 8099
    """
    from .ingest import Ingestor

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--dir", default=os.environ.get("REPRO_SERVICE_DIR"),
                    help="partition directory to serve (REPRO_SERVICE_DIR)")
    ap.add_argument("--ingest-from", default=None,
                    help="batch directory to tail into --dir while serving")
    ap.add_argument("--host", default=None)
    ap.add_argument("--port", type=int, default=None)
    args = ap.parse_args(argv)
    if not args.dir:
        ap.error("--dir (or REPRO_SERVICE_DIR) is required")
    source: object = args.dir
    ingestor = None
    if args.ingest_from:
        ingestor = Ingestor(args.dir, args.ingest_from).start()
        source = ingestor
    httpd = serve(source, args.host, args.port)
    print(f"repro mining service on http://{httpd.server_address[0]}:"
          f"{httpd.server_address[1]} (dir={args.dir})", flush=True)
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        httpd.shutdown()
        if ingestor is not None:
            ingestor.stop()


if __name__ == "__main__":
    main()
