"""The live mining service: append-only ingestion + a concurrent query API.

Three layers over the ``Dataset`` facade, closing the loop the paper
opens (columnar event dataframes scale *analysis*; this serves it):

* ``storage.edf.append`` / ``Dataset.append`` — atomic append-only
  growth of EDFV0003 files (new row groups, header rewritten through
  ``os.replace``; old groups byte-identical, so the per-group state
  cache stays hot);
* :class:`~repro.service.ingest.Ingestor` — a resilient batch ETL loop
  tailing a source (directory or callable) into partitioned EDFV0003
  files, with a persisted skip-index, retry-with-backoff, and
  crash-safe resume;
* :class:`~repro.service.server.MiningService` / :func:`serve` — a
  threaded ``http.server`` JSON API (``/collect`` ``/profile``
  ``/window`` ``/explain`` ``/health``) over the shared reader pool and
  state/result caches, each request mining a snapshot-consistent view.
"""
from .ingest import Ingestor, directory_source
from .server import MiningService, ServiceError, serve, to_jsonable

__all__ = ["Ingestor", "directory_source", "MiningService", "ServiceError",
           "serve", "to_jsonable"]
