"""Resilient batch ETL: tail a source of event batches into EDF partitions.

The :class:`Ingestor` drains a *source* — a directory of batch ``.edf``
files, or any callable — into partitioned EDFV0003 files under ``out_dir``
(``part_00000.edf``, ``part_00001.edf``, ...), appending row groups to the
current partition (``storage.edf.append``) until it reaches
``partition_rows``, then sealing it and starting the next.

Crash safety is a write-ahead skip-index (``_ingest_index.json`` in
``out_dir``, rewritten atomically):

1. record the batch as *pending* — batch id, target partition, row count,
   and the partition's row count *before* the apply;
2. apply the batch (create the partition via temp file + ``os.replace``,
   or append to it — both atomic), retrying with exponential backoff on
   transient ``OSError``;
3. move the batch from *pending* to *done*.

Because step 2 is atomic, a crash anywhere leaves the partition either
pre- or post-apply, never torn; on resume the pending entry is resolved
by comparing the partition's header row count against
``nrows_before + rows`` — landed appends are acknowledged, lost ones
redone, and re-delivered batches in ``done`` are skipped.  Batches must
arrive in case-major order across the whole stream (each partition stays
(case, time)-sorted; ``append`` enforces it per file).

Env knobs (constructor arguments win):

* ``REPRO_SERVICE_PARTITION_ROWS`` — rows before a partition seals
  (default 500000);
* ``REPRO_SERVICE_ROW_GROUP_ROWS`` — row-group size inside a partition
  (default 8192);
* ``REPRO_SERVICE_RETRIES`` / ``REPRO_SERVICE_BACKOFF`` — transient-write
  retry count (default 5) and initial backoff seconds (default 0.05).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Iterable, Mapping

from repro.core.eventframe import EventFrame
from repro.storage import edf

INDEX_NAME = "_ingest_index.json"


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name)
    return int(raw) if raw not in (None, "") else default


def _env_float(name: str, default: float) -> float:
    raw = os.environ.get(name)
    return float(raw) if raw not in (None, "") else default


def directory_source(batch_dir: str) -> Callable:
    """A source that tails ``batch_dir`` for ``*.edf`` batch files.

    Returns a callable ``poll(done_ids) -> [(batch_id, frame, tables)]``
    yielding not-yet-processed batches in sorted filename order (name
    your batches monotonically — e.g. zero-padded sequence numbers — so
    arrival order is ingest order).  Batch files are left in place; the
    skip-index is what marks them processed.
    """
    def poll(done_ids) -> list:
        out = []
        try:
            names = sorted(os.listdir(batch_dir))
        except FileNotFoundError:
            return out
        for name in names:
            if not name.endswith(".edf") or name in done_ids:
                continue
            path = os.path.join(batch_dir, name)
            try:
                frame, tables = edf.read(path)
            except (OSError, ValueError, AssertionError):
                continue            # partially-written drop: next poll
            out.append((name, frame, tables))
        return out

    return poll


class Ingestor:
    """Drain a batch source into partitioned EDFV0003 files (module doc).

    ``source`` is a directory path (tailed via :func:`directory_source`)
    or a callable ``poll(done_ids) -> iterable[(batch_id, frame, tables)]``.
    ``run_once()`` drains what is currently available; ``start()`` /
    ``stop()`` run the loop on a daemon thread with ``poll_interval``
    sleeps between empty polls.
    """

    def __init__(self, out_dir: str, source,
                 partition_rows: int | None = None,
                 row_group_rows: int | None = None,
                 max_retries: int | None = None,
                 backoff: float | None = None,
                 poll_interval: float = 0.2):
        self.out_dir = out_dir
        self.poll = (directory_source(source) if isinstance(source, str)
                     else source)
        self.partition_rows = (partition_rows if partition_rows is not None
                               else _env_int("REPRO_SERVICE_PARTITION_ROWS",
                                             500_000))
        self.row_group_rows = (row_group_rows if row_group_rows is not None
                               else _env_int("REPRO_SERVICE_ROW_GROUP_ROWS",
                                             8192))
        self.max_retries = (max_retries if max_retries is not None
                            else _env_int("REPRO_SERVICE_RETRIES", 5))
        self.backoff = (backoff if backoff is not None
                        else _env_float("REPRO_SERVICE_BACKOFF", 0.05))
        self.poll_interval = poll_interval
        os.makedirs(out_dir, exist_ok=True)
        self.index_path = os.path.join(out_dir, INDEX_NAME)
        self._index = self._load_index()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()   # run_once is single-flight
        self.ingested = 0               # batches applied by this instance
        self.retried = 0                # transient-write retries performed
        self._resume_pending()

    # ----------------------------------------------------------- index
    def _load_index(self) -> dict:
        try:
            with open(self.index_path) as f:
                return json.load(f)
        except FileNotFoundError:
            return {"done": {}, "pending": None}
        except (OSError, json.JSONDecodeError):
            # a torn index write never happens (atomic replace), but an
            # unreadable file should not brick the service: start over and
            # let partition row counts resolve what actually landed
            return {"done": {}, "pending": None}

    def _save_index(self) -> None:
        tmp = self.index_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._index, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.index_path)

    def _resume_pending(self) -> None:
        """Resolve a crash that happened between the pending record and the
        done record: the apply itself is atomic, so the partition's row
        count says whether the batch landed."""
        pending = self._index.get("pending")
        if not pending:
            return
        path = os.path.join(self.out_dir, pending["partition"])
        landed = False
        try:
            header, _ = edf.read_header(path)
            landed = header["nrows"] >= pending["nrows_before"] + pending["rows"]
        except (OSError, AssertionError):
            landed = False
        if landed:
            self._index["done"][pending["batch"]] = {
                "partition": pending["partition"], "rows": pending["rows"]}
        self._index["pending"] = None
        self._save_index()
        # a lost apply is redone naturally: the batch is not in done, so
        # the next poll re-delivers it

    # ------------------------------------------------------- partitions
    @property
    def done_ids(self) -> set:
        return set(self._index["done"])

    @property
    def paths(self) -> list[str]:
        """Current partition files, in partition (= case-major) order."""
        try:
            names = sorted(n for n in os.listdir(self.out_dir)
                           if n.startswith("part_") and n.endswith(".edf"))
        except FileNotFoundError:
            return []
        return [os.path.join(self.out_dir, n) for n in names]

    def _target_partition(self) -> tuple[str, int]:
        """(partition name, its current row count) for the next batch."""
        paths = self.paths
        if paths:
            last = paths[-1]
            try:
                header, _ = edf.read_header(last)
                if header["nrows"] < self.partition_rows:
                    return os.path.basename(last), int(header["nrows"])
            except (OSError, AssertionError):
                pass                    # unreadable partial: next number
            n = int(os.path.basename(last)[5:10]) + 1
        else:
            n = 0
        return f"part_{n:05d}.edf", 0

    def _apply(self, path: str, frame: EventFrame, tables, fresh: bool
               ) -> None:
        """Create or extend one partition, retrying transient OS errors
        with exponential backoff.  Both arms land via ``os.replace``, so
        a retry after a half-failure never observes a torn file."""
        delay = self.backoff
        for attempt in range(self.max_retries + 1):
            try:
                if fresh:
                    tmp = f"{path}.create.{os.getpid()}.tmp"
                    try:
                        edf.write(tmp, frame, tables, version=3,
                                  row_group_rows=self.row_group_rows)
                        os.replace(tmp, path)
                    finally:
                        if os.path.exists(tmp):
                            try:
                                os.remove(tmp)
                            except OSError:
                                pass
                else:
                    edf.append(path, frame, tables,
                               row_group_rows=self.row_group_rows)
                return
            except OSError:
                if attempt == self.max_retries:
                    raise
                self.retried += 1
                time.sleep(delay)
                delay *= 2

    # -------------------------------------------------------- the loop
    def run_once(self, limit: int | None = None) -> int:
        """Ingest up to ``limit`` currently-available batches; returns how
        many were applied (0 = source drained)."""
        with self._lock:
            count = 0
            for batch_id, frame, tables in self.poll(self.done_ids):
                if limit is not None and count >= limit:
                    break
                if batch_id in self._index["done"]:
                    continue
                name, nrows_before = self._target_partition()
                self._index["pending"] = {
                    "batch": batch_id, "partition": name,
                    "rows": frame.nrows, "nrows_before": nrows_before}
                self._save_index()
                self._apply(os.path.join(self.out_dir, name), frame, tables,
                            fresh=nrows_before == 0 and not os.path.exists(
                                os.path.join(self.out_dir, name)))
                self._index["done"][batch_id] = {
                    "partition": name, "rows": frame.nrows}
                self._index["pending"] = None
                self._save_index()
                count += 1
                self.ingested += 1
            return count

    def run(self, stop: threading.Event | None = None) -> None:
        """Blocking ingest loop until ``stop`` (or :meth:`stop`) is set."""
        stop = stop or self._stop
        while not stop.is_set():
            if self.run_once() == 0:
                stop.wait(self.poll_interval)

    def start(self) -> "Ingestor":
        """Run the loop on a daemon thread (idempotent)."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self.run, daemon=True,
                                            name="repro-ingestor")
            self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
