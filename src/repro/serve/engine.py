"""Batched serving engine: prefill + decode loop with a preallocated KV cache.

The production layout (see ``Mdl.cache_specs``) shards caches over batch
(data axes) and *sequence* (model axis — flash-decoding). On CPU this engine
drives the same step functions unsharded; the dry-run proves the sharded
lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as Mdl
from repro.models.config import ModelConfig
from repro.models.module import ShardingRules


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, steps)
    prefill_logits: np.ndarray  # (B, V)


class Engine:
    def __init__(self, cfg: ModelConfig, params, rules: ShardingRules | None = None,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.rules = rules or ShardingRules(
            embed=None, vocab=None, heads=None, mlp=None, expert=None,
            batch=None, seq=None)
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t, f: Mdl.prefill(cfg, p, t, rules=self.rules, frontend=f),
            static_argnums=())
        self._decode = jax.jit(
            lambda p, c, t: Mdl.decode_step(cfg, p, c, t, rules=self.rules))

    def _grow_cache(self, cache):
        """Pad KV caches from prompt length to max_len (SSM states are O(1))."""
        out = dict(cache)
        for k in ("k", "v"):
            if k in out and out[k].ndim >= 3:
                cur = out[k].shape[2]
                if cur < self.max_len:
                    pad = [(0, 0)] * out[k].ndim
                    pad[2] = (0, self.max_len - cur)
                    out[k] = jnp.pad(out[k], pad)
        return out

    def generate(self, prompts: np.ndarray, steps: int, *,
                 frontend=None, greedy: bool = True, rng=None) -> GenerationResult:
        logits, cache = self._prefill(self.params, jnp.asarray(prompts), frontend)
        cache = self._grow_cache(cache)
        toks = []
        tok = jnp.argmax(logits, -1)[:, None]
        for i in range(steps):
            toks.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok)
            if greedy:
                tok = jnp.argmax(logits, -1)[:, None]
            else:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits)[:, None]
        return GenerationResult(np.stack(toks, 1), np.asarray(logits))
