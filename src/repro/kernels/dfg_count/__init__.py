from . import ops
from .dfg_count import dfg_count_pallas
from .ref import dfg_count_ref

__all__ = ["ops", "dfg_count_pallas", "dfg_count_ref"]
