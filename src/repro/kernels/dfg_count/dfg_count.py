"""Pallas TPU kernel: DFG pair counting as one-hot matmuls on the MXU.

TPU adaptation of the paper's shifting-and-counting (§5.4): after the shift/
same-case mask, counting (src, dst) activity pairs is

    C = sum_i w_i * e[src_i] e[dst_i]^T  =  (onehot(src) * w)^T @ onehot(dst)

i.e. a matrix product — the systolic MXU *is* the counter. No hash map, no
scatter: the paper's worst-case O(N^2) collision pathology disappears by
construction.

Tiling: the event stream is cut into ``block_e`` tiles (grid axis k, the
reduction axis — innermost, so the output block accumulates in VMEM across
iterations); the (A, A) count matrix is cut into ``block_a x block_a`` output
tiles (grid axes i, j). VMEM per step: 2 * block_e * block_a * 4B for the
one-hot operands + block_a^2 * 4B for the accumulator — with the defaults
(block_e=512, block_a=128) that is ~0.6 MiB, comfortably inside VMEM, and
both matmul dims are multiples of the 128-lane MXU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(src_ref, dst_ref, w_ref, out_ref, *, block_a: int):
    i = pl.program_id(0)          # src-activity tile
    j = pl.program_id(1)          # dst-activity tile
    k = pl.program_id(2)          # event tile (reduction — innermost)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = src_ref[...].reshape(-1, 1)            # (block_e, 1)
    d = dst_ref[...].reshape(-1, 1)
    w = w_ref[...].reshape(-1, 1)
    be = s.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (be, block_a), 1)
    x = jnp.where(s == rows + i * block_a, w, 0.0)               # (be, A_i)
    y = jnp.where(d == rows + j * block_a, 1.0, 0.0)             # (be, A_j)
    out_ref[...] += jnp.dot(x.T, y, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_activities", "block_e", "block_a", "interpret"))
def dfg_count_pallas(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    num_activities: int,
    *,
    block_e: int = 512,
    block_a: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Count weighted (src, dst) pairs into a dense (A, A) int32 matrix.

    ``w`` is the same-case mask (float); padding events must carry w == 0.
    """
    e = src.shape[0]
    pad_e = (-e) % block_e
    a_pad = max(block_a, ((num_activities + block_a - 1) // block_a) * block_a)
    src = jnp.pad(src.astype(jnp.int32), (0, pad_e), constant_values=-1)
    dst = jnp.pad(dst.astype(jnp.int32), (0, pad_e), constant_values=-1)
    w = jnp.pad(w.astype(jnp.float32), (0, pad_e))
    ne, na = (e + pad_e) // block_e, a_pad // block_a

    out = pl.pallas_call(
        functools.partial(_kernel, block_a=block_a),
        grid=(na, na, ne),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, j, k: (k,)),
            pl.BlockSpec((block_e,), lambda i, j, k: (k,)),
            pl.BlockSpec((block_e,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((block_a, block_a), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a_pad, a_pad), jnp.float32),
        interpret=interpret,
    )(src, dst, w)
    return out[:num_activities, :num_activities].astype(jnp.int32)
