"""DFG pair counting — the square special case of the generalized
``kernels.segment_ops.pair_count`` MXU kernel.

Historically this module held its own Pallas kernel; the tiling and the
one-hot-matmul formulation now live in ``segment_ops.pair_count`` (which
generalizes them to any rectangular (src, dst, weight) triple), and this
entry point is kept as the stable, paper-named API: counting (src, dst)
activity pairs into a dense (A, A) int32 matrix with the systolic MXU as
the counter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops.pair_count import pair_count_pallas


@functools.partial(jax.jit, static_argnames=("num_activities", "block_e", "block_a", "interpret"))
def dfg_count_pallas(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    num_activities: int,
    *,
    block_e: int = 512,
    block_a: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Count weighted (src, dst) pairs into a dense (A, A) int32 matrix.

    ``w`` is the same-case mask (float); padding events must carry w == 0.
    """
    out = pair_count_pallas(src, dst, w.astype(jnp.float32),
                            num_activities, num_activities,
                            block_e=block_e, block_s=block_a,
                            block_d=block_a, interpret=interpret)
    return out.astype(jnp.int32)
