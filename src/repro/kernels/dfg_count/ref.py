"""Pure-jnp oracle for the dfg_count kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("num_activities",))
def dfg_count_ref(src: jax.Array, dst: jax.Array, w: jax.Array, num_activities: int) -> jax.Array:
    """Scatter-add oracle: counts[src_i, dst_i] += w_i."""
    a = num_activities
    key = jnp.clip(src.astype(jnp.int32), 0, a - 1) * a + jnp.clip(dst.astype(jnp.int32), 0, a - 1)
    inb = (src >= 0) & (src < a) & (dst >= 0) & (dst < a)
    ww = jnp.where(inb, w.astype(jnp.float32), 0.0)
    flat = jnp.zeros((a * a,), jnp.float32).at[key].add(ww)
    return flat.reshape(a, a).astype(jnp.int32)
