"""Public entry point for DFG pair counting.

Chooses the Pallas MXU kernel on TPU (or when forced) and the scatter-add
reference elsewhere. ``interpret=True`` runs the kernel body on CPU for
validation — the TPU lowering uses the identical code with interpret=False.
"""
from __future__ import annotations

import jax

from .dfg_count import dfg_count_pallas
from .ref import dfg_count_ref


def dfg_count(src, dst, w, num_activities: int, *, impl: str | None = None):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return dfg_count_pallas(src, dst, w, num_activities,
                                interpret=jax.default_backend() != "tpu")
    return dfg_count_ref(src, dst, w, num_activities)
