"""Pure-jnp oracle for flash_attention: materialized-score GQA attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention_ref(
    q: jax.Array,                # (B, H, Sq, D)
    k: jax.Array,                # (B, KVH, Sk, D)
    v: jax.Array,                # (B, KVH, Sk, D)
    kv_len: jax.Array | None = None,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    g = h // kvh
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (d ** -0.5)
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= rows >= cols
    if window is not None:
        mask &= cols > rows - window
    if kv_len is not None:
        mask &= cols < kv_len
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
