from . import ops
from .flash_attention import flash_attention_pallas
from .ref import attention_ref

__all__ = ["ops", "flash_attention_pallas", "attention_ref"]
