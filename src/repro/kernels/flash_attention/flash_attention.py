"""Pallas TPU kernel: FlashAttention-style causal GQA with optional window.

Online-softmax attention tiled for VMEM: the (S, S) score matrix is never
materialized — each (block_q, block_k) tile is produced on the MXU, folded
into running (max, sum, accumulator) statistics, and discarded. Supports:

* GQA — kv heads indexed as ``q_head // (H // KVH)`` via the K/V BlockSpec
  index maps (no repeat/broadcast of K/V in HBM);
* causal masking and sliding windows (Mixtral SWA, Gemma-3 local layers);
* ragged kv lengths via a scalar length operand (padding-safe).

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv axis is innermost so the
running stats live in VMEM scratch across its iterations. VMEM per step ~
(block_q + 2*block_k) * head_dim * 4B + block_q*block_k*4B; with the defaults
(block_q = block_k = 128, head_dim <= 256) well under 1 MiB. All matmul dims
are multiples of the MXU's 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            block_q: int, block_k: int, num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip tiles entirely above the causal diagonal / outside the window.
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = needed & (k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        kv_len = len_ref[0]
        mask = cols < kv_len
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, :1]                    # (bq, 1)
        l_prev = l_ref[...][:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(
    q: jax.Array,                 # (B, H, Sq, D)
    k: jax.Array,                 # (B, KVH, Sk, D)
    v: jax.Array,                 # (B, KVH, Sk, D)
    kv_len: jax.Array | None = None,   # () int32 — valid kv length
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    scale = d ** -0.5
    if kv_len is None:
        kv_len = jnp.int32(sk)

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = (sq + pad_q) // block_q, (sk + pad_k) // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, qi, ki: (0,)),
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), q, k, v)
    return out[:, :, :sq, :]
