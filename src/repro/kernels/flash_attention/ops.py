"""Public entry point for fused attention (kernel on TPU, oracle elsewhere)."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


def flash_attention(q, k, v, kv_len=None, *, causal=True, window=None, impl=None):
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, kv_len, causal=causal, window=window,
            interpret=jax.default_backend() != "tpu")
    return attention_ref(q, k, v, kv_len, causal=causal, window=window)
